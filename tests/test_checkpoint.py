"""Checkpoint manager: atomicity, keep-K, async, restore, elastic reshard."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serial import load_tree, save_tree


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "step": jnp.int32(seed)}


def test_roundtrip(tmp_path):
    p = str(tmp_path / "t.npz")
    s = _state(3)
    save_tree(p, s)
    r = load_tree(p, s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30, 40):
        m.save(step, _state(step))
    assert m.steps() == [30, 40]


def test_corrupt_tmp_ignored(tmp_path):
    """A crash mid-write (tmp dir without COMMIT) must be invisible."""
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(10, _state(10))
    # simulate a crashed write of step 20
    bad = os.path.join(str(tmp_path), "step_00000020")
    os.makedirs(bad)
    with open(os.path.join(bad, "state.npz"), "w") as f:
        f.write("garbage")
    assert m.steps() == [10]
    restored, step = m.restore_latest(_state(0))
    assert step == 10
    assert int(restored["step"]) == 10


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    m.save(5, _state(5), block=False)
    m.wait()
    assert m.steps() == [5]


def test_restore_latest_none(tmp_path):
    m = CheckpointManager(str(tmp_path))
    restored, step = m.restore_latest(_state(0))
    assert restored is None and step == -1


def test_largest_feasible_mesh_infeasible_counts():
    """Degraded device counts that nothing divides -> None, never an
    exception — the caller decides whether to wait for capacity or give
    up.  Dummy device lists are fine here: a Mesh is only constructed
    on the feasible path."""
    from repro.checkpoint.elastic import largest_feasible_mesh
    # 7 survivors, model must divide 2 or 4: infeasible
    assert largest_feasible_mesh(list(range(7)),
                                 model_divisors={2, 4}) is None
    assert largest_feasible_mesh(list(range(5)),
                                 model_divisors={2}) is None
    # no divisors at all, and no devices at all
    assert largest_feasible_mesh(list(range(4)),
                                 model_divisors=set()) is None
    assert largest_feasible_mesh([], model_divisors={1}) is None


def test_largest_feasible_mesh_prefer_model_edge_cases(subproc):
    """``prefer_model`` outside the divisor set is ignored (largest
    divisor wins); inside the set but not dividing the device count, it
    falls back rather than failing."""
    subproc("""
import jax
from repro.checkpoint.elastic import largest_feasible_mesh

devs = jax.devices()
assert len(devs) == 8

# preference honored when feasible
m = largest_feasible_mesh(devs, model_divisors={1, 2, 4}, prefer_model=2)
assert dict(m.shape) == {'data': 4, 'model': 2}

# prefer_model not in the divisor set: ignored, largest divisor wins
m = largest_feasible_mesh(devs, model_divisors={1, 2, 4}, prefer_model=3)
assert dict(m.shape) == {'data': 2, 'model': 4}

# in the set but 8 % 3 != 0: falls back to the next feasible divisor
m = largest_feasible_mesh(devs, model_divisors={2, 3}, prefer_model=3)
assert dict(m.shape) == {'data': 4, 'model': 2}
print('prefer_model edges OK')
""", devices=8)


def test_elastic_reshard_shrunk_mesh(subproc):
    """Restore an 8-device state onto a 2-device mesh with model=1 —
    the severe-degradation path: every sharded dim collapses onto the
    data axis and values survive bit-exactly."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.elastic import reshard_state, largest_feasible_mesh

mesh8 = make_test_mesh((4, 2), ('data', 'model'))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, P('data', 'model')))
d = tempfile.mkdtemp()
m = CheckpointManager(d)
m.save(1, {'w': x})

devs = jax.devices()[:2]
mesh2 = largest_feasible_mesh(devs, model_divisors={1, 2, 4},
                              prefer_model=1)
assert dict(mesh2.shape) == {'data': 2, 'model': 1}
restored, step = m.restore_latest({'w': x})
out = reshard_state(restored, {'w': ('batch', 'mlp')}, mesh2)
np.testing.assert_array_equal(np.asarray(out['w']),
                              np.arange(64.0).reshape(8, 8))
assert len(out['w'].sharding.device_set) == 2
print('shrunk reshard OK')
""", devices=8)


def test_elastic_reshard(subproc):
    """Save on an 8-device (4,2) mesh -> restore onto (2,2) after
    'failures' (elastic re-entry)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.elastic import reshard_state, largest_feasible_mesh

mesh8 = make_test_mesh((4, 2), ('data', 'model'))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, P('data', 'model')))
state = {'w': x}
d = tempfile.mkdtemp()
m = CheckpointManager(d)
m.save(1, state)

# 4 devices "fail": rebuild on the survivors
devs = jax.devices()[:4]
mesh4 = largest_feasible_mesh(devs, model_divisors={1, 2, 4}, prefer_model=2)
assert mesh4 is not None and mesh4.devices.size == 4
restored, step = m.restore_latest(state)
axes = {'w': ('batch', 'mlp')}
out = reshard_state(restored, axes, mesh4)
np.testing.assert_array_equal(np.asarray(out['w']), np.arange(64.0).reshape(8, 8))
print('elastic OK; new mesh', mesh4.shape)
""", devices=8)
