"""Checkpoint manager: atomicity, keep-K, async, restore, elastic reshard."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serial import load_tree, save_tree


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "step": jnp.int32(seed)}


def test_roundtrip(tmp_path):
    p = str(tmp_path / "t.npz")
    s = _state(3)
    save_tree(p, s)
    r = load_tree(p, s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30, 40):
        m.save(step, _state(step))
    assert m.steps() == [30, 40]


def test_corrupt_tmp_ignored(tmp_path):
    """A crash mid-write (tmp dir without COMMIT) must be invisible."""
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(10, _state(10))
    # simulate a crashed write of step 20
    bad = os.path.join(str(tmp_path), "step_00000020")
    os.makedirs(bad)
    with open(os.path.join(bad, "state.npz"), "w") as f:
        f.write("garbage")
    assert m.steps() == [10]
    restored, step = m.restore_latest(_state(0))
    assert step == 10
    assert int(restored["step"]) == 10


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    m.save(5, _state(5), block=False)
    m.wait()
    assert m.steps() == [5]


def test_restore_latest_none(tmp_path):
    m = CheckpointManager(str(tmp_path))
    restored, step = m.restore_latest(_state(0))
    assert restored is None and step == -1


def test_elastic_reshard(subproc):
    """Save on an 8-device (4,2) mesh -> restore onto (2,2) after
    'failures' (elastic re-entry)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.elastic import reshard_state, largest_feasible_mesh

mesh8 = make_test_mesh((4, 2), ('data', 'model'))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, P('data', 'model')))
state = {'w': x}
d = tempfile.mkdtemp()
m = CheckpointManager(d)
m.save(1, state)

# 4 devices "fail": rebuild on the survivors
devs = jax.devices()[:4]
mesh4 = largest_feasible_mesh(devs, model_divisors={1, 2, 4}, prefer_model=2)
assert mesh4 is not None and mesh4.devices.size == 4
restored, step = m.restore_latest(state)
axes = {'w': ('batch', 'mlp')}
out = reshard_state(restored, axes, mesh4)
np.testing.assert_array_equal(np.asarray(out['w']), np.arange(64.0).reshape(8, 8))
print('elastic OK; new mesh', mesh4.shape)
""", devices=8)
