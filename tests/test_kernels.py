"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps.

Runs in interpret mode by default; the compiled-backend CI lane re-runs
the same sweeps with ``REPRO_PALLAS_INTERPRET=0`` so TPU/GPU runners
validate the *compiled* kernels against the oracles.  On CPU-only
jaxlibs (which cannot compile Pallas) the forced-compiled run self-skips
rather than failing the lane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.swd import random_directions, sphere_prior_samples
from repro.kernels import ops, ref

if not ops.default_interpret() and not ops.compiled_backend_supported():
    pytest.skip("REPRO_PALLAS_INTERPRET=0 but this jax backend only "
                "supports Pallas interpret mode (CPU)",
                allow_module_level=True)


def _sphere(key, shape):
    z = jax.random.normal(key, shape)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-9)


@pytest.mark.parametrize("B,C,d", [(64, 8, 32), (200, 64, 128), (33, 16, 64),
                                   (128, 32, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_posterior_sweep(B, C, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B + C + d), 4)
    z = _sphere(ks[0], (B, d)).astype(dtype)
    mu = (0.5 * jax.random.normal(ks[1], (C, d))).astype(jnp.float32)
    var = jax.random.uniform(ks[2], (C, d), minval=0.05, maxval=0.5)
    logpi = jax.nn.log_softmax(jax.random.normal(ks[3], (C,)))
    r1, e1 = ops.gmm_posterior(z, mu, var, logpi, block_b=64)
    r2, e2 = ref.gmm_posterior_ref(z.astype(jnp.float32), mu, var, logpi)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=tol)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=tol * 5)


@pytest.mark.parametrize("B,N,d", [(32, 64, 32), (64, 256, 128),
                                   (16, 100, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_infonce_vneg_sweep(B, N, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * N + d), 3)
    z = _sphere(ks[0], (B, d)).astype(dtype)
    zp = _sphere(ks[1], (B, d)).astype(dtype)
    zn = _sphere(ks[2], (B, N, d)).astype(dtype)
    l1 = ops.infonce_vneg(z, zp, zn, tau=0.1)
    l2 = ref.infonce_vneg_ref(z.astype(jnp.float32),
                              zp.astype(jnp.float32),
                              zn.astype(jnp.float32), 0.1)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=tol,
                               rtol=1e-2)


@pytest.mark.parametrize("N,d,M", [(100, 32, 8), (256, 128, 50),
                                   (512, 64, 16), (65, 16, 4)])
def test_swd_kernel_sweep(N, d, M):
    key = jax.random.PRNGKey(N + d + M)
    x = _sphere(key, (N, d))
    s1 = float(ops.swd(jax.random.PRNGKey(1), x, n_dirs=M))
    kd, kp = jax.random.split(jax.random.PRNGKey(1))
    dirs = random_directions(kd, M, d)
    prior = sphere_prior_samples(kp, N, d)
    s2 = float(ref.swd_ref(x, prior, dirs))
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("shape", [(100,), (37, 91), (8, 16, 33), (5000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_quant_sweep(shape, dtype):
    x = (3.0 * jax.random.normal(jax.random.PRNGKey(sum(shape)), shape)
         + 1.0).astype(dtype)
    q, sc, zo = ops.int8_quantize(x)
    q2, sc2, zo2 = ref.int8_quantize_ref(x.astype(jnp.float32))
    # bf16 inputs may round-trip to an off-by-one level on exact ties
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)
                               - q2.astype(jnp.int32)))) <= 1
    np.testing.assert_allclose(float(sc), float(sc2), rtol=1e-6)
    xd = ops.int8_dequantize(q, sc, zo)
    assert float(jnp.max(jnp.abs(xd - x.astype(jnp.float32)))) <= \
        float(sc) * 0.51 + 1e-6


@pytest.mark.parametrize("B,shape", [(1, (40, 32)), (5, (16, 16)),
                                     (17, (7,)), (64, (16, 16)),
                                     (3, (100,)), (13, (10, 8, 4)),
                                     (2, (128,)), (33, (20, 24))])
def test_wire_roundtrip_bitwise_matches_vmapped_reference(B, shape):
    """The fused wire kernel IS the vmapped quantize∘dequantize pair —
    bitwise, not allclose: ``SplitEngine.run_batch_async`` swaps one for
    the other inside the serving hot path, so any divergence would break
    the per-frame vs bucketed embedding parity contract.  Odd batch
    sizes and non-128-multiple sample lengths exercise the lane padding
    (which pads each row with its own first element, leaving per-sample
    min/max untouched)."""
    from repro.quant.int8 import dequantize, quantize
    x = (3.0 * jax.random.normal(jax.random.PRNGKey(B + sum(shape)),
                                 (B,) + shape) + 1.0)
    fused = ops.wire_roundtrip(x)
    vmapped = jax.jit(jax.vmap(lambda a: dequantize(quantize(a))))(x)
    assert fused.dtype == jnp.float32 and fused.shape == x.shape
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(vmapped))


def test_wire_roundtrip_b1_matches_per_tensor_reference():
    """At B=1 the per-sample kernel equals the per-tensor quantize of
    ``SplitEngine.run`` — the parity boundary between the batched and
    per-frame serving paths."""
    from repro.quant.int8 import dequantize, quantize
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 24, 16)) * 2.0
    fused = ops.wire_roundtrip(x)
    tensor = jax.jit(lambda a: dequantize(quantize(a)))(x)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(tensor))


@pytest.mark.parametrize("B,T,d,k", [(1, 100, 128, 5), (4, 50, 32, 3),
                                     (2, 16, 8, 7)])
def test_laplacian_kernel_sweep(B, T, d, k):
    ks = jax.random.split(jax.random.PRNGKey(B * T + d), 2)
    z = jax.random.normal(ks[0], (B, T, d))
    m = (jax.random.uniform(ks[1], (B, T)) > 0.3).astype(jnp.float32)
    l1 = float(ops.laplacian_energy(z, m, k=k))
    tots = [ref.laplacian_energy_ref(z[i], m[i], k) for i in range(B)]
    l2 = sum(float(t) for t, _ in tots) / max(
        sum(float(c) for _, c in tots), 1.0)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_kernels_match_core_implementations():
    """The kernels and the core/ layers must agree (they are the same math
    at two altitudes)."""
    from repro.core import gmm as G
    from repro.core.laplacian import dirichlet_energy
    key = jax.random.PRNGKey(0)
    st_ = G.init_gmm(key, 16, 64)
    z = _sphere(jax.random.PRNGKey(1), (64, 64))
    pi, mu, var = G.params_of(st_)
    r1, e1 = ops.gmm_posterior(z, mu, var, jnp.log(pi), block_b=64)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(G.entropy(st_, z)),
                               atol=1e-4)
    z3 = jax.random.normal(jax.random.PRNGKey(2), (2, 40, 16))
    np.testing.assert_allclose(
        float(ops.laplacian_energy(z3, k=5)),
        float(dirichlet_energy(z3, k=5)), rtol=1e-5)
