"""Multi-gateway federation: consistent-hash ring determinism and
stability, session snapshot export/import (gateway- and server-level,
host and sharded backends), the live-migration bit-parity oracle,
drain/rebalance conservation, and chaos-tested member failure with
explicitly counted ``lost_in_flight`` — all on a fake clock.

The load-bearing oracle: a session snapshot-transferred between two
gateways mid-stream produces bit-identical embeddings to the sequential
single-gateway run on the same admitted schedule, and the cluster-wide
per-class conservation identity

    submitted == served + queue_depth + in_flight
                 + shed_expired + lost_in_flight

holds at EVERY ``stats()`` snapshot, including under injected member
failure.
"""
import jax
import numpy as np
import pytest

from repro.api import (AdmissionError, FrameRequest, QoSClass,
                       SessionSnapshot, ShardedFleetBackend,
                       StreamSplitGateway)
from repro.cluster import FailureInjector, GatewayCluster, HashRing
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
from repro.serving import SchedulerCfg, StreamServer, StragglerMonitor

CFG = AudioEncCfg(widths=(8, 8), strides=(1, 1), n_mels=8, frames=8,
                  d_embed=16, groups=2)
L = CFG.n_blocks
N_CLASSES = 4
I, S, B = QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK


@pytest.fixture(scope="module")
def params():
    return init_audio_encoder(CFG, jax.random.PRNGKey(0))


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class QuantilePolicy:
    """u quantile -> split index: deterministic per frame CONTENT, so
    embeddings are independent of batch composition and serving order
    — the property the migration oracle rides on."""

    def __init__(self, L):
        self.L = L

    def decide(self, obs_batch):
        return np.clip((obs_batch[:, 0] * (self.L + 1)).astype(np.int64),
                       0, self.L)


def _head():
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (CFG.d_embed, N_CLASSES))}

    def head_apply(p, z):
        return z @ p["w"]

    return head_init, head_apply


def _mel(sid, t):
    rng = np.random.default_rng(1000 * (sid + 1) + t)
    return rng.normal(size=(CFG.frames, CFG.n_mels)).astype(np.float32)


def _req(sid, t, label=-1):
    rng = np.random.default_rng(5000 * (sid + 1) + t)
    return FrameRequest(t=t, mel=_mel(sid, t), u=float(rng.random()),
                        label=label)


def _gw(params, clock, *, capacity=8, backend=None, **kw):
    base = dict(capacity=capacity, window=8, qos_reserve=0, overlap=True,
                clock=clock)
    if backend is not None:
        base["backend"] = backend
    return StreamSplitGateway(CFG, params, policy=QuantilePolicy(L),
                              **base, **kw)


def _server(params, clock, *, max_batch=8, **kw):
    gw_kw = {k: kw.pop(k) for k in list(kw)
             if k in ("capacity", "backend", "head_init", "head_apply",
                      "refine_every")}
    return StreamServer(_gw(params, clock, **gw_kw),
                        cfg=SchedulerCfg(max_batch=max_batch), clock=clock,
                        **kw)


def _assert_conserved(st):
    assert st.conserved, (st.submitted, st.served, st.queue_depth,
                          st.in_flight, st.shed_expired, st.lost_in_flight)


def _assert_member_conserved(st):
    """Per-member ``StreamStats`` conservation (no lost term: a live
    member never loses frames)."""
    for c in st.frames_submitted:
        assert st.frames_submitted[c] == (
            st.frames_served[c] + st.queue_depth[c] + st.in_flight[c]
            + st.shed_expired[c]), (c, st.frames_submitted,
                                    st.frames_served, st.queue_depth,
                                    st.in_flight, st.shed_expired)


# ---------------------------------------------------------------------------
# HashRing: determinism, consistency, weight bias
# ---------------------------------------------------------------------------

def test_ring_deterministic_and_seeded():
    r1 = HashRing(["a", "b", "c"], seed=7)
    r2 = HashRing(["c", "a", "b"], seed=7)   # order-independent
    assert [r1.owner(k) for k in range(200)] == \
        [r2.owner(k) for k in range(200)]
    r3 = HashRing(["a", "b", "c"], seed=8)   # seed changes placement
    assert [r1.owner(k) for k in range(200)] != \
        [r3.owner(k) for k in range(200)]


def test_ring_add_moves_keys_only_to_newcomer():
    r = HashRing(["a", "b"], seed=0)
    before = {k: r.owner(k) for k in range(500)}
    r.add("c")
    moved = {k for k in before if r.owner(k) != before[k]}
    assert moved                                # c took a real share
    assert all(r.owner(k) == "c" for k in moved)


def test_ring_remove_reassigns_only_departed_keys():
    r = HashRing(["a", "b", "c"], seed=0)
    before = {k: r.owner(k) for k in range(500)}
    r.remove("c")
    for k, m in before.items():
        if m != "c":
            assert r.owner(k) == m              # survivors keep theirs


def test_ring_share_sums_to_one_and_weight_bias():
    r = HashRing(["a", "b", "c"], seed=1)
    sh = r.share()
    assert abs(sum(sh.values()) - 1.0) < 1e-9
    assert all(v > 0.05 for v in sh.values())   # vnodes smooth the arcs
    before = r.share()["b"]
    r.set_weight("b", 0.25)
    after = r.share()["b"]
    assert after < before                        # straggler bias shrinks b
    assert abs(sum(r.share().values()) - 1.0) < 1e-9


def test_ring_preference_walk_and_empty():
    r = HashRing(["a", "b", "c"], seed=2)
    for k in range(50):
        pref = r.preference(k)
        assert sorted(pref) == ["a", "b", "c"]   # all distinct members
        assert pref[0] == r.owner(k)             # owner first
    empty = HashRing()
    assert empty.preference(1) == []
    with pytest.raises(KeyError):
        empty.owner(1)
    with pytest.raises(ValueError):
        HashRing(["a"]).add("a")


# ---------------------------------------------------------------------------
# SessionSnapshot: gateway-level export/import
# ---------------------------------------------------------------------------

def test_gateway_export_import_roundtrip_bits_and_books(params):
    clock = FakeClock()
    src, dst = _gw(params, clock), _gw(params, clock)
    sid = src.open_session(platform="jetson", qos=I).sid
    for t in range(5):
        src.submit(sid, _req(sid, t))
        src.tick()
    before = src.session(sid)
    snap = src.export_session(sid)
    # the exported row is the host representation, bit-exact
    assert snap.ring_t.dtype == np.int64 and snap.ring_newest == 4
    # serialization round-trips bitwise
    snap2 = SessionSnapshot.from_bytes(snap.to_bytes())
    np.testing.assert_array_equal(snap.ring_z, snap2.ring_z)
    np.testing.assert_array_equal(snap.ring_t, snap2.ring_t)
    assert snap.nbytes > 0
    # the source counted an export, not a close; the row is gone
    s = src.stats()
    assert s.sessions_exported == 1 and s.sessions_closed == 0
    assert s.sessions_open == 0
    with pytest.raises(KeyError):
        src.session(sid)
    # import restores every book the SessionInfo surfaces
    info = dst.import_session(snap2)
    after = dst.session(info.sid)
    assert after.frames == before.frames == 5
    assert after.wire_bytes == before.wire_bytes
    assert after.transitions == before.transitions
    assert after.last_k == before.last_k
    assert after.qos is I and after.platform == "jetson"
    assert after.fill_fraction == before.fill_fraction
    assert dst.stats().sessions_imported == 1
    # the stream continues where it left off
    dst.submit(info.sid, _req(sid, 5))
    (r,) = dst.tick()
    assert r.t == 5


def test_gateway_export_refuses_pending_frames(params):
    clock = FakeClock()
    gw = _gw(params, clock)
    sid = gw.open_session().sid
    gw.submit(sid, _req(sid, 0))
    with pytest.raises(RuntimeError, match="pending"):
        gw.export_session(sid)
    gw.tick()
    gw.export_session(sid)          # drained: export succeeds


def test_gateway_import_obeys_admission_policy(params):
    clock = FakeClock()
    src = _gw(params, clock, capacity=4)
    dst = _gw(params, clock, capacity=1)
    a = src.open_session(qos=B).sid
    b = src.open_session(qos=B).sid
    dst.import_session(src.export_session(a))
    with pytest.raises(AdmissionError):          # dst is full
        dst.import_session(src.export_session(b))


def test_export_import_refine_row_transfer_bit_parity(params):
    """The ring-row transfer oracle: after migrating every session, a
    single same-key refine step on the destination produces the SAME
    loss and per-session losses, bitwise, as on a gateway whose
    sessions never moved."""
    head_init, head_apply = _head()
    clock = FakeClock()

    def mk():
        return _gw(params, clock, capacity=4, head_init=head_init,
                   head_apply=head_apply, refine_every=0)

    stay, src, dst = mk(), mk(), mk()
    sids_stay = [stay.open_session().sid for _ in range(3)]
    sids_src = [src.open_session().sid for _ in range(3)]
    for t in range(6):
        for i in range(3):
            stay.submit(sids_stay[i], _req(i, t, label=t % N_CLASSES))
            src.submit(sids_src[i], _req(i, t, label=t % N_CLASSES))
        stay.tick()
        src.tick()
    for i in range(3):               # migrate all three sessions
        dst.import_session(src.export_session(sids_src[i]))
    key = jax.random.PRNGKey(42)
    loss_stay, _, per_stay = stay.backend.refine(key)
    loss_dst, _, per_dst = dst.backend.refine(key)
    np.testing.assert_array_equal(np.asarray(loss_stay),
                                  np.asarray(loss_dst))
    np.testing.assert_array_equal(np.asarray(per_stay),
                                  np.asarray(per_dst))


def test_export_import_across_backends_host_to_sharded(params):
    """Snapshots are backend-portable: a host-ring session implants
    into a device-resident sharded fleet (sentinel remap included) and
    refines to the same per-row loss."""
    head_init, head_apply = _head()
    clock = FakeClock()
    host = _gw(params, clock, capacity=4, head_init=head_init,
               head_apply=head_apply, refine_every=0)
    sharded = _gw(params, clock, backend=ShardedFleetBackend(
        capacity=4, window=8, dim=CFG.d_embed, head_init=head_init,
        head_apply=head_apply, lr=1e-2, seed=0), refine_every=0)
    twin = _gw(params, clock, backend=ShardedFleetBackend(
        capacity=4, window=8, dim=CFG.d_embed, head_init=head_init,
        head_apply=head_apply, lr=1e-2, seed=0), refine_every=0)
    sid_h = host.open_session().sid
    sid_t = twin.open_session().sid
    for t in range(5):
        host.submit(sid_h, _req(0, t, label=t % N_CLASSES))
        twin.submit(sid_t, _req(0, t, label=t % N_CLASSES))
        host.tick()
        twin.tick()
    snap = host.export_session(sid_h)
    info = sharded.import_session(snap)
    # gap slots round-trip: sentinel-remapped, not fake timestamps
    z, t_row, label, newest = sharded.backend.export_row(info.sid)
    np.testing.assert_array_equal(t_row, snap.ring_t)
    np.testing.assert_array_equal(z, snap.ring_z)
    assert newest == snap.ring_newest
    key = jax.random.PRNGKey(3)
    loss_m, _, _ = sharded.backend.refine(key)
    loss_t, _, _ = twin.backend.refine(key)
    np.testing.assert_array_equal(np.asarray(loss_m), np.asarray(loss_t))


def test_sharded_import_rejects_out_of_range_timestamps(params):
    b = ShardedFleetBackend(capacity=2, window=4, dim=3)
    sid = b.admit()
    t = np.full((4,), np.iinfo(np.int64).max // 2, np.int64)
    with pytest.raises(ValueError, match="int32"):
        b.import_row(sid, np.zeros((4, 3), np.float32), t,
                     np.full((4,), -1, np.int64), 1)


# ---------------------------------------------------------------------------
# StreamServer-level export/import: queued frames + books migrate
# ---------------------------------------------------------------------------

def test_server_export_import_moves_queued_frames_and_books(params):
    clock = FakeClock()
    src = _server(params, clock, rate_limit=(10.0, 8))
    dst = _server(params, clock)
    sid = src.open_session(qos=S, weight=2.0).sid
    # serve two frames, then queue three more without stepping
    for t in range(2):
        src.submit(sid, _req(sid, t))
        clock.advance(0.01)
        src.step()
    src.quiesce()
    for t in range(2, 5):
        src.submit(sid, _req(sid, t))
    st_src = src.stats()
    depth_before = sum(st_src.queue_depth.values())
    snap = src.export_session(sid)
    assert snap.server is not None
    assert (snap.server.submitted, snap.server.served) == (5, 2)
    assert len(snap.server.queued) == 3
    assert snap.server.weight == 2.0
    assert snap.server.bucket is not None       # token-bucket level moves
    # the frames' ledger left with them: source conservation holds with
    # zero depth for the departed session
    st = src.stats()
    _assert_member_conserved(st)
    assert sum(st.queue_depth.values()) == depth_before - 3
    info = dst.import_session(snap)
    st = dst.stats()
    _assert_member_conserved(st)
    assert sum(st.queue_depth.values()) == 3
    # the queued frames serve on the new owner with original identity
    seen = []
    dst._on_result = seen.append
    while dst.busy():
        clock.advance(0.01)
        dst.step()
    assert [r.t for r in seen] == [2, 3, 4]
    # close drains cleanly: books balanced (5 submitted = 5 served)
    dst.close_session(info.sid)
    assert dst.stats().gateway.sessions_open == 0


def test_server_export_requires_quiesce(params):
    clock = FakeClock()
    srv = _server(params, clock)
    sid = srv.open_session().sid
    srv.submit(sid, _req(sid, 0))
    clock.advance(0.01)
    srv.step()                       # pipelined: plan now in flight
    with pytest.raises(RuntimeError, match="quiesce"):
        srv.export_session(sid)
    srv.quiesce()
    snap = srv.export_session(sid)   # in-flight collected: exports fine
    assert snap.server.served == 1


def test_server_import_merges_queued_frames_in_enq_order(params):
    """Migrated frames interleave with the target's own by ORIGINAL
    arrival time — the front==oldest==earliest-deadline invariant
    survives the merge, so EDF order is preserved across migration."""
    clock = FakeClock()
    src = _server(params, clock)
    dst = _server(params, clock)
    a = src.open_session(qos=B).sid
    b = dst.open_session(qos=B).sid
    # interleaved arrivals: src at t=0.0, 0.2; dst at 0.1, 0.3
    src.submit(a, _req(a, 0))
    clock.advance(0.1)
    dst.submit(b, _req(b, 0))
    clock.advance(0.1)
    src.submit(a, _req(a, 1))
    clock.advance(0.1)
    dst.submit(b, _req(b, 1))
    snap = src.export_session(a)
    info = dst.import_session(snap)
    with dst.queues.cond:
        order = [(qf.sid, qf.frame.t, qf.enq_s)
                 for qf in dst.queues.by_class[B].q]
        seqs = [qf.seq for qf in dst.queues.by_class[B].q]
    assert [e for (_, _, e) in order] == sorted(e for (_, _, e) in order)
    assert order[0][0] == info.sid and order[0][1] == 0   # oldest first
    assert seqs == sorted(seqs)      # seq order agrees with queue order


# ---------------------------------------------------------------------------
# The live-migration oracle
# ---------------------------------------------------------------------------

def _run_cluster_stream(params, clock, *, drain_at=None, n_sessions=4,
                        n_frames=8, seed=11):
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    served = []
    cl = GatewayCluster(members, seed=seed, timer=clock,
                        on_result=served.append)
    infos = [cl.open_session(qos=S) for _ in range(n_sessions)]
    for t in range(n_frames):
        if drain_at is not None and t == drain_at:
            victim = sorted({cl.session_member(i.sid) for i in infos})[0]
            moved = cl.drain(victim)
            assert moved > 0         # the drain actually migrated work
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())
    cl.pump()
    _assert_conserved(cl.stats())
    for i in infos:
        cl.close_session(i.sid)
    return cl, infos, served


def test_live_migration_bit_parity_oracle(params):
    """THE acceptance oracle: sessions snapshot-transferred between two
    gateways mid-stream produce bit-identical embeddings to the
    sequential single-gateway run on the same admitted schedule, and
    nothing is dropped or double-served."""
    clock = FakeClock()
    cl, infos, served = _run_cluster_stream(params, clock, drain_at=4)
    assert cl.stats().migrations > 0
    # every (session, t) served exactly once, with original identity
    by_sid = {}
    for r in served:
        by_sid.setdefault(r.sid, {})[r.t] = r
    assert sorted(by_sid) == [i.sid for i in infos]
    for sid, rs in by_sid.items():
        assert sorted(rs) == list(range(8))     # nothing lost, no dupes
    # sequential oracle: one fresh gateway, same frames in t order
    oracle = _gw(params, FakeClock(), capacity=8)
    for sid in sorted(by_sid):
        osid = oracle.open_session().sid
        for t in range(8):
            oracle.submit(osid, _req(sid, t))
            (r,) = oracle.tick()
            got = by_sid[sid][t]
            np.testing.assert_array_equal(got.z, r.z)   # bitwise
            assert got.k == r.k and got.route == r.route
            assert got.wire_bytes == r.wire_bytes


def test_drain_conserves_and_serves_queued_frames(params):
    """Queued frames at drain time are replayed on the new owner with
    their ORIGINAL deadlines — none shed, none lost, and the books
    balance: submitted == served cluster-wide after the drain."""
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=5, timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    # build a backlog, then drain the busier member mid-stream
    for t in range(3):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
    victim = cl.session_member(infos[0].sid)
    homed = [i.sid for i in infos if cl.session_member(i.sid) == victim]
    moved = cl.drain(victim)
    assert moved == len(homed)       # exactly the victim's sessions moved
    st = cl.stats()
    _assert_conserved(st)
    assert victim not in st.members
    assert st.drains == 1 and st.migrated_frames > 0
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.served == st.submitted            # every frame served
    assert sum(st.shed_expired.values()) == 0
    assert sum(st.lost_in_flight.values()) == 0
    # the drained member can come back and take new placements
    cl.add_member(victim, _server(params, clock))
    assert victim in cl.stats().members


def test_drained_member_rejoins_without_double_counting(params):
    """``drain()`` parks the member server for reuse; ``add_member()``
    with the SAME object must re-interpose the delivery callbacks
    cleanly.  (A rejoin used to double-wrap them — every frame the
    rejoined member served counted twice, silently breaking the
    conservation identity.)"""
    clock = FakeClock()
    servers = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(dict(servers), seed=5, timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    victim = cl.session_member(infos[0].sid)
    cl.drain(victim)
    # identical membership -> identical ring -> ownership reverts
    assert cl.add_member(victim, servers[victim]) > 0
    for t in range(4):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.served == st.submitted
    assert sum(st.served.values()) == 16    # once each, not twice
    for i in infos:
        cl.close_session(i.sid)


def test_drain_refuses_last_member_with_sessions(params):
    clock = FakeClock()
    cl = GatewayCluster({"a": _server(params, clock)}, timer=clock)
    cl.open_session()
    with pytest.raises(RuntimeError, match="only member"):
        cl.drain("a")


def test_add_member_rebalances_only_moved_ownership(params):
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=9, timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(8)]
    before = {i.sid: cl.session_member(i.sid) for i in infos}
    ring_twin = HashRing(["a", "b"], seed=9)
    ring_twin.add("c")
    cl.add_member("c", _server(params, clock))
    for i in infos:
        now = cl.session_member(i.sid)
        want = ring_twin.owner(i.sid)
        if want == "c":
            assert now == "c"                   # moved to the newcomer
        else:
            assert now == before[i.sid]         # everyone else untouched
    _assert_conserved(cl.stats())


# ---------------------------------------------------------------------------
# Chaos: injected member failure, straggler bias
# ---------------------------------------------------------------------------

def test_member_failure_counts_lost_and_restores_from_checkpoint(params):
    clock = FakeClock()
    members = {"a": _server(params, clock, max_batch=4),
               "b": _server(params, clock, max_batch=4)}
    cl = GatewayCluster(members, seed=3, snapshot_every=2,
                        injectors={"a": FailureInjector(fail_at=(6,))},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    homes = {i.sid: cl.session_member(i.sid) for i in infos}
    assert "a" in homes.values()                # the victim serves work
    for t in range(10):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())           # ...including mid-chaos
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.failures == 1 and st.members == ("b",)
    # the death was not silent: queued+in-flight frames are counted
    assert sum(st.lost_in_flight.values()) > 0
    # every session survived via its checkpoint and kept serving
    assert st.sessions_open == 4 and cl.lost_sessions == []
    assert all(cl.session_member(i.sid) == "b" for i in infos)
    # streams continue after recovery
    for i in infos:
        cl.submit(i.sid, _req(i.sid, 99))
    cl.pump()
    _assert_conserved(cl.stats())
    for i in infos:
        cl.close_session(i.sid)
    _assert_conserved(cl.stats())


def test_member_failure_without_checkpoints_drops_visibly(params):
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=3, snapshot_every=0,
                        injectors={"a": FailureInjector(fail_at=(3,))},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    victims = [i.sid for i in infos if cl.session_member(i.sid) == "a"]
    assert victims
    for t in range(5):
        for i in infos:
            try:
                cl.submit(i.sid, _req(i.sid, t))
            except KeyError:
                assert i.sid in victims         # dropped sessions refuse
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())
    st = cl.stats()
    assert sorted(cl.lost_sessions) == sorted(victims)
    assert st.sessions_open == 4 - len(victims)
    assert sum(st.lost_in_flight.values()) > 0   # explicit, never silent
    _assert_conserved(st)


def test_straggler_signal_shrinks_ring_share(params):
    """An injected step-duration source makes member a stall; the
    monitor flags it and the stepping loop shrinks a's hash-space share
    — new placements drift to b, nothing already placed is evicted."""
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    # timer readings per step, members in sorted order: (a.t0, a.t1,
    # b.t0, b.t1).  Six healthy 10ms steps, then a stalls for 5s.
    vals = [0.0, 0.01, 0.0, 0.01] * 6 + [0.0, 5.0, 0.0, 0.01]
    it = iter(vals)
    cl = GatewayCluster(
        members, seed=1,
        straggler_factory=lambda: StragglerMonitor(factor=3.0, window=8,
                                                   warmup=3),
        straggler_weight=0.25, timer=lambda: next(it, 0.0))
    share0 = cl.stats().ring_share["a"]
    for _ in range(7):
        cl.step()
    assert cl._stragglers["a"].events            # the stall was flagged
    assert not cl._stragglers["b"].events
    share1 = cl.stats().ring_share["a"]
    assert share1 < share0                       # placement bias applied
    assert abs(sum(cl.stats().ring_share.values()) - 1.0) < 1e-9
    # placement now prefers b (members have 8 rows each)
    homes = [cl.session_member(cl.open_session().sid) for _ in range(12)]
    assert homes.count("b") > homes.count("a")


def test_cluster_rejections_counted_at_federation_boundary(params):
    clock = FakeClock()
    cl = GatewayCluster(
        {"a": _server(params, clock, queue_maxlen=2)}, timer=clock)
    info = cl.open_session(qos=B, rate_limit=None)
    from repro.serving import QueueFullError
    cl.submit(info.sid, _req(0, 0))
    cl.submit(info.sid, _req(0, 1))
    with pytest.raises(QueueFullError):
        cl.submit(info.sid, _req(0, 2))
    st = cl.stats()
    assert st.rejected_full["bulk"] == 1
    assert st.submitted["bulk"] == 2            # refusals never counted
    _assert_conserved(st)


def test_cluster_refuses_started_members(params):
    clock = FakeClock()
    srv = _server(params, clock)
    srv.start()
    try:
        with pytest.raises(ValueError, match="serving thread"):
            GatewayCluster({"a": srv}, timer=clock)
    finally:
        srv.stop()
