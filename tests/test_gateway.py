"""Gateway API: session lifecycle + typed admission, k-bucket dispatch
bit-parity with per-frame SplitEngine.run, wire accounting, policy
unification, and refine cadence."""
import jax
import numpy as np
import pytest

from repro.api import (AdmissionError, FrameRequest, QoSClass, SplitPolicy,
                       StreamSplitGateway, make_policy)
from repro.api.policies import (EntropyThresholdPolicy, FixedKPolicy,
                                RLPolicy, RulePolicy)
from repro.core.fleet import FleetFullError
from repro.core.splitter import SplitEngine
from repro.models.audio_encoder import (AudioEncCfg, boundary_bytes,
                                        init_audio_encoder)

CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)
L = CFG.n_blocks
N_CLASSES = 4


@pytest.fixture(scope="module")
def params():
    return init_audio_encoder(CFG, jax.random.PRNGKey(0))


def _mel(rng):
    return rng.normal(size=(CFG.frames, CFG.n_mels)).astype(np.float32)


def _head():
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (CFG.d_embed, N_CLASSES))}

    def head_apply(p, z):
        return z @ p["w"]

    return head_init, head_apply


class SpreadPolicy:
    """Deterministic test policy: frame i gets k = i % (L+1) — every
    split index appears in one tick."""

    def __init__(self, L):
        self.L = L

    def decide(self, obs_batch):
        return np.arange(len(obs_batch), dtype=np.int64) % (self.L + 1)


# ---------------------------------------------------------------------------
# Session lifecycle + typed admission
# ---------------------------------------------------------------------------

def test_session_lifecycle(params):
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 2),
                            capacity=4, window=8, qos_reserve=0)
    rng = np.random.default_rng(0)
    info = gw.open_session(platform="m2", qos=QoSClass.INTERACTIVE)
    assert info.platform == "m2" and info.qos is QoSClass.INTERACTIVE
    assert info.frames == 0 and info.last_k == -1
    gw.submit(info.sid, FrameRequest(t=0, mel=_mel(rng), label=1))
    (r,) = gw.tick()
    assert r.sid == info.sid and r.t == 0 and r.k == 2
    assert r.z.shape == (CFG.d_embed,)
    mid = gw.session(info.sid)
    assert mid.frames == 1 and mid.last_k == 2 and mid.fill_fraction > 0
    final = gw.close_session(info.sid)
    assert final.frames == 1
    with pytest.raises(KeyError):
        gw.submit(info.sid, FrameRequest(t=1, mel=_mel(rng)))
    with pytest.raises(KeyError):
        gw.session(info.sid)
    # the row is reusable and starts clean
    info2 = gw.open_session()
    assert gw.session(info2.sid).fill_fraction == 0.0
    s = gw.stats()
    assert s.sessions_opened == 2 and s.sessions_closed == 1
    assert s.sessions_open == 1


def test_submit_rejects_batched_mel(params):
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 1),
                            capacity=2, qos_reserve=0)
    sid = gw.open_session().sid
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        gw.submit(sid, FrameRequest(t=0, mel=_mel(rng)[None]))


def test_admission_error_is_typed_fleet_full(params):
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 0),
                            capacity=2, qos_reserve=0)
    gw.open_session()
    gw.open_session()
    with pytest.raises(AdmissionError) as ei:
        gw.open_session()
    # the api error IS a FleetFullError (existing guards keep working)
    assert isinstance(ei.value, FleetFullError)
    assert ei.value.n_active == 2 and ei.value.capacity == 2
    assert gw.stats().admission_refusals == 1


def test_qos_classes_reserve_headroom(params):
    """BULK runs out first, then STANDARD; INTERACTIVE fills the fleet."""
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 0),
                            capacity=8, qos_reserve=2)
    for _ in range(4):
        gw.open_session(qos=QoSClass.BULK)      # admitted while free >= 5
    with pytest.raises(AdmissionError):
        gw.open_session(qos=QoSClass.BULK)      # free=4 < 1+2*2
    for _ in range(2):
        gw.open_session(qos=QoSClass.STANDARD)  # admitted while free >= 3
    with pytest.raises(AdmissionError):
        gw.open_session(qos=QoSClass.STANDARD)  # free=2 < 1+2
    for _ in range(2):
        gw.open_session(qos=QoSClass.INTERACTIVE)
    with pytest.raises(AdmissionError):
        gw.open_session(qos=QoSClass.INTERACTIVE)  # truly full
    assert gw.stats().sessions_open == 8


# ---------------------------------------------------------------------------
# k-bucket dispatch parity: gateway z bit-matches per-frame SplitEngine.run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [True, False])
def test_bucketed_dispatch_bit_matches_per_frame_run(params, quantize):
    n = 2 * (L + 1)   # every k twice -> every bucket is a real batch
    gw = StreamSplitGateway(CFG, params, policy=SpreadPolicy(L),
                            capacity=n, window=8, qos_reserve=0,
                            quantize_wire=quantize)
    eng = SplitEngine(CFG, quantize_wire=quantize)
    rng = np.random.default_rng(1)
    sids = [gw.open_session().sid for _ in range(n)]
    mels = {}
    for t in range(2):
        for sid in sids:
            mels[(sid, t)] = _mel(rng)
            gw.submit(sid, FrameRequest(t=t, mel=mels[(sid, t)]))
        results = gw.tick()
        assert len(results) == n
        assert sorted({r.k for r in results}) == list(range(L + 1))
        for r in results:
            z_ref, _ = eng.run(params, mels[(r.sid, r.t)][None], r.k)
            np.testing.assert_array_equal(
                r.z, np.asarray(z_ref)[0],
                err_msg=f"k={r.k} not bit-identical to per-frame run")


def test_results_in_submission_order_with_bucket_sizes(params):
    gw = StreamSplitGateway(CFG, params,
                            policy=EntropyThresholdPolicy(L, threshold=0.5,
                                                          offload_k=2),
                            capacity=6, window=8, qos_reserve=0)
    rng = np.random.default_rng(2)
    sids = [gw.open_session().sid for _ in range(6)]
    us = [0.1, 0.9, 0.2, 0.8, 0.3, 0.9]
    for i, sid in enumerate(sids):
        gw.submit(sid, FrameRequest(t=0, mel=_mel(rng), u=us[i]))
    results = gw.tick()
    assert [r.sid for r in results] == sids       # submission order
    for r, u in zip(results, us):
        assert r.k == (2 if u > 0.5 else L)
        assert r.route == ("split" if u > 0.5 else "edge")
        assert r.bucket_size == 3
    assert gw.stats().dispatches == 2             # two buckets, two dispatches


# ---------------------------------------------------------------------------
# Overlapped tick data plane: single-sync contract + PR-3 path parity
# ---------------------------------------------------------------------------

def test_async_tick_bit_matches_pr3_sync_path(params):
    """A mixed-k tick through the overlapped plane (staged H2D, async
    bucket chains, fused Pallas wire kernel, ONE sync) is bit-identical
    to the PR-3 per-bucket-sync dispatch — and performs exactly one
    device sync and one D2H embedding copy where PR-3 paid one round-trip
    per bucket (counted through the instrumented _block/_d2h hooks)."""
    n = 2 * (L + 1)   # every k twice -> L+1 buckets per tick (mixed-k)

    def mk(overlap):
        return StreamSplitGateway(CFG, params, policy=SpreadPolicy(L),
                                  capacity=n, window=8, qos_reserve=0,
                                  overlap=overlap)

    gw_a, gw_s = mk(True), mk(False)
    rng = np.random.default_rng(11)
    sids_a = [gw_a.open_session().sid for _ in range(n)]
    sids_s = [gw_s.open_session().sid for _ in range(n)]
    for t in range(2):
        mels = [_mel(rng) for _ in range(n)]
        for gw, sids in ((gw_a, sids_a), (gw_s, sids_s)):
            for i, sid in enumerate(sids):
                gw.submit(sid, FrameRequest(t=t, mel=mels[i]))
        for ra, rs in zip(gw_a.tick(), gw_s.tick()):
            np.testing.assert_array_equal(
                ra.z, rs.z, err_msg=f"k={ra.k} diverged from the sync path")
            assert ra.k == rs.k and ra.wire_bytes == rs.wire_bytes
            assert ra.bucket_size == rs.bucket_size
    sa, ss = gw_a.stats(), gw_s.stats()
    # THE contract: one sync + one D2H per tick, however many buckets
    assert sa.device_syncs_per_tick == 1
    assert sa.d2h_copies_per_tick == 1
    assert ss.device_syncs_per_tick == L + 1      # PR-3: one per bucket
    assert ss.d2h_copies_per_tick == L + 1
    # the whole tick's frames staged as ONE h2d transfer (pow2-padded so
    # arbitrary streaming tick sizes don't grow the gather compile
    # cache), measured
    from repro.core.fleet import pad_pow2
    assert sa.staged_h2d_bytes == \
        2 * pad_pow2(n) * CFG.frames * CFG.n_mels * 4
    assert ss.staged_h2d_bytes == 0               # PR-3 staged per bucket
    assert sa.frames == ss.frames == 2 * n


def test_pipelined_phase_ticks_bit_match_sequential(params):
    """``tick_launch``/``tick_collect`` interleaved across ticks (tick
    t+1 launched while tick t's chains are in flight — the serving
    runtime's cross-tick pipeline) serve bit-identical embeddings to the
    plain ``tick()`` loop, and every collected tick still reports
    exactly one device sync and one D2H copy."""
    n = L + 1
    def mk():
        return StreamSplitGateway(CFG, params, policy=SpreadPolicy(L),
                                  capacity=n, window=8, qos_reserve=0)

    gw_p, gw_s = mk(), mk()
    sids_p = [gw_p.open_session().sid for _ in range(n)]
    sids_s = [gw_s.open_session().sid for _ in range(n)]
    rng = np.random.default_rng(13)
    mels = [[_mel(rng) for _ in range(n)] for _ in range(3)]

    def submit(gw, sids, t):
        for i, sid in enumerate(sids):
            gw.submit(sid, FrameRequest(t=t, mel=mels[t][i]))

    # pipelined: two plans in flight before the first collect
    submit(gw_p, sids_p, 0)
    plan0 = gw_p.tick_launch()
    submit(gw_p, sids_p, 1)
    plan1 = gw_p.tick_launch()
    res_p = [gw_p.tick_collect(plan0), gw_p.tick_collect(plan1)]
    assert gw_p.stats().device_syncs_per_tick == 1
    assert gw_p.stats().d2h_copies_per_tick == 1
    submit(gw_p, sids_p, 2)
    res_p.append(gw_p.tick_collect(gw_p.tick_launch()))
    # sequential reference
    res_s = []
    for t in range(3):
        submit(gw_s, sids_s, t)
        res_s.append(gw_s.tick())
    for tick_p, tick_s in zip(res_p, res_s):
        for rp, rs in zip(tick_p, tick_s):
            np.testing.assert_array_equal(rp.z, rs.z)
            assert rp.k == rs.k and rp.t == rs.t
    sp, ss = gw_p.stats(), gw_s.stats()
    assert sp.ticks == ss.ticks == 3 and sp.frames == ss.frames == 3 * n
    assert sp.device_syncs_per_tick == 1 and sp.d2h_copies_per_tick == 1
    # the fleet rings saw the same launch-order ingest
    for a, b in zip(gw_p.backend.snapshot(), gw_s.backend.snapshot()):
        np.testing.assert_array_equal(a, b)


def test_tick_launch_requires_overlapped_plane(params):
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 1),
                            capacity=2, qos_reserve=0, overlap=False)
    with pytest.raises(RuntimeError):
        gw.tick_launch()


def test_refine_due_next_tick_predicts_refine(params):
    head_init, head_apply = _head()
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 2),
                            capacity=2, window=8, qos_reserve=0,
                            head_init=head_init, head_apply=head_apply,
                            refine_every=2)
    rng = np.random.default_rng(14)
    sid = gw.open_session().sid
    for t in range(4):
        due = gw.refine_due_next_tick()
        assert due == (t % 2 == 1)
        gw.submit(sid, FrameRequest(t=t, mel=_mel(rng), label=0))
        before = gw.stats().refine_rounds
        gw.tick()
        assert gw.stats().refine_rounds == before + (1 if due else 0)


def test_profile_tick_restores_per_bucket_timing(params):
    """``tick(profile=True)`` is the diagnostic mode: one sync per bucket
    (so per-bucket latency is measurable) while results stay identical."""
    n = L + 1
    ticks = iter(range(10_000))
    gw = StreamSplitGateway(CFG, params, policy=SpreadPolicy(L),
                            capacity=n, window=8, qos_reserve=0,
                            clock=lambda: 0.5 * next(ticks))
    rng = np.random.default_rng(12)
    sids = [gw.open_session().sid for _ in range(n)]
    for sid in sids:
        gw.submit(sid, FrameRequest(t=0, mel=_mel(rng)))
    results = gw.tick(profile=True)
    s = gw.stats()
    # one per bucket + the final reassembly-gather wait
    assert s.device_syncs_per_tick == n + 1
    assert s.d2h_copies_per_tick == 1             # embeddings still 1 copy
    # fake clock: each bucket spans one 0.5 s read pair -> 500 ms/frame
    assert all(r.latency_ms == 500.0 for r in results)


# ---------------------------------------------------------------------------
# Wire accounting through the gateway
# ---------------------------------------------------------------------------

def test_gateway_wire_bytes_match_boundary_bytes_every_k(params):
    n = L + 1
    gw = StreamSplitGateway(CFG, params, policy=SpreadPolicy(L),
                            capacity=n, window=8, qos_reserve=0)
    per_sample = boundary_bytes(CFG, dtype_bytes=1)
    rng = np.random.default_rng(3)
    sids = [gw.open_session().sid for _ in range(n)]
    for sid in sids:
        gw.submit(sid, FrameRequest(t=0, mel=_mel(rng)))
    for r in gw.tick():
        if r.k == L:
            assert r.wire_bytes == 0 and r.route == "edge"
        else:
            # +8: per-tensor scale/zero header of the INT8 wire format
            assert r.wire_bytes == per_sample[r.k] + 8, f"k={r.k}"
    info = gw.session(sids[0])
    assert info.wire_bytes == per_sample[0] + 8   # frame 0 ran k=0
    assert gw.stats().wire_bytes == sum(
        per_sample[k] + 8 for k in range(L))      # k=L ships nothing


# ---------------------------------------------------------------------------
# Refine cadence + lazy sync surface
# ---------------------------------------------------------------------------

def test_refine_cadence_and_sync_accounting(params):
    head_init, head_apply = _head()
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 2),
                            capacity=2, window=8, qos_reserve=0,
                            head_init=head_init, head_apply=head_apply,
                            refine_every=2)
    rng = np.random.default_rng(4)
    sid = gw.open_session().sid
    for t in range(4):
        gw.submit(sid, FrameRequest(t=t, mel=_mel(rng), label=t % N_CLASSES,
                                    bandwidth_mbps=30.0, charging=True))
        gw.tick()
    s = gw.stats()
    assert s.refine_rounds == 2                   # ticks 2 and 4
    assert np.isfinite(s.last_refine_loss)
    # lazy sync fired (weights push: charging + high bandwidth)
    assert s.sync_events >= 1 and s.sync_bytes > 0
    assert gw.session(sid).sync_bytes == s.sync_bytes


def test_atomic_transition_counting(params):
    gw = StreamSplitGateway(CFG, params,
                            policy=EntropyThresholdPolicy(L, threshold=0.5,
                                                          offload_k=1),
                            capacity=2, window=8, qos_reserve=0)
    rng = np.random.default_rng(5)
    sid = gw.open_session().sid
    for t, u in enumerate([0.1, 0.9, 0.9, 0.1]):  # L, 1, 1, L
        gw.submit(sid, FrameRequest(t=t, mel=_mel(rng), u=u))
        gw.tick()
    assert gw.session(sid).transitions == 2       # L->1 and 1->L


# ---------------------------------------------------------------------------
# Policy unification
# ---------------------------------------------------------------------------

def test_make_policy_covers_all_controller_kinds():
    obs = np.array([[0.1, 0.2, 0.9],    # low U, idle cpu, high bw
                    [0.9, 0.9, 0.01]],  # high U, busy cpu, dead link
                   np.float32)
    for kind, expected in [("edge", [L, L]), ("server", [0, 0]),
                           ("static", [3, 3]), ("rule", [2, L]),
                           ("entropy", [L, 2])]:
        pol = make_policy(kind, L)
        assert isinstance(pol, SplitPolicy)
        np.testing.assert_array_equal(pol.decide(obs), expected, err_msg=kind)
    with pytest.raises(ValueError):
        make_policy("nope", L)
    with pytest.raises(ValueError):
        make_policy("rl", L)                      # rl needs params


def test_rl_policy_batched_matches_greedy_action():
    from repro.core.ppo import greedy_action, init_policy
    rl_params = init_policy(jax.random.PRNGKey(0), 3, L + 1)
    pol = make_policy("rl", L, rl_params=rl_params)
    rng = np.random.default_rng(6)
    obs = rng.random((5, 3)).astype(np.float32)
    ks = pol.decide(obs)
    for i in range(5):
        assert ks[i] == greedy_action(rl_params, obs[i]), f"row {i}"


def test_gateway_rejects_mismatched_policy_action_space(params):
    with pytest.raises(ValueError):
        StreamSplitGateway(CFG, params, policy=FixedKPolicy(L + 3, 1))


# ---------------------------------------------------------------------------
# Fleet backend seam + injected clock
# ---------------------------------------------------------------------------

def test_injected_clock_makes_timing_deterministic(params):
    """Every timing stat derives from the injected clock: with a fake
    counter clock the latency/uptime numbers are exact, not wall-clock."""
    ticks = iter(range(10_000))
    gw = StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 2),
                            capacity=2, window=8, qos_reserve=0,
                            clock=lambda: 0.5 * next(ticks))
    sid = gw.open_session().sid
    rng = np.random.default_rng(7)
    gw.submit(sid, FrameRequest(t=0, mel=_mel(rng)))
    (r,) = gw.tick()
    # dispatch stamps t_d0 at read 2 and closes the span at read 5 (the
    # always-on launch/collect EWMA stage gauges stamp reads 3 and 4 in
    # between): (2.5 - 1.0) * 1e3 / bucket = 1500ms
    assert r.latency_ms == 1500.0
    s = gw.stats()
    # tick entry(1) .. exit(7) around dispatch + EWMA stamps: 3.0 s
    assert s.last_tick_ms == 3000.0
    # reads: ctor(0), entry(1), dispatch(2,5), launch/collect EWMA
    # stamps(3,4,6), tick exit(7), stats(8)
    assert s.uptime_s == 0.5 * 8


def test_gateway_on_sharded_backend_bit_matches_host(params):
    """The backend seam must not change serving results: a gateway over a
    1-shard device-resident backend serves bit-identical embeddings and
    refine losses, with zero snapshot h2d traffic."""
    from repro.api import ShardedFleetBackend
    head_init, head_apply = _head()

    def mk(backend=None):
        kw = dict(capacity=4, window=8, qos_reserve=0)
        if backend is None:
            kw.update(head_init=head_init, head_apply=head_apply)
        return StreamSplitGateway(CFG, params, policy=SpreadPolicy(L),
                                  refine_every=2, backend=backend, **kw)

    gw_h = mk()
    gw_s = mk(ShardedFleetBackend(
        capacity=4, window=8, dim=CFG.d_embed, head_init=head_init,
        head_apply=head_apply, lr=1e-2, seed=0))
    rng = np.random.default_rng(8)
    sids_h = [gw_h.open_session().sid for _ in range(4)]
    sids_s = [gw_s.open_session().sid for _ in range(4)]
    for t in range(4):
        mels = [_mel(rng) for _ in range(4)]
        for gw, sids in ((gw_h, sids_h), (gw_s, sids_s)):
            for i, sid in enumerate(sids):
                gw.submit(sid, FrameRequest(t=t, mel=mels[i],
                                            label=t % N_CLASSES))
        for rh, rs in zip(gw_h.tick(), gw_s.tick()):
            np.testing.assert_array_equal(rh.z, rs.z)
            assert rh.k == rs.k and rh.wire_bytes == rs.wire_bytes
    sh, ss = gw_h.stats(), gw_s.stats()
    assert sh.refine_rounds == ss.refine_rounds == 2
    assert ss.last_refine_loss == sh.last_refine_loss  # bitwise
    assert (sh.backend, sh.shards) == ("host", 1)
    assert (ss.backend, ss.shards) == ("sharded", 1)
    assert sum(ss.shard_frames) == ss.frames == 16
    assert ss.snapshot_h2d_bytes == 0 and sh.snapshot_h2d_bytes > 0
    # gateway hands embeddings to the sharded fleet as device arrays:
    # zero h2d payload, the full volume measured as device-to-device
    assert ss.ingest_h2d_bytes == 0
    assert gw_s.backend.ingest_d2d_bytes == ss.frames * CFG.d_embed * 4
    # session-level accounting rides the same seam
    assert gw_h.session(sids_h[0]).fill_fraction == \
        gw_s.session(sids_s[0]).fill_fraction


def test_gateway_rejects_backend_dim_mismatch(params):
    from repro.api import HostFleetBackend
    with pytest.raises(ValueError):
        StreamSplitGateway(CFG, params, policy=FixedKPolicy(L, 1),
                           backend=HostFleetBackend(
                               capacity=2, window=8, dim=CFG.d_embed + 1))


# ---------------------------------------------------------------------------
# Sharded dispatch plane (shard_dispatch=True; docs/SHARDING.md)
# ---------------------------------------------------------------------------

def test_shard_dispatch_one_shard_bitwise_matches_overlapped(params):
    """Forcing ``shard_dispatch`` on a 1-shard backend is the in-process
    bitwise-parity configuration of the sharded plane: identical
    results, identical staged bytes (the S=1 blocked layout IS the flat
    layout), the one-sync contract, and rings identical to the plain
    overlapped plane's ``insert_batch`` path."""
    from repro.api import ShardedFleetBackend

    def mk(**kw):
        return StreamSplitGateway(
            CFG, params, policy=SpreadPolicy(L), qos_reserve=0,
            backend=ShardedFleetBackend(capacity=6, window=8,
                                        dim=CFG.d_embed), **kw)

    gw_a = mk()                       # plain overlapped plane (auto-off)
    gw_b = mk(shard_dispatch=True)    # sharded plane on ONE shard
    assert not gw_a.shard_dispatch and gw_b.shard_dispatch
    rng = np.random.default_rng(3)
    sa = [gw_a.open_session().sid for _ in range(5)]
    sb = [gw_b.open_session().sid for _ in range(5)]
    for t in range(3):
        mels = [_mel(rng) for _ in range(5)]
        for gw, sids in ((gw_a, sa), (gw_b, sb)):
            for i, sid in enumerate(sids):
                gw.submit(sid, FrameRequest(t=t, mel=mels[i],
                                            label=t % N_CLASSES))
        for ra, rb in zip(gw_a.tick(), gw_b.tick()):
            np.testing.assert_array_equal(ra.z, rb.z)
            assert ra.k == rb.k and ra.wire_bytes == rb.wire_bytes
    st_a, st_b = gw_a.stats(), gw_b.stats()
    assert st_b.device_syncs_per_tick == 1
    assert st_b.d2h_copies_per_tick == 1
    assert st_b.staged_h2d_bytes == st_a.staged_h2d_bytes
    assert (st_a.dispatch_shards, st_b.dispatch_shards) == (1, 1)
    assert sum(st_b.dispatch_shard_frames) == st_b.frames == 15
    assert st_b.ingest_h2d_bytes == 0
    for xa, xb in zip(gw_a.backend.snapshot(), gw_b.backend.snapshot()):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_shard_dispatch_argument_validation(params):
    from repro.api import HostFleetBackend, ShardedFleetBackend
    with pytest.raises(ValueError, match="overlap"):
        StreamSplitGateway(
            CFG, params, policy=FixedKPolicy(L, 1), overlap=False,
            shard_dispatch=True,
            backend=ShardedFleetBackend(capacity=2, window=8,
                                        dim=CFG.d_embed))
    with pytest.raises(ValueError, match="device-resident"):
        StreamSplitGateway(
            CFG, params, policy=FixedKPolicy(L, 1), shard_dispatch=True,
            backend=HostFleetBackend(capacity=2, window=8,
                                     dim=CFG.d_embed))


def test_shard_dispatch_profile_reports_per_shard(params):
    """``tick(profile=True)`` surfaces per-shard stage timings next to
    the per-bucket split (``gateway.last_profile``) on BOTH planes —
    the single-device plane reports everything under shard 0."""
    from repro.api import ShardedFleetBackend
    rng = np.random.default_rng(5)

    def run_profiled(gw):
        sids = [gw.open_session().sid for _ in range(4)]
        assert gw.last_profile is None
        for sid in sids:
            gw.submit(sid, FrameRequest(t=0, mel=_mel(rng)))
        gw.tick(profile=True)
        return gw.last_profile

    prof = run_profiled(StreamSplitGateway(
        CFG, params, policy=SpreadPolicy(L), qos_reserve=0,
        shard_dispatch=True,
        backend=ShardedFleetBackend(capacity=4, window=8,
                                    dim=CFG.d_embed)))
    assert set(prof["per_shard"]) == {0}
    ps = prof["per_shard"][0]
    assert ps["frames"] == 4
    assert ps["chains"] == len(prof["per_bucket_ms"]) == 4
    assert set(ps["per_bucket_ms"]) == set(prof["per_bucket_ms"])
    assert all(v >= 0.0 for v in prof["per_bucket_ms"].values())
    # plain overlapped plane: same shape, shard 0 only
    prof_h = run_profiled(StreamSplitGateway(
        CFG, params, policy=SpreadPolicy(L), capacity=4, window=8,
        qos_reserve=0))
    assert set(prof_h["per_shard"]) == {0}
    assert prof_h["per_shard"][0]["frames"] == 4


_SHARDED_DISPATCH_PARITY = """
import jax, numpy as np
S = @S@
assert len(jax.devices()) == S
from repro.api import FrameRequest, ShardedFleetBackend, StreamSplitGateway
from repro.launch.mesh import make_sessions_mesh
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

CFG = AudioEncCfg(widths=(8, 8, 8, 8), strides=(1, 1, 1, 1), n_mels=8,
                  frames=8, d_embed=16, groups=2)
L = CFG.n_blocks
params = init_audio_encoder(CFG, jax.random.PRNGKey(0))

class Spread:
    def __init__(self, L):
        self.L = L
    def decide(self, obs):
        return np.arange(len(obs), dtype=np.int64) % (self.L + 1)

def mk(backend=None):
    return StreamSplitGateway(CFG, params, policy=Spread(L), capacity=8,
                              window=8, qos_reserve=0, backend=backend)

n = 7                      # != 0 mod S: uneven per-shard blocks
gw_ref = mk()              # host backend, single-device overlapped plane
gw_sh = mk(ShardedFleetBackend(capacity=8, window=8, dim=CFG.d_embed,
                               mesh=make_sessions_mesh(S)))
assert gw_sh.shard_dispatch, "shard_dispatch must auto-enable on shards>1"
rng = np.random.default_rng(0)
sr = [gw_ref.open_session().sid for _ in range(n)]
ss = [gw_sh.open_session().sid for _ in range(n)]

def feed(gw, sids, t, mels):
    for i, sid in enumerate(sids):
        gw.submit(sid, FrameRequest(t=t, mel=mels[i], label=t % 3))

# tick(): per-device chains, embeddings == the unsharded overlapped
# plane serving the same admitted order, bit for bit
for t in range(3):
    mels = [rng.normal(size=(8, 8)).astype(np.float32) for _ in range(n)]
    feed(gw_ref, sr, t, mels); feed(gw_sh, ss, t, mels)
    for rr, rs in zip(gw_ref.tick(), gw_sh.tick()):
        np.testing.assert_array_equal(rr.z, rs.z)
        assert rr.k == rs.k and rr.wire_bytes == rs.wire_bytes
st = gw_sh.stats()
assert st.device_syncs_per_tick == 1 and st.d2h_copies_per_tick == 1
assert st.dispatch_shards == S
assert sum(st.dispatch_shard_frames) == st.frames == 3 * n
assert all(f > 0 for f in st.dispatch_shard_frames)
assert st.ingest_h2d_bytes == 0   # scatter stayed shard-local

# a tick that leaves S-1 shards idle holds every contract too
m = rng.normal(size=(8, 8)).astype(np.float32)
gw_ref.submit(sr[0], FrameRequest(t=3, mel=m, label=0))
gw_sh.submit(ss[0], FrameRequest(t=3, mel=m, label=0))
np.testing.assert_array_equal(gw_ref.tick()[0].z, gw_sh.tick()[0].z)
assert gw_sh.stats().device_syncs_per_tick == 1

# interleaved tick_launch/tick_collect: the streaming runtime's
# pipelining seam — one sync per collected tick survives two plans in
# flight
mels1 = [rng.normal(size=(8, 8)).astype(np.float32) for _ in range(n)]
mels2 = [rng.normal(size=(8, 8)).astype(np.float32) for _ in range(n)]
feed(gw_sh, ss, 4, mels1)
p0 = gw_sh.tick_launch()
feed(gw_sh, ss, 5, mels2)
p1 = gw_sh.tick_launch()
r0 = gw_sh.tick_collect(p0)
r1 = gw_sh.tick_collect(p1)
assert gw_sh.stats().device_syncs_per_tick == 1
assert gw_sh.stats().d2h_copies_per_tick == 1
for t, mels, res in ((4, mels1, r0), (5, mels2, r1)):
    feed(gw_ref, sr, t, mels)
    for rr, rs in zip(gw_ref.tick(), res):
        np.testing.assert_array_equal(rr.z, rs.z)

# the placed scatter left the rings exactly as host-backend ingest did
# (admission order, not row index, is the cross-backend identity)
zh, mh, lh = (np.asarray(a) for a in gw_ref.backend.snapshot())
zd, md, ld = (np.asarray(a) for a in gw_sh.backend.snapshot())
np.testing.assert_array_equal(zh[np.array(sr)], zd[np.array(ss)])
np.testing.assert_array_equal(mh[np.array(sr)], md[np.array(ss)])
np.testing.assert_array_equal(lh[np.array(sr)], ld[np.array(ss)])

# misplacing a frame on a foreign shard's row block must raise, not
# silently scatter cross-shard
import jax.numpy as jnp
be = gw_sh.backend
zbad = jax.device_put(jnp.zeros((S, CFG.d_embed), jnp.float32),
                      be._sharding)
try:
    wrong = (int(be.shards_of(np.array([ss[0]]))[0]) + 1) % S
    be.insert_batch_placed(np.array([ss[0]]), np.array([99]), zbad, None,
                           np.array([wrong]))
    raise SystemExit("misplaced row was accepted")
except ValueError:
    pass

# per-shard profile: every shard reports its own stage timings
feed(gw_sh, ss, 6, mels1)
gw_sh.tick(profile=True)
prof = gw_sh.last_profile
assert set(prof["per_shard"]) == set(range(S))
assert sum(d["frames"] for d in prof["per_shard"].values()) == n
print("OK", S)
"""


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_dispatch_multi_device_parity(subproc, shards):
    """Tentpole contract, end to end on forced host devices: per-device
    edge→wire→server chains over the sessions axis produce embeddings
    bit-identical to the unsharded overlapped plane, with ONE device
    sync and ONE D2H per collected tick — via ``tick()`` AND through
    the interleaved launch/collect pipelining seam — shard-local ring
    ingest, and per-shard profile timings."""
    out = subproc(_SHARDED_DISPATCH_PARITY.replace("@S@", str(shards)),
                  devices=shards)
    assert out.strip().endswith(f"OK {shards}")
