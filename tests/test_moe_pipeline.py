"""MoE expert-parallel path vs dense reference + pod-axis split pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoECfg
from repro.models import moe as M


def test_reference_moe_combines_topk():
    cfg = MoECfg(n_experts=4, top_k=2, d_ff_expert=16)
    key = jax.random.PRNGKey(0)
    p, axes = M.init_moe(key, cfg, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))
    y, aux = M.moe_reference(p, cfg, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and float(aux) > 0
    # aux load-balance loss is ~1 for uniform routing, larger when skewed
    assert 0.5 < float(aux) < float(cfg.n_experts)


def test_moe_gradients_flow_to_all_parts():
    cfg = MoECfg(n_experts=4, top_k=2, d_ff_expert=16)
    p, _ = M.init_moe(jax.random.PRNGKey(0), cfg, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))

    def f(p):
        y, aux = M.moe_reference(p, cfg, x)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(f)(p)
    for name in ("router", "w_up", "w_gate", "w_down"):
        leaf = g[name]["w"] if isinstance(g[name], dict) else g[name]
        assert float(jnp.sum(jnp.abs(leaf))) > 0, name


def test_moe_ep_matches_reference(subproc):
    """shard_map all-to-all EP path == dense reference (within capacity:
    generous cap_factor so nothing drops)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoECfg
from repro.launch.mesh import make_test_mesh
from repro.distributed import sharding as shd
from repro.models import moe as M

mesh = make_test_mesh((2, 4), ('data', 'model'))
cfg = MoECfg(n_experts=8, top_k=2, d_ff_expert=16, cap_factor=8.0)
key = jax.random.PRNGKey(0)
p, axes = M.init_moe(key, cfg, 8)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8))
ref, aux_ref = M.moe_reference(p, cfg, x)
rules = shd.rules_for(mesh, type('C', (), {'n_heads': 0, 'n_kv_heads': 0,
                                           'head_dim': 0, 'ssm': None})(),
                      batch=4, kind='train')
with shd.axis_rules(rules), mesh:
    y, aux = jax.jit(lambda p, x: M.moe_ep(p, cfg, x, cap_factor=8.0))(p, x)
err = float(jnp.max(jnp.abs(np.asarray(y) - np.asarray(ref))))
print('ep vs ref max err', err, 'aux', float(aux), float(aux_ref))
assert err < 2e-4
np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
print('EP OK')
""", devices=8)


def test_moe_ep_capacity_drops_degrade_gracefully(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.configs.base import MoECfg
from repro.launch.mesh import make_test_mesh
from repro.distributed import sharding as shd
from repro.models import moe as M
mesh = make_test_mesh((1, 4), ('data', 'model'))
cfg = MoECfg(n_experts=4, top_k=2, d_ff_expert=16, cap_factor=0.5)
p, _ = M.init_moe(jax.random.PRNGKey(0), cfg, 8)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
rules = shd.rules_for(mesh, type('C', (), {'n_heads': 0, 'n_kv_heads': 0,
                                           'head_dim': 0, 'ssm': None})(),
                      batch=2, kind='train')
with shd.axis_rules(rules), mesh:
    y, aux = jax.jit(lambda p, x: M.moe_ep(p, cfg, x, cap_factor=0.5))(p, x)
assert jnp.isfinite(y).all()  # dropped tokens pass through as zeros
print('capacity-drop OK')
""", devices=4)


def test_split_pipeline_podwise_matches_sequential(subproc):
    """2-stage pod pipeline (collective_permute, fp32 wire) == sequential
    stage application."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.core.splitter import split_pipeline_podwise
mesh = make_test_mesh((2, 2), ('pod', 'data'))
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (2, 16, 16)) * 0.3   # (stage, d, d)
def stage_fn(w, h):
    return jnp.tanh(h @ w)
M, mb, d = 3, 4, 16
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
out = split_pipeline_podwise(mesh, stage_fn, W, x, quantize_wire=False,
                             batch_axes='data')
want = jnp.tanh(jnp.tanh(x @ W[0]) @ W[1])
err = float(jnp.max(jnp.abs(out - want)))
print('pipeline err', err)
assert err < 1e-5
print('pipeline OK')
""", devices=4)


def test_split_pipeline_int8_wire(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.core.splitter import split_pipeline_podwise
mesh = make_test_mesh((2, 2), ('pod', 'data'))
W = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16)) * 0.3
def stage_fn(w, h):
    return jnp.tanh(h @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 16))
out = split_pipeline_podwise(mesh, stage_fn, W, x, quantize_wire=True,
                             batch_axes='data')
want = jnp.tanh(jnp.tanh(x @ W[0]) @ W[1])
rel = float(jnp.max(jnp.abs(out - want)))
print('int8 wire err', rel)
assert rel < 0.05   # INT8 quantization noise only
print('pipeline-int8 OK')
""", devices=4)
