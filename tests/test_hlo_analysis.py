"""Trip-count-aware HLO cost model: unit parses + live compile checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as HA

SAMPLE = """
HloModule test

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256] get-tuple-element(%p), index=1
  %w = f32[256,256]{1,0} constant(0)
  %d = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%d), to_apply=%add_comp
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256] parameter(0)
  %c = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%c, %x)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %o = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_sample_module_trip_counts():
    r = HA.analyze(SAMPLE)
    # dot: 2*128*256*256 flops, once per trip (12)
    assert r["flops"] == pytest.approx(12 * 2 * 128 * 256 * 256)
    assert r["collective_bytes"] == pytest.approx(12 * 128 * 256 * 4)
    assert r["per_kind_counts"] == {"all-reduce": 1}


def test_shape_bytes_tuple():
    assert HA.shape_bytes("(s32[], bf16[8,4]{1,0}, f32[2,2])") == \
        4 + 8 * 4 * 2 + 4 * 4


def test_live_layer_scaling():
    """FLOPs scale ~linearly with scanned layer count on a real compile."""
    from dataclasses import replace
    from repro.configs.base import get_config, smoke_config
    from repro.models import lm
    flops = {}
    for L in (2, 4):
        cfg = replace(smoke_config(get_config("qwen1.5-0.5b")), n_layers=L,
                      remat=False)
        shapes = jax.eval_shape(lambda k: lm.init_lm(cfg, k)[0],
                                jax.random.PRNGKey(0))
        toks = jax.ShapeDtypeStruct((4, 64), jnp.int32)

        def f(p, t):
            h, _ = lm.forward(cfg, p, tokens=t)
            return h.sum()

        comp = jax.jit(f).lower(shapes, toks).compile()
        flops[L] = HA.analyze(comp.as_text())["flops"]
    ratio = flops[4] / flops[2]
    assert 1.7 < ratio < 2.3, ratio


def test_dus_traffic_counts_update_window_only():
    """The decode KV-cache write must not count the whole cache."""
    def f(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 5))

    cache = jax.ShapeDtypeStruct((64, 1024), jnp.float32)
    new = jax.ShapeDtypeStruct((64, 1), jnp.float32)
    comp = jax.jit(f, donate_argnums=(0,)).lower(cache, new).compile()
    r = HA.analyze(comp.as_text())
    # traffic should be ~2x the 64x1 update, far below the 256KB cache
    assert r["hbm_bytes"] < 64 * 1024 * 4 / 4
