"""Arch-aware TP rules: head-divisibility fallbacks, FSDP, decode caches."""
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed.sharding import rules_for
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def mesh16():
    # single-device fake 16-way mesh is fine for spec computation
    import numpy as np
    dev = jax.devices()[0]
    arr = np.array([dev] * 256).reshape(16, 16)
    return jax.sharding.Mesh(arr, ("data", "model"))


def test_divisible_heads_column_parallel(mesh16):
    cfg = get_config("qwen3-1.7b")       # 16 q heads, 8 kv heads
    r = rules_for(mesh16, cfg, batch=256, kind="train")
    assert r.param_rules["heads"] == "model"      # 16 % 16 == 0
    assert r.param_rules["kv_heads"] is None      # 8 % 16 != 0
    assert r.param_rules["kv_in"] == "model"      # row-parallel fallback
    assert r.act_rules["kv_seq"] == "model"       # decode cache seq-sharded


def test_indivisible_heads_row_parallel(mesh16):
    cfg = get_config("arctic-480b")       # 56 heads
    r = rules_for(mesh16, cfg, batch=256, kind="train", fsdp=True)
    assert r.param_rules["heads"] is None
    assert r.param_rules["q_in"] == "model"
    assert r.param_rules["o_hd"] == "model"
    assert r.param_rules["embed"] == "data"       # FSDP
    assert r.param_rules["q_hd"] == "data"        # head_dim 128 % 16 == 0


def test_mha_fully_sharded(mesh16):
    cfg = get_config("musicgen-large")    # 32/32 heads
    r = rules_for(mesh16, cfg, batch=128, kind="decode")
    assert r.param_rules["heads"] == "model"
    assert r.param_rules["kv_heads"] == "model"
    assert r.act_rules["kv_seq"] is None          # kv-head sharding suffices
    assert r.act_rules["batch"] == "data"


def test_batch_one_leaves_batch_unsharded(mesh16):
    cfg = get_config("zamba2-1.2b")
    r = rules_for(mesh16, cfg, batch=1, kind="decode")
    assert r.act_rules["batch"] is None
    assert r.act_rules["kv_seq"] == "data"        # 500k cache seq over data
    assert r.act_rules["seq"] == "data"


def test_spec_lookup_roundtrip(mesh16):
    cfg = get_config("kimi-k2-1t-a32b")
    r = rules_for(mesh16, cfg, batch=256, kind="train", fsdp=True)
    spec = r.spec(("experts", "embed", "expert_mlp"), kind="param")
    assert spec == P("model", "data", None)
    spec = r.spec(("batch", "seq"), kind="act")
    assert spec == P("data", None)
