"""Unified telemetry plane (repro.obs): the quantile sketch's two
regimes pinned against ``numpy.percentile``, registry typing and
views, deterministic per-frame span tracing across pipelined ticks /
live migration / journal replay, the zero-allocation tracing-off fast
path, flight-recorder exactness under eviction, exporter schema
validation, and the stats-view bit-parity + conservation contracts.
"""
import json

import jax
import numpy as np
import pytest

from repro.api import FrameRequest, QoSClass
from repro.cluster import FailureInjector, GatewayCluster
from repro.obs import (Counter, FlightRecorder, Gauge, Histogram,
                       MetricsRegistry, QuantileSketch, Tracer,
                       registry_snapshot, sampled, to_prometheus,
                       validate_prometheus, write_jsonl)
from repro.runtime.metrics import MetricsLogger
from repro.serving import (QoSQueues, SchedulerCfg, StreamServer,
                           TickScheduler)

from test_cluster import (FakeClock, _assert_conserved, _gw, _req,
                          _server)

I, S, B = QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK
ALL = ("interactive", "standard", "bulk")


@pytest.fixture(scope="module")
def params():
    from repro.models.audio_encoder import init_audio_encoder
    from test_cluster import CFG
    return init_audio_encoder(CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# QuantileSketch: exact regime is numpy, binned regime is bounded
# ---------------------------------------------------------------------------

def test_sketch_exact_regime_bit_identical_to_numpy():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=3.0, sigma=1.2, size=1000)
    sk = QuantileSketch(exact_cap=4096)
    for x in xs:
        sk.observe(x)
    assert sk.exact
    for q in (0, 10, 50, 90, 95, 99, 100):
        assert sk.quantile(q) == float(np.percentile(xs, q))   # bitwise
    assert sk.summary()["max"] == float(xs.max())
    assert sk.count == 1000 and sk.total == pytest.approx(xs.sum())


def test_sketch_insertion_order_never_matters():
    rng = np.random.default_rng(8)
    xs = rng.exponential(50.0, size=500)
    a, b = QuantileSketch(exact_cap=100), QuantileSketch(exact_cap=100)
    for x in xs:
        a.observe(x)
    for x in xs[::-1]:
        b.observe(x)
    assert not a.exact and not b.exact       # both in the binned regime
    for q in (50, 95, 99):
        assert a.quantile(q) == b.quantile(q)
    assert (a.vmin, a.vmax, a.count) == (b.vmin, b.vmax, b.count)


def test_sketch_binned_regime_error_bounded_by_growth():
    """Past ``exact_cap`` quantiles come from growth-ratio log bins:
    relative error stays under the bin ratio on seeded heavy-tailed
    data, and min/max/count/sum stay EXACT."""
    rng = np.random.default_rng(9)
    xs = rng.lognormal(mean=2.0, sigma=1.0, size=20_000)
    sk = QuantileSketch(exact_cap=64, growth=1.1)
    for x in xs:
        sk.observe(x)
    assert not sk.exact
    for q in (50, 90, 95, 99):
        ref = float(np.percentile(xs, q))
        assert abs(sk.quantile(q) - ref) / ref < 0.1    # ~growth - 1
    assert sk.vmin == xs.min() and sk.vmax == xs.max()
    assert sk.count == len(xs)
    assert sk.total == pytest.approx(xs.sum())


def test_sketch_single_sample_and_empty():
    sk = QuantileSketch()
    assert sk.summary() == {"p50": 0.0, "p95": 0.0, "mean": 0.0,
                            "max": 0.0}
    sk.observe(250.0)
    s = sk.summary()
    assert s["p50"] == s["p95"] == s["max"] == 250.0


# ---------------------------------------------------------------------------
# Registry: typed get-or-create, label keying, views
# ---------------------------------------------------------------------------

def test_registry_get_or_create_idempotent_and_typed():
    r = MetricsRegistry()
    c = r.counter("x_total", qos="bulk")
    assert r.counter("x_total", qos="bulk") is c
    assert r.counter("x_total", qos="interactive") is not c
    with pytest.raises(ValueError):
        r.gauge("x_total", qos="bulk")       # same name, wrong type
    with pytest.raises(ValueError):
        r.histogram("x_total", qos="bulk")
    assert r.value("x_total", qos="bulk") == 0
    assert r.value("never_created") == 0     # view convention
    c.inc(3)
    assert r.value("x_total", qos="bulk") == 3
    assert len(r) == 2


def test_counter_accepts_negative_inc_for_ledger_relocation():
    c = Counter("moved", ())
    c.inc(5)
    c.inc(-2)                                # migration withdraws frames
    assert c.value == 3


def test_gauge_ewma_first_sample_seeds():
    g = Gauge("lat", ())
    assert g.ewma(10.0) == 10.0              # no zero-pull warmup
    v = g.ewma(20.0, alpha=0.5)
    assert v == 15.0
    g.try_set_max(12.0)
    assert g.value == 15.0
    g.try_set_max(99.0)
    assert g.value == 99.0


def test_histogram_through_registry():
    r = MetricsRegistry()
    h = r.histogram("wait_ms", qos="bulk")
    assert isinstance(h, Histogram)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4
    assert h.summary()["max"] == 4.0
    assert h.quantile(50) == float(np.percentile([1, 2, 3, 4], 50))


# ---------------------------------------------------------------------------
# Sampling: deterministic, member/replay-stable
# ---------------------------------------------------------------------------

def test_sampled_is_deterministic_and_edge_exact():
    for sid in range(20):
        for t in range(20):
            assert sampled(sid, t, 1.0) is True
            assert sampled(sid, t, 0.0) is False
            assert sampled(sid, t, 0.5) == sampled(sid, t, 0.5)
    hits = sum(sampled(sid, t, 0.25)
               for sid in range(50) for t in range(50))
    assert 0.15 < hits / 2500 < 0.35         # hash is roughly uniform


def test_tracer_off_allocates_nothing():
    tr = Tracer(0.0)
    assert not tr.enabled
    assert tr.maybe_begin(1, 2) is None and tr.started == 0
    tr.finish(None)                          # no-ops on None
    tr.retire(None)
    assert tr.finished == 0


# ---------------------------------------------------------------------------
# FlightRecorder: rings evict, counts never do
# ---------------------------------------------------------------------------

def test_recorder_counts_exact_under_ring_eviction():
    rec = FlightRecorder(event_capacity=4, clock=lambda: 1.5)
    for i in range(10):
        rec.record("shed", sid=0, t=i)
    rec.record("failover", member="a")
    assert rec.counts() == {"shed": 10, "failover": 1}
    assert len(rec.events()) == 4            # ring is bounded
    assert len(rec.events("shed")) == 3
    d = rec.dump(reason="test")
    assert d["reason"] == "test" and d["t_s"] == 1.5
    assert d["counts"]["shed"] == 10         # exact despite eviction
    assert d["evicted_events"] == 7
    json.dumps(d)                            # dump is JSON-able


# ---------------------------------------------------------------------------
# Exporters: Prometheus text format + JSONL snapshots
# ---------------------------------------------------------------------------

def _loaded_registry():
    r = MetricsRegistry()
    r.counter("stream_frames_served", qos="bulk").inc(7)
    r.counter("stream_frames_served", qos="interactive").inc(2)
    r.gauge("gateway_stage_ewma_ms", stage="tick").set(1.25)
    h = r.histogram("stream_queue_wait_ms", qos="bulk")
    for v in (10.0, 20.0, 400.0):
        h.observe(v)
    return r


def test_prometheus_export_validates_and_round_trips():
    text = to_prometheus(_loaded_registry())
    n = validate_prometheus(text)            # raises on any violation
    assert n >= 8                            # 2 counters, 1 gauge, summary
    assert 'stream_frames_served{qos="bulk"} 7' in text
    assert 'quantile="0.95"' in text
    assert "stream_queue_wait_ms_count" in text
    assert "stream_queue_wait_ms_max" in text


def test_prometheus_validator_rejects_garbage():
    with pytest.raises(ValueError):
        validate_prometheus("9bad_name 1\n")
    with pytest.raises(ValueError):
        validate_prometheus('ok{label="x"} notanumber\n')
    with pytest.raises(ValueError):          # duplicate series
        validate_prometheus("# TYPE a counter\na 1\na 2\n")


def test_jsonl_snapshot_appends_parseable_lines(tmp_path):
    p = tmp_path / "metrics.jsonl"
    r = _loaded_registry()
    write_jsonl(r, p, step=0, clock=lambda: 5.0)
    write_jsonl(r, p, step=1, clock=lambda: 6.0)
    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert [x["step"] for x in lines] == [0, 1]
    snap = registry_snapshot(r, clock=lambda: 5.0)
    names = {m["name"] for m in snap["metrics"]}
    assert "stream_queue_wait_ms" in names and snap["t_s"] == 5.0


# ---------------------------------------------------------------------------
# MetricsLogger (runtime/metrics.py): the satellite fix
# ---------------------------------------------------------------------------

def test_metrics_logger_context_manager_clock_and_mean(tmp_path):
    p = tmp_path / "train.jsonl"
    with MetricsLogger(str(p), window=2, clock=lambda: 9.0) as m:
        m.log(0, loss=4.0)
        m.log(1, loss=2.0)
        m.log(2, loss=1.0)
        assert m.mean("loss") == 1.5         # rolling window of 2
        assert np.isnan(m.mean("nope"))      # lookups do not pollute
        assert "nope" not in m.buf
    assert m._f is None                      # closed by __exit__
    rows = [json.loads(x) for x in p.read_text().splitlines()]
    assert len(rows) == 3 and all(r["t"] == 9.0 for r in rows)


# ---------------------------------------------------------------------------
# Serving integration: spans across pipelined ticks (fake clock)
# ---------------------------------------------------------------------------

# submit -> enqueue -> [promote] -> stage -> admit -> dispatch ->
# collect -> serve; shed terminates, migrate hops continue
_ORDER = ("submit", "enqueue", "stage", "admit", "dispatch", "collect",
          "serve")


def _assert_span_order(trace):
    names = [n for n in trace.names() if n in _ORDER]
    assert names == [n for n in _ORDER if n in names]
    stamps = [e[1] for e in trace.events]
    assert stamps == sorted(stamps)          # clock-monotone


def test_trace_spans_ordered_across_pipelined_ticks(params):
    clock = FakeClock()
    srv = _server(params, clock, max_batch=4, trace_sample=1.0)
    sids = [srv.open_session(qos=q).sid for q in (I, S, B)]
    n = 0
    for t in range(4):
        for sid in sids:
            srv.submit(sid, _req(sid, t))
            n += 1
        clock.advance(0.01)
        srv.step()
    while srv.busy():
        clock.advance(0.01)
        srv.step()
    st = srv.stats()
    assert st.pipelined_ticks > 0            # the overlap really happened
    traces = srv.recorder.traces()
    assert len(traces) == n                  # sample=1.0: all retired
    assert srv.tracer.started == srv.tracer.finished == n
    for tr in traces:
        _assert_span_order(tr)
        assert tr.find("submit") is not None
        assert tr.find("serve") is not None
        d = tr.find("dispatch")
        assert d is not None and "k" in d[2] and "shard" in d[2]
    # deterministic span math on the fake clock: submit -> serve is a
    # whole number of 10ms steps
    ms = traces[0].span_ms("submit", "serve")
    assert ms == pytest.approx(round(ms / 10) * 10, abs=1e-6)


def test_trace_sampling_subset_matches_hash(params):
    clock = FakeClock()
    srv = _server(params, clock, max_batch=8, trace_sample=0.5)
    sid = srv.open_session(qos=S).sid
    want = set()
    for t in range(20):
        srv.submit(sid, _req(sid, t))
        if sampled(sid, t, 0.5):
            want.add(t)
        clock.advance(0.01)
        srv.step()
    while srv.busy():
        clock.advance(0.01)
        srv.step()
    got = {tr.t for tr in srv.recorder.traces()}
    assert got == want and 0 < len(got) < 20


def test_tracing_off_is_the_zero_allocation_path(params):
    clock = FakeClock()
    srv = _server(params, clock, max_batch=4)      # default: off
    sid = srv.open_session(qos=S).sid
    for t in range(3):
        srv.submit(sid, _req(sid, t))
        clock.advance(0.01)
        srv.step()
    while srv.busy():
        srv.step()
    assert srv.tracer.started == 0 and srv.recorder.traces() == []
    with srv.queues.cond:                    # nothing carries a trace
        assert all(qf.trace is None
                   for cq in srv.queues.by_class.values()
                   for qf in cq.q)
    assert srv.served_total == 3             # and serving still works


def test_trace_shed_terminates_span_into_recorder(params):
    clock = FakeClock()
    srv = StreamServer(
        _gw(params, clock, capacity=2),
        cfg=SchedulerCfg(max_batch=2, deadline_ms={B: 100.0},
                         shed_horizon_ms=200.0, max_wait_ms={B: None}),
        clock=clock, trace_sample=1.0)
    sid = srv.open_session(qos=B).sid
    for t in range(6):
        srv.submit(sid, _req(sid, t))
    srv.step()                               # admits 2, stages 2
    clock.t = 10.0
    srv.step()                               # sheds the 2 queued frames
    while srv.busy():
        srv.step()
    shed_traces = [tr for tr in srv.recorder.traces()
                   if tr.find("shed") is not None]
    assert len(shed_traces) == 2
    for tr in shed_traces:
        assert tr.find("serve") is None      # shed IS the terminal
        assert tr.events[-1][0] == "shed"
    # the recorder's anomaly ledger agrees with the stats view, exactly
    st = srv.stats()
    assert srv.recorder.counts()["shed"] == st.shed_expired["bulk"] == 2
    ev = srv.recorder.events("shed")[0]
    assert ev["sid"] == sid and "waited_ms" in ev


# ---------------------------------------------------------------------------
# Migration + journal replay: trace continuity
# ---------------------------------------------------------------------------

def test_trace_survives_live_migration_with_original_submit(params):
    clock = FakeClock(t=1.0)
    src = _server(params, clock, max_batch=4, trace_sample=1.0)
    dst = _server(params, clock, max_batch=4, trace_sample=1.0)
    sid = src.open_session(qos=S).sid
    for t in range(3):
        src.submit(sid, _req(sid, t))       # queued, never stepped
    clock.advance(0.5)
    snap = src.export_session(sid)
    assert all(s.trace is not None for s in snap.server.queued)
    info = dst.import_session(snap)
    while dst.busy():
        clock.advance(0.01)
        dst.step()
    traces = dst.recorder.traces()
    assert len(traces) == 3
    for tr in traces:
        names = tr.names()
        for hop in ("submit", "enqueue", "migrate_out", "migrate_in",
                    "serve"):
            assert hop in names, (hop, names)
        assert names.index("migrate_out") < names.index("migrate_in")
        assert tr.find("submit")[1] == 1.0   # ORIGINAL submit stamp
        assert tr.find("migrate_out")[1] == 1.5
        assert tr.find("migrate_in")[2]["sid"] == info.sid
    # src retired nothing: the spans moved, they did not end there
    assert src.recorder.traces() == []


def test_cluster_failover_dump_and_replay_trace_adoption(params):
    """Seeded overload + member kill: the automatic flight-recorder
    dump reconstructs the failover/failure counts exactly, and frames
    recovered by journal replay carry adopted traces that begin at the
    ``replay`` hop with their ORIGINAL enqueue timestamp."""
    clock = FakeClock()
    members = {"a": _server(params, clock, max_batch=4,
                            trace_sample=1.0),
               "b": _server(params, clock, max_batch=4,
                            trace_sample=1.0)}
    cl = GatewayCluster(members, seed=3, snapshot_every=2,
                        replicate=True, journal_flush_every=1,
                        injectors={"a": FailureInjector(fail_at=(6,))},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    for t in range(10):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())
    cl.pump()
    st = cl.stats()
    assert st.failures == 1 and st.failovers > 0
    # -- the acceptance contract: dump == books, exactly ------------------
    dump = cl.dump_trace()
    assert dump["counts"]["failover"] == st.failovers
    assert dump["counts"]["member_failed"] == st.failures
    assert dump["counts"].get("journal_replay", 0) > 0
    auto = cl.failover_dumps
    assert len(auto) == 1 and auto[0]["reason"] == "member_failed:a"
    assert auto[0]["counts"]["member_failed"] == 1
    # every failover event names source and destination
    for ev in cl.recorder.events("failover"):
        assert ev["src"] == "a" and ev["dst"] == "b"
    # -- replayed frames: adopted spans, original enqueue ----------------
    replayed = [tr for tr in members["b"].recorder.traces()
                if tr.names() and tr.names()[0] == "replay"]
    assert len(replayed) == st.replayed_frames > 0
    for tr in replayed:
        assert tr.events[0][2]["member"] == "b"
        assert "enq_s" in tr.events[0][2]    # the original ledger
        assert tr.names()[-1] == "serve"     # recovered AND served
    # cluster books and prometheus export agree
    text = cl.metrics()
    validate_prometheus(text)
    assert f"cluster_failovers {st.failovers}" in text


# ---------------------------------------------------------------------------
# Stats views: bit-parity, conservation, EWMA stage timings, signals
# ---------------------------------------------------------------------------

def _run_workload(params, seed=0):
    clock = FakeClock()
    srv = _server(params, clock, max_batch=4)
    sids = [srv.open_session(qos=q).sid for q in (I, S, B)]
    for t in range(6):
        for sid in sids:
            srv.submit(sid, _req(sid, t))
        clock.advance(0.01)
        srv.step()
    while srv.busy():
        clock.advance(0.01)
        srv.step()
    return srv


def test_stats_views_bit_reproducible_and_conserved(params):
    a, b = _run_workload(params), _run_workload(params)
    sa, sb = a.stats(), b.stats()
    # registry-backed views are plain dicts, equal across reruns
    assert sa.frames_submitted == sb.frames_submitted
    assert sa.frames_served == sb.frames_served
    assert dict(sa.deadline_misses) == dict(sb.deadline_misses)
    assert sa.queue_wait_ms == sb.queue_wait_ms       # sketch: exact
    for c in ALL:                                     # conservation
        assert sa.frames_submitted[c] == (
            sa.frames_served[c] + sa.queue_depth[c] + sa.in_flight[c]
            + sa.shed_expired[c])
    # wait percentiles really are numpy.percentile in the exact regime
    h = a.scheduler.wait_hist["standard"]
    assert h.sketch.exact
    assert sa.queue_wait_ms["standard"]["p95"] == h.quantile(95)


def test_stage_ewma_always_on_without_profile(params):
    srv = _run_workload(params)
    R = srv.registry
    for stage in ("launch", "collect", "tick"):
        assert R.value("gateway_stage_ewma_ms", stage=stage) >= 0.0
        assert R.get("gateway_stage_ewma_ms", stage=stage) is not None
    # the profile knob is a debug detail now, not the only timing source
    assert srv.gateway.last_profile is None


def test_server_metrics_export_and_resource_signals(params):
    srv = _run_workload(params)
    text = srv.metrics()
    validate_prometheus(text)
    assert "stream_frames_served" in text
    assert "gateway_frames_total" in text or "gateway_" in text
    sig = srv.resource_signals()
    assert sig.queue_depth == 0              # fully drained
    obs = sig.as_observation()
    assert obs.shape == (5,) and obs.dtype == np.float32
    assert np.all(obs >= 0.0) and np.all(obs <= 1.0)
    assert sig.throughput_fps > 0.0
    st = srv.stats()
    assert sig.wait_p95_ms == max(
        w["p95"] for w in st.queue_wait_ms.values())


def test_scheduler_wait_sketch_matches_numpy_on_known_waits():
    """Satellite (b): the per-class wait-sample lists are gone; the
    sketch behind ``wait_percentiles`` reproduces ``numpy.percentile``
    exactly for deterministic fake-clock waits."""
    qs = QoSQueues(maxlen=64)
    sched = TickScheduler(SchedulerCfg(max_batch=64,
                                       max_wait_ms={B: None}))
    f = FrameRequest(t=0, mel=np.zeros((2, 2), np.float32))
    waits = [10.0, 20.0, 40.0, 80.0, 160.0]
    for i, w in enumerate(waits):
        qs.submit(i, f, B, now=1.0 - w * 1e-3, deadline_s=99.0)
    sched.stage(qs)
    batch = sched.admit(qs, 1.0)
    assert len(batch) == len(waits)
    got = sched.wait_percentiles()["bulk"]
    # the expectation reproduces the scheduler's own float arithmetic
    # ((now - enq_s) * 1e3) — bit-identity, not approximation
    arr = np.asarray([(1.0 - (1.0 - w * 1e-3)) * 1e3 for w in waits])
    assert got["p50"] == float(np.percentile(arr, 50))
    assert got["p95"] == float(np.percentile(arr, 95))
    assert got["max"] == float(arr.max())
    assert got["mean"] == pytest.approx(arr.mean())
