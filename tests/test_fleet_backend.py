"""FleetBackend seam: Host vs Sharded data-plane parity.

The contracts the refactor is allowed to rely on:

- 1-shard ``ShardedFleetBackend`` refine == ``HostFleetBackend`` refine
  **bitwise** (losses, parts, per-session losses, updated head params,
  distributional memory);
- device-resident ingest/refine moves no fleet snapshot over the host
  boundary (``snapshot_h2d_bytes`` stays 0);
- multi-shard (forced host devices, subprocess) refine matches the
  unsharded estimator to fp32 tolerance — pmean'd SWD/loss aggregation,
  psum'd GMM sufficient statistics;
- ``FleetBuffer.insert_batch`` accepts ``jax.Array`` inputs (no silent
  double-conversion path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import (FleetBuffer, FleetFullError, HostFleetBackend,
                              ShardedFleetBackend, T_SENTINEL_DEV,
                              make_backend)
from repro.core import gmm

DIM, N_CLASSES = 8, 4


def _head():
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (DIM, N_CLASSES))}

    def head_apply(p, z):
        return z @ p["w"]

    return head_init, head_apply


def _build(cls, *, capacity=4, window=12, n_components=0, seed=0):
    head_init, head_apply = _head()
    b = cls(capacity=capacity, window=window, dim=DIM, head_init=head_init,
            head_apply=head_apply, lr=0.1, seed=seed,
            n_components=n_components)
    rng = np.random.default_rng(0)
    sids = [b.admit() for _ in range(min(3, capacity))]
    for t in range(15):
        for sid in sids:
            if (t + sid) % 5 == 2:          # per-session drops -> gaps
                continue
            b.insert(sid, t, rng.normal(size=DIM).astype(np.float32),
                     label=t % N_CLASSES)
    b.evict(sids[1])
    s2 = b.admit()                          # re-admit onto the dirty row
    b.insert(s2, 0, np.ones(DIM, np.float32), label=1)
    return b


# ---------------------------------------------------------------------------
# 1-device bitwise parity (the acceptance contract)
# ---------------------------------------------------------------------------

def test_sharded_refine_bitwise_matches_host_on_one_device():
    host = _build(HostFleetBackend, n_components=6)
    shrd = _build(ShardedFleetBackend, n_components=6)
    assert shrd.shards == 1 and shrd.kind == "sharded"
    zh, mh, lh = host.snapshot()
    zs, ms, ls = shrd.snapshot()
    np.testing.assert_array_equal(zh, zs)
    np.testing.assert_array_equal(mh, ms)
    np.testing.assert_array_equal(lh, ls)
    for i in range(3):
        key = jax.random.PRNGKey(i)
        loss_h, parts_h, per_h = host.refine(key)
        loss_s, parts_s, per_s = shrd.refine(key)
        assert loss_s == loss_h, f"round {i} loss not bitwise identical"
        assert parts_s == parts_h
        np.testing.assert_array_equal(per_s, per_h)
    for a, b in zip(jax.tree.leaves(host.refiner.state.params),
                    jax.tree.leaves(shrd.refiner.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(host.memory),
                    jax.tree.leaves(shrd.memory)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_device_resident_refine_copies_no_snapshot():
    """The point of the sharded backend: N refine rounds move 0 snapshot
    bytes host->device, while the host backend pays (N, W, d) + masks
    per round."""
    host = _build(HostFleetBackend)
    shrd = _build(ShardedFleetBackend)
    for i in range(3):
        host.refine(jax.random.PRNGKey(i))
        shrd.refine(jax.random.PRNGKey(i))
    per_round = (host.capacity * host.window * (host.dim * 4 + 4 + 8)
                 + host.capacity)          # z f32 + mask f32 + labels i64
    assert host.snapshot_h2d_bytes == 3 * per_round
    assert shrd.snapshot_h2d_bytes == 0


# ---------------------------------------------------------------------------
# Sharded backend: FleetBuffer admission/ring semantics on device
# ---------------------------------------------------------------------------

def test_sharded_admission_eviction_and_lazy_wipe():
    b = ShardedFleetBackend(capacity=2, window=5, dim=DIM)
    sid = b.admit()
    rng = np.random.default_rng(0)
    for t in range(5):
        b.insert(sid, t, rng.normal(size=DIM).astype(np.float32), label=t % 2)
    b.evict(sid)
    assert b.n_active == 0
    # lazy: device bytes not wiped at evict time ...
    assert (np.asarray(b.z[sid]) != 0.0).any()
    # ... but the snapshot masks the evicted row completely
    z, mask, labels = b.snapshot()
    assert mask[sid].sum() == 0 and (z[sid] == 0).all() \
        and (labels[sid] == -1).all()
    with pytest.raises(KeyError):
        b.insert(sid, 6, np.ones(DIM))
    with pytest.raises(KeyError):
        b.evict(sid)
    # re-admission hands out a clean row (deferred wipe on device)
    sid2 = b.admit()
    assert sid2 == sid
    assert (np.asarray(b.z[sid2]) == 0.0).all()
    assert (np.asarray(b.t[sid2]) == T_SENTINEL_DEV).all()
    assert b.fill_fraction(sid2) == 0.0
    b.admit()
    with pytest.raises(FleetFullError):
        b.admit()


def test_sharded_rows_match_host_buffer_rows():
    """Ring semantics (wraparound, gaps, expiry, fill fraction) match the
    host FleetBuffer for identical insert histories."""
    buf = FleetBuffer(capacity=3, window=6, dim=2)
    dev = ShardedFleetBackend(capacity=3, window=6, dim=2)
    sids = [buf.admit() for _ in range(3)]
    [dev.admit() for _ in range(3)]
    rng = np.random.default_rng(1)
    for t in range(20):
        for sid in sids:
            if rng.random() < 0.3:
                continue
            z = rng.normal(size=2).astype(np.float32)
            buf.insert(sid, t + sid, z, label=t % 3)
            dev.insert(sid, t + sid, z, label=t % 3)
    zh, mh, lh = buf.snapshot()
    zd, md, ld = dev.snapshot()
    np.testing.assert_array_equal(zh, zd)
    np.testing.assert_array_equal(mh, md)
    np.testing.assert_array_equal(lh, ld)
    for sid in sids:
        assert buf.fill_fraction(sid) == pytest.approx(
            dev.fill_fraction(sid))


def test_sharded_capacity_must_divide_shards():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1), ("sessions",))
    ShardedFleetBackend(capacity=3, window=4, dim=2, mesh=mesh)  # 3 % 1 ok
    big = jax.sharding.Mesh(
        np.array([jax.devices()[0]] * 2).reshape(2), ("sessions",)) \
        if len(jax.devices()) >= 2 else None
    if big is not None:
        with pytest.raises(ValueError):
            ShardedFleetBackend(capacity=3, window=4, dim=2, mesh=big)


def test_make_backend_factory():
    assert make_backend("host", capacity=2, window=4, dim=2).kind == "host"
    assert make_backend("sharded", capacity=2, window=4,
                        dim=2).kind == "sharded"
    with pytest.raises(ValueError):
        make_backend("nope")


# ---------------------------------------------------------------------------
# Satellite: jax.Array ingest without a host round-trip / double copy
# ---------------------------------------------------------------------------

def test_fleet_buffer_insert_batch_accepts_jax_arrays():
    f_np, f_jx = (FleetBuffer(capacity=4, window=5, dim=3) for _ in range(2))
    for f in (f_np, f_jx):
        for _ in range(4):
            f.admit()
    rng = np.random.default_rng(2)
    sids, ts = np.array([0, 2, 3]), np.array([7, 1, 4])
    zs = rng.normal(size=(3, 3)).astype(np.float32)
    labs = np.array([1, -1, 0])
    f_np.insert_batch(sids, ts, zs, labs)
    f_jx.insert_batch(jnp.asarray(sids), jnp.asarray(ts), jnp.asarray(zs),
                      jnp.asarray(labs))
    np.testing.assert_array_equal(f_np.z, f_jx.z)
    np.testing.assert_array_equal(f_np.t, f_jx.t)
    np.testing.assert_array_equal(f_np.label, f_jx.label)
    np.testing.assert_array_equal(f_np.newest, f_jx.newest)


def test_sharded_insert_batch_device_arrays_move_no_payload():
    b = ShardedFleetBackend(capacity=2, window=4, dim=DIM)
    assert b.device_ingest
    b.admit()
    b.admit()
    z_dev = jnp.ones((2, DIM), jnp.float32)     # already device-resident
    b.insert_batch(np.array([0, 1]), np.array([0, 0]), z_dev)
    assert b.ingest_h2d_bytes == 0              # payload stayed on device
    b.insert_batch(np.array([0, 1]), np.array([1, 1]),
                   np.ones((2, DIM), np.float32))
    assert b.ingest_h2d_bytes == 2 * DIM * 4    # host payload counted


def test_sharded_duplicate_slot_writes_are_last_wins_like_host():
    """jnp scatter with repeated indices is undefined — the sharded
    backend must fold duplicate (sid, slot) writes to numpy's last-wins
    before dispatch, with ``newest`` still seeing the max timestamp."""
    host = FleetBuffer(capacity=2, window=4, dim=2)
    dev = ShardedFleetBackend(capacity=2, window=4, dim=2)
    for b in (host, dev):
        b.admit()
        b.admit()
    # same slot twice for sid 0 (t=1 and t=5 both hit slot 1, out of
    # order so the kept ring value and the max timestamp differ), plus a
    # normal write to sid 1
    sids = np.array([0, 1, 0])
    ts = np.array([5, 2, 1])
    zs = np.array([[5., 5.], [2., 2.], [1., 1.]], np.float32)
    labs = np.array([5, 2, 1])
    host.insert_batch(sids, ts, zs, labs)
    dev.insert_batch(sids, ts, zs, labs)
    np.testing.assert_array_equal(np.asarray(dev.z[0, 1]), host.z[0, 1])
    assert int(dev.t[0, 1]) == host.t[0, 1] == 1      # last write wins
    assert int(dev.newest[0]) == host.newest[0] == 5  # max t still seen
    zh, mh, lh = host.snapshot()
    zd, md, ld = dev.snapshot()
    np.testing.assert_array_equal(zh, zd)
    np.testing.assert_array_equal(mh, md)
    np.testing.assert_array_equal(lh, ld)


def test_backends_accept_empty_insert_batch():
    """The host buffer no-ops on an empty batch; the sharded twin must
    honor the same contract (callers batch conditionally)."""
    for cls in (HostFleetBackend, ShardedFleetBackend):
        b = cls(capacity=2, window=4, dim=DIM)
        b.admit()
        b.insert_batch(np.array([], np.int64), np.array([], np.int64),
                       np.zeros((0, DIM), np.float32))
        _, mask, _ = b.snapshot()
        assert mask.sum() == 0, cls.__name__


def test_backends_reject_memory_without_head():
    """n_components without a head is an error on BOTH backends (memory
    updates ride the refine round), not a silent divergence."""
    for cls in (HostFleetBackend, ShardedFleetBackend):
        with pytest.raises(ValueError):
            cls(capacity=2, window=4, dim=DIM, n_components=4)


# ---------------------------------------------------------------------------
# Weighted EM (the hook the fleet memory update rides on)
# ---------------------------------------------------------------------------

def test_em_update_weights_none_is_unchanged():
    key = jax.random.PRNGKey(0)
    st = gmm.init_gmm(key, 8, DIM)
    z = jax.random.normal(jax.random.PRNGKey(1), (32, DIM))
    a = gmm.em_update(st, z)
    b = gmm.em_update(st, z, weights=None)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_em_update_zero_weights_drop_frames():
    """weights=indicator == running the update on the kept subset."""
    key = jax.random.PRNGKey(0)
    st = gmm.init_gmm(key, 6, DIM)
    z = jax.random.normal(jax.random.PRNGKey(1), (24, DIM))
    keep = np.zeros(24, np.float32)
    keep[[0, 3, 7, 11, 20]] = 1.0
    a = gmm.em_update(st, z[keep > 0], reseed_frac=0.0)
    b = gmm.em_update(st, z, weights=jnp.asarray(keep), reseed_frac=0.0)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# insert_batch_placed: the sharded dispatch plane's blocked scatter
# ---------------------------------------------------------------------------

def test_insert_batch_placed_matches_plain_scatter():
    """The blocked shard-local scatter (``insert_batch_placed``) leaves
    the rings exactly as ``insert_batch``: pad rows drop, duplicate
    (sid, slot) writes keep the LAST payload, ``newest`` sees the max
    timestamp — the same fold, expressed as drop-sentinel rows."""
    rng = np.random.default_rng(0)
    a = ShardedFleetBackend(capacity=4, window=6, dim=DIM)
    b = ShardedFleetBackend(capacity=4, window=6, dim=DIM)
    for x in (a, b):
        for _ in range(3):
            x.admit()
    # three duplicates of (sid 0, slot 1): ts 7, 1 and 13 all land on
    # slot 1 — last-wins keeps ts 13's payload, newest[0] becomes 13
    sids = np.array([0, 2, 0, 1, 0])
    ts = np.array([7, 3, 1, 2, 13])
    zs = rng.normal(size=(5, DIM)).astype(np.float32)
    labels = np.array([1, 2, 3, 4, 5])
    a.insert_batch(sids, ts, jnp.asarray(zs), labels)
    blocked = np.zeros((8, DIM), np.float32)   # 3 pad rows at the tail
    rows = np.arange(5)
    blocked[rows] = zs
    b.insert_batch_placed(sids, ts,
                          jax.device_put(jnp.asarray(blocked), b._sharding),
                          labels, rows)
    for xa, xb in zip((a.z, a.t, a.label, a.newest),
                      (b.z, b.t, b.label, b.newest)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    # accounting counts the real frame payload, like insert_batch
    assert b.ingest_d2d_bytes == a.ingest_d2d_bytes == 5 * DIM * 4
    assert b.ingest_h2d_bytes == 0
    # empty batch: the host-buffer no-op contract rides along
    b.insert_batch_placed(np.array([], np.int64), np.array([], np.int64),
                          b.z[:0, 0], None, np.array([], np.int64))


def test_insert_batch_placed_validates_inputs():
    b = ShardedFleetBackend(capacity=4, window=6, dim=DIM)
    b.admit()
    z1 = jax.device_put(jnp.zeros((2, DIM), jnp.float32), b._sharding)
    with pytest.raises(TypeError):     # host payloads go via insert_batch
        b.insert_batch_placed(np.array([0]), np.array([0]),
                              np.zeros((2, DIM), np.float32), None,
                              np.array([0]))
    with pytest.raises(KeyError):      # inactive session
        b.insert_batch_placed(np.array([3]), np.array([0]), z1, None,
                              np.array([0]))
    with pytest.raises(ValueError, match="int32"):
        b.insert_batch_placed(np.array([0]), np.array([2 ** 40]), z1, None,
                              np.array([0]))


# ---------------------------------------------------------------------------
# Multi-shard: forced host devices (subprocess -> slow/full CI lane)
# ---------------------------------------------------------------------------

_MULTI_SHARD_PARITY = """
import jax, numpy as np
assert len(jax.devices()) == 4
from repro.core.fleet import HostFleetBackend, ShardedFleetBackend

DIM, NC = 8, 4
def head_init(key): return {"w": 0.01 * jax.random.normal(key, (DIM, NC))}
def head_apply(p, z): return z @ p["w"]

def build(cls):
    b = cls(capacity=8, window=12, dim=DIM, head_init=head_init,
            head_apply=head_apply, lr=0.1, seed=0, n_components=6)
    rng = np.random.default_rng(0)
    sids = [b.admit() for _ in range(7)]   # uneven active count per shard
    # drops/draws keyed by ADMISSION index, not row id: the sharded
    # backend places least-loaded (session i lands on row i*shards mod
    # ...), so the i-th admitted session must carry the same frames on
    # both backends for the pairing below to be meaningful
    for t in range(15):
        for i, sid in enumerate(sids):
            if (t + i) % 5 == 2:
                continue
            b.insert(sid, t, rng.normal(size=DIM).astype(np.float32),
                     label=t % NC)
    b.evict(sids[2])
    return b, sids

(host, sids_h), (shrd, sids_s) = \\
    build(HostFleetBackend), build(ShardedFleetBackend)
assert shrd.shards == 4
# least-loaded placement spread the 7 admissions 2/2/2/1 across shards
assert sorted(shrd.shards_of(np.array(sids_s)).tolist()) == [0,0,1,1,2,2,3]
pair = [i for i in range(7) if i != 2]      # admission i -> row sids_*[i]
rows_h = np.array([sids_h[i] for i in pair])
rows_s = np.array([sids_s[i] for i in pair])
for i in range(3):
    key = jax.random.PRNGKey(i)
    loss_h, parts_h, per_h = host.refine(key)
    loss_s, parts_s, per_s = shrd.refine(key)
    # cross-shard pmean'd loss/SWD aggregation: fp32 reassociation only
    assert abs(loss_s - loss_h) < 1e-5, (i, loss_h, loss_s)
    for k in parts_h:
        assert abs(parts_s[k] - parts_h[k]) < 1e-5, (i, k)
    # per-session losses are row-local (fleet-shared CRN draws), so the
    # i-th admitted session matches across backends whatever row the
    # placement chose for it
    np.testing.assert_allclose(per_s[rows_s], per_h[rows_h], atol=1e-5)
# pmean'd gradients -> head parity
for a, b in zip(jax.tree.leaves(host.refiner.state.params),
                jax.tree.leaves(shrd.refiner.state.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
# psum'd GMM sufficient statistics -> memory parity
for a, b in zip(jax.tree.leaves(host.memory), jax.tree.leaves(shrd.memory)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
# device-resident: no per-round snapshot copy on any shard count
assert shrd.snapshot_h2d_bytes == 0 and host.snapshot_h2d_bytes > 0
print("OK")
"""


def test_multi_shard_refine_matches_unsharded_estimator(subproc):
    out = subproc(_MULTI_SHARD_PARITY, devices=4)
    assert "OK" in out


_LEAST_LOADED_PLACEMENT = """
import jax, numpy as np
assert len(jax.devices()) == 4
from repro.core.fleet import FleetFullError, ShardedFleetBackend

b = ShardedFleetBackend(capacity=64, window=4, dim=4)
assert b.shards == 4
sids = [b.admit() for _ in range(32)]
counts = np.bincount(b.shards_of(np.array(sids)), minlength=4)
# least-loaded placement: 32 admissions land 8/8/8/8, NOT 16/16/0/0
assert counts.tolist() == [8, 8, 8, 8], counts
# drain one shard's sessions: the next admissions refill the hole first
for sid in sids:
    if b.shard_of(sid) == 2:
        b.evict(sid)
refill = [b.admit() for _ in range(8)]
assert all(b.shard_of(s) == 2 for s in refill), refill
# fill to capacity, then the typed full error
for _ in range(64 - b.n_active):
    b.admit()
try:
    b.admit()
except FleetFullError:
    print("OK")
"""


def test_least_loaded_shard_placement_on_admit(subproc):
    """ROADMAP "per-shard load balancing of admissions": a 4-shard fleet
    spreads admissions across the mesh instead of filling shard 0 first,
    and refills the emptiest shard after a drain."""
    out = subproc(_LEAST_LOADED_PLACEMENT, devices=4)
    assert "OK" in out


_SHARDED_ESTIMATOR_HOOKS = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.gmm import em_update, init_gmm
from repro.core.swd import swd_loss
from repro.launch.mesh import make_sessions_mesh

mesh = make_sessions_mesh(4)
key = jax.random.PRNGKey(0)
z = jax.random.normal(jax.random.PRNGKey(1), (64, 16))

# pmean'd SWD: the sharded estimator averages per-shard local SWDs
sharded = jax.jit(shard_map(
    lambda z: swd_loss(key, z, n_dirs=16, axis_name="sessions"),
    mesh=mesh, in_specs=(P("sessions"),), out_specs=P(),
    check_vma=False))(z)
locals_ = [float(swd_loss(key, z[i * 16:(i + 1) * 16], n_dirs=16))
           for i in range(4)]
np.testing.assert_allclose(float(sharded), np.mean(locals_), rtol=1e-5)

# psum'd GMM stats: distributed EM == global EM on the gathered batch
st = init_gmm(jax.random.PRNGKey(2), 8, 16)
upd = jax.jit(shard_map(
    lambda st, z: em_update(st, z, axis_name="sessions", reseed_frac=0.0),
    mesh=mesh, in_specs=(P(), P("sessions")), out_specs=P(),
    check_vma=False))(st, z)
ref = em_update(st, z, reseed_frac=0.0)
for a, b in zip(jax.tree.leaves(upd), jax.tree.leaves(ref)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
print("OK")
"""


def test_sharded_swd_and_gmm_estimator_hooks(subproc):
    """The axis_name hooks the sharded refine rides on, pinned directly:
    pmean'd SWD == mean of per-shard SWDs; psum'd EM == global EM."""
    out = subproc(_SHARDED_ESTIMATOR_HOOKS, devices=4)
    assert "OK" in out
