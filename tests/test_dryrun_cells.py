"""Dry-run machinery on a small mesh (subprocess): one cell per family,
single- and multi-pod, asserting compile success + roofline fields."""
import json

import pytest

CODE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'
import jax
from repro.compat import make_mesh
from repro.launch.dryrun import build_and_compile
mesh = make_mesh({mesh_shape}, {mesh_axes})
rec = build_and_compile('{arch}', '{shape}', mesh, overrides={overrides})
r = rec['roofline']
assert r['compute_s'] > 0 and r['bottleneck'] in ('compute', 'memory',
                                                  'collective')
assert rec['collectives']['collective_bytes'] >= 0
assert rec['memory'].get('peak_memory_in_bytes', 1) > 0
print('CELL-OK', '{arch}', '{shape}', r['bottleneck'])
"""


def _run(subproc, arch, shape, *, overrides, multi_pod=False, devices=16):
    mesh_shape = (2, 2, 4) if multi_pod else (4, 4)
    mesh_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    out = subproc(CODE.format(
        devices=devices, arch=arch, shape=shape,
        mesh_shape=mesh_shape, mesh_axes=mesh_axes, n_axes=len(mesh_shape),
        overrides=overrides), devices=devices)
    assert "CELL-OK" in out


# reduced layer counts keep CPU compiles fast; shapes stay FULL-size inputs
SMALL = {"n_layers": 4}
SMALL_HY = {"n_layers": 7, "hybrid_period": 3}


@pytest.mark.parametrize("arch,shape,ovr", [
    ("qwen3-1.7b", "train_4k", SMALL),
    ("gemma2-2b", "prefill_32k", SMALL),          # sliding+softcap
    ("arctic-480b", "train_4k", {"n_layers": 2}), # MoE EP + dense residual
    ("mamba2-780m", "long_500k", SMALL),          # SSM decode 500k
    ("zamba2-1.2b", "decode_32k", SMALL_HY),      # hybrid decode
])
def test_single_pod_cells(subproc, arch, shape, ovr):
    _run(subproc, arch, shape, overrides=ovr)


@pytest.mark.parametrize("arch,shape,ovr", [
    ("qwen3-1.7b", "train_4k", SMALL),
    ("kimi-k2-1t-a32b", "train_4k", {"n_layers": 2}),
])
def test_multi_pod_cells(subproc, arch, shape, ovr):
    _run(subproc, arch, shape, overrides=ovr, multi_pod=True)
