"""Wire accounting: SplitEngine's measured wire_bytes must agree with the
boundary_bytes-based cost model in core/env.py for every split index k,
including the k=L no-offload and the quantize_wire=False paths."""
import jax
import numpy as np
import pytest

from repro.core.env import EMBED_BYTES, RAW_PCM_BYTES, EdgeCloudEnv, EnvCfg
from repro.core.splitter import SplitEngine
from repro.models.audio_encoder import (AudioEncCfg, boundary_bytes,
                                        init_audio_encoder)

CFG = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                  n_mels=32, frames=40, d_embed=32, groups=4)


@pytest.fixture(scope="module")
def setup():
    params = init_audio_encoder(CFG, jax.random.PRNGKey(0))
    B = 2
    mel = jax.random.normal(jax.random.PRNGKey(1), (B, CFG.frames, CFG.n_mels))
    return params, mel, B


def test_engine_int8_wire_matches_boundary_bytes_every_k(setup):
    params, mel, B = setup
    eng = SplitEngine(CFG, quantize_wire=True)
    per_sample = boundary_bytes(CFG, dtype_bytes=1)
    for k in range(CFG.n_blocks):
        _, wire = eng.run(params, mel, k)
        # +8: per-tensor scale/zero header of the INT8 wire format
        assert wire == B * per_sample[k] + 8, f"k={k}"


def test_engine_fp32_wire_matches_boundary_bytes_every_k(setup):
    params, mel, B = setup
    eng = SplitEngine(CFG, quantize_wire=False)
    per_sample = boundary_bytes(CFG, dtype_bytes=4)
    for k in range(CFG.n_blocks):
        _, wire = eng.run(params, mel, k)
        assert wire == B * per_sample[k], f"k={k}"


def test_engine_k_equals_L_ships_nothing(setup):
    """k=L is fully local: the embedding syncs lazily (core/sync.py), so the
    synchronous split link carries zero bytes on both wire formats."""
    params, mel, _ = setup
    for q in (True, False):
        _, wire = SplitEngine(CFG, quantize_wire=q).run(
            params, mel, CFG.n_blocks)
        assert wire == 0


def test_env_wire_table_matches_boundary_bytes_every_k():
    env = EdgeCloudEnv(EnvCfg())
    enc = env.cfg.enc
    L = env.L
    b1 = boundary_bytes(enc, dtype_bytes=1)
    b4 = boundary_bytes(enc, dtype_bytes=4)
    for k in range(1, L):
        assert env.wire_int8[k] == b1[k], f"k={k}"
        assert env.wire_fp32[k] == b4[k], f"k={k}"
    # endpoints: k=0 ships raw PCM (the audio precedes the mel frontend);
    # k=L accounts only the lazily-synced embedding
    assert env.wire_int8[0] == RAW_PCM_BYTES == env.wire_fp32[0]
    assert env.wire_int8[L] == EMBED_BYTES
    assert env.wire_fp32[L] == 4 * EMBED_BYTES


def test_run_batch_wire_matches_per_frame_run_every_k(setup):
    """The gateway hot path: per-frame wire bytes of a k-bucketed batch
    equal a single-frame ``run`` for every k (per-sample quantization)."""
    params, mel, B = setup
    for q in (True, False):
        eng = SplitEngine(CFG, quantize_wire=q)
        for k in range(CFG.n_blocks + 1):
            _, wire_single = eng.run(params, mel[:1], k)
            _, wire_batch = eng.run_batch(params, mel, k)
            assert wire_batch == wire_single, f"k={k} quantize={q}"


def test_gateway_frame_results_match_boundary_bytes_every_k(setup):
    """End to end: FrameResult.wire_bytes == the boundary_bytes cost table
    for every split index, on both wire formats."""
    from repro.api import FrameRequest, StreamSplitGateway

    class Spread:
        L = CFG.n_blocks

        def decide(self, obs):
            return np.arange(len(obs), dtype=np.int64) % (self.L + 1)

    params, _, _ = setup
    rng = np.random.default_rng(0)
    n = CFG.n_blocks + 1
    for q, dtype_bytes, header in ((True, 1, 8), (False, 4, 0)):
        gw = StreamSplitGateway(CFG, params, policy=Spread(), capacity=n,
                                window=4, qos_reserve=0, quantize_wire=q)
        per_sample = boundary_bytes(CFG, dtype_bytes=dtype_bytes)
        for _ in range(n):
            sid = gw.open_session().sid
            gw.submit(sid, FrameRequest(
                t=0, mel=rng.normal(size=(CFG.frames, CFG.n_mels))))
        for r in gw.tick():
            expect = 0 if r.k == CFG.n_blocks else per_sample[r.k] + header
            assert r.wire_bytes == expect, f"k={r.k} quantize={q}"


def test_env_step_costs_use_the_wire_table_every_k():
    env = EdgeCloudEnv(EnvCfg())
    for k in range(env.L + 1):
        for quantize, table in ((True, env.wire_int8),
                                (False, env.wire_fp32)):
            *_, wire, _ = env.step_costs(k, quantize=quantize)
            assert wire == table[k], f"k={k} quantize={quantize}"
