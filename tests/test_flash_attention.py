"""Flash-attention Pallas kernel: fwd + custom-vjp bwd vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


@pytest.mark.parametrize("B,H,Sq,Sk,d,causal", [
    (2, 4, 128, 128, 64, True),
    (1, 2, 256, 256, 32, True),
    (2, 2, 128, 256, 64, False),
    (1, 1, 64, 64, 128, True),
])
def test_flash_forward_matches_ref(B, H, Sq, Sk, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + d), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d))
    k = jax.random.normal(ks[1], (B, H, Sk, d))
    v = jax.random.normal(ks[2], (B, H, Sk, d))
    out = flash_attention(q, k, v, causal, 64, 64, True)
    ref = flash_attention_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_backward_matches_ref(causal):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, S, d = 1, 2, 128, 32
    q = jax.random.normal(ks[0], (B, H, S, d))
    k = jax.random.normal(ks[1], (B, H, S, d))
    v = jax.random.normal(ks[2], (B, H, S, d))

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal, 64, 64, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal) ** 2)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   err_msg=f"d{name}")


def test_flash_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, S, d = 1, 2, 128, 64
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.bfloat16)
    out = flash_attention(q, k, v, True, 64, 64, True)
    ref = flash_attention_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
