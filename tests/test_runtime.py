"""Trainer loop: convergence, fault tolerance, stragglers, hybrid aux."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_config
from repro.data.tokens import TokenStream, random_batch
from repro.runtime.fault import FailureInjector, StragglerMonitor
from repro.runtime.trainer import TrainCfg, Trainer, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    def data_fn(step):
        return random_batch(jax.random.PRNGKey(step), cfg.vocab, 8, 32)
    return cfg, data_fn


def test_loss_decreases(tiny, tmp_path):
    cfg, data_fn = tiny
    tcfg = TrainCfg(lr=2e-3, total_steps=40, warmup=4)
    tr = Trainer(cfg, tcfg, data_fn, ckpt_dir=None)
    hist = tr.run(40, log_every=0)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.8


def test_failure_restore_and_continue(tiny, tmp_path):
    cfg, data_fn = tiny
    tcfg = TrainCfg(lr=1e-3, total_steps=40, warmup=4)
    tr = Trainer(cfg, tcfg, data_fn, ckpt_dir=str(tmp_path), ckpt_every=10,
                 failure_injector=FailureInjector(fail_at=[17, 23]))
    tr.run(30, log_every=0)
    assert tr.restarts == 2
    assert tr.step == 30


def test_restart_resumes_from_disk(tiny, tmp_path):
    cfg, data_fn = tiny
    tcfg = TrainCfg(lr=1e-3, total_steps=40, warmup=4)
    tr1 = Trainer(cfg, tcfg, data_fn, ckpt_dir=str(tmp_path), ckpt_every=5)
    tr1.run(10, log_every=0)
    # fresh process-equivalent: a new Trainer picks up step 10
    tr2 = Trainer(cfg, tcfg, data_fn, ckpt_dir=str(tmp_path), ckpt_every=5)
    assert tr2.step == 10
    w1 = jax.tree.leaves(tr1.state["params"])[0]
    w2 = jax.tree.leaves(tr2.state["params"])[0]
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_microbatch_accumulation_matches_full_batch(tiny):
    """grad(mean over microbatches) == grad(full batch) for the same data."""
    cfg, data_fn = tiny
    batch = data_fn(0)
    key = jax.random.PRNGKey(0)
    from repro.models import lm
    params, _ = lm.init_lm(cfg, key)
    from repro.optim import adamw_init
    opt = adamw_init(params)
    outs = {}
    for n_micro in (1, 4):
        tcfg = TrainCfg(lr=1e-3, microbatches=n_micro, total_steps=10,
                        warmup=1)
        step = make_train_step(cfg, tcfg)
        p2, o2, m = jax.jit(step)(params, opt, batch, jnp.int32(0), key)
        outs[n_micro] = (jax.tree.leaves(p2)[0], m["loss"])
    np.testing.assert_allclose(np.asarray(outs[1][1]),
                               np.asarray(outs[4][1]), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[1][0]),
                               np.asarray(outs[4][0]), atol=1e-5)


def test_hybrid_aux_loss_reported(tiny):
    cfg, data_fn = tiny
    tcfg = TrainCfg(lr=1e-3, hybrid=True, hybrid_pool=8, total_steps=10,
                    warmup=1)
    tr = Trainer(cfg, tcfg, data_fn)
    hist = tr.run(3, log_every=0)
    assert "swd" in hist[-1] and "lap" in hist[-1]
    assert np.isfinite(hist[-1]["swd"]) and np.isfinite(hist[-1]["lap"])


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(factor=3.0, warmup=3)
    for i, dt in enumerate([0.1, 0.1, 0.1, 0.1, 0.1, 0.9, 0.1]):
        m.record(i, dt)
    assert len(m.events) == 1
    assert m.events[0].step == 5


def test_token_stream_learnable_structure():
    ts = TokenStream(64, seed=0)
    b = ts.batch(4, 32, step=0)
    assert b["tokens"].shape == (4, 32)
    # deterministic per step
    b2 = ts.batch(4, 32, step=0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    assert not np.array_equal(b["tokens"], ts.batch(4, 32, step=1)["tokens"])
