"""Self-healing federation: frame journaling + buddy replication,
heartbeat hang detection, deterministic retry, degraded-mode admission,
and the drain-timeout summary — all on a fake clock, no wall-clock
sleeps anywhere.

The load-bearing oracles:

- **Loss bound**: a member killed mid-stream with replication on loses
  STRICTLY fewer frames than the same seeded run with replication off —
  and with a per-step journal flush, exactly zero.
- **Replay parity**: frames recovered via checkpoint + journal replay
  produce bit-identical embeddings to an unfailed sequential run of the
  same admitted schedule (replay re-enters frames with their original
  ledger through the same ``import_session`` seam migration uses).
- **Conservation under repeated chaos**: ``submitted == served +
  queue_depth + in_flight + shed_expired + lost_in_flight`` per class
  at EVERY snapshot across kill → recover → kill cycles, with
  ``lost_sessions`` empty whenever a buddy holds a journal.
"""
import jax
import numpy as np
import pytest

from repro.api import FrameRequest, QoSClass
from repro.cluster import (ClusterDegradedError, ClusterDrainTimeout,
                           FailureInjector, FrameJournal, GatewayCluster,
                           HashRing, JournalEntry, MemberHungError,
                           ReplicationLog, RetryPolicy, TransientFault)
from repro.models.audio_encoder import init_audio_encoder

from test_cluster import (CFG, FakeClock, _assert_conserved, _gw, _req,
                          _server)

I, S, B = QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK


@pytest.fixture(scope="module")
def params():
    return init_audio_encoder(CFG, jax.random.PRNGKey(0))


def _entry(t, *, sid=0):
    f = _req(sid, t)
    return JournalEntry(t=t, frame=f, enq_s=0.1 * t,
                        deadline_s=0.1 * t + 1.0)


# ---------------------------------------------------------------------------
# FrameJournal / ReplicationLog units
# ---------------------------------------------------------------------------

def test_journal_lifecycle_pending_acked_settled():
    j = FrameJournal(gsid=0, buddy="b")
    for t in range(4):
        j.append(_entry(t))
    assert len(j.pending()) == 4 and j.replayable() == []
    shipped = j.flush()
    assert shipped > 0 and j.pending() == []
    assert [e.t for e in j.replayable()] == [0, 1, 2, 3]
    j.settle(0)
    j.settle(1)
    assert [e.t for e in j.replayable()] == [2, 3]
    # truncation drops ONLY acked-and-settled: the open tail survives
    assert j.truncate_settled() == 2
    assert [e.t for e in j.entries] == [2, 3]
    # a second flush ships nothing — acks are idempotent
    assert j.flush() == 0


def test_journal_without_buddy_never_acks():
    j = FrameJournal(gsid=0, buddy=None)
    j.append(_entry(0))
    assert j.flush() == 0                    # nowhere to ship
    assert j.pending() and j.replayable() == []


def test_journal_settle_matches_oldest_open_entry():
    j = FrameJournal(gsid=0, buddy="b")
    j.append(_entry(7))
    j.append(_entry(7))                      # same t twice (re-submit)
    j.flush()
    assert j.settle(7) and j.entries[0].settled
    assert not j.entries[1].settled          # one serve settles one entry
    assert not j.settle(99)                  # unknown t: no-op


def test_log_drop_member_clears_only_acked_entries():
    """The buddy died: entries that were SHIPPED lived there and die
    with it; pending entries never left the owner's side and survive."""
    log = ReplicationLog()
    log.open(0, "b")
    log.open(1, "c")                         # different buddy: untouched
    for t in range(3):
        log.record(0, t=t, frame=_req(0, t), enq_s=0.0, deadline_s=1.0)
        log.record(1, t=t, frame=_req(1, t), enq_s=0.0, deadline_s=1.0)
    log.flush_all()
    log.record(0, t=3, frame=_req(0, 3), enq_s=0.0, deadline_s=1.0)
    hit = log.drop_member("b")
    assert hit == [0] and log.resets == 1
    j0 = log.journal(0)
    assert j0.buddy is None
    assert [e.t for e in j0.entries] == [3]  # the pending one survives
    assert [e.t for e in log.journal(1).entries] == [0, 1, 2]


def test_log_rehome_keeps_entries_and_meters_reship():
    log = ReplicationLog()
    log.open(0, "b")
    log.record(0, t=0, frame=_req(0, 0), enq_s=0.0, deadline_s=1.0)
    log.flush_all()
    first = log.bytes_shipped
    assert first > 0
    log.rehome(0, "c")                       # old buddy alive: data moves
    assert log.journal(0).buddy == "c"
    assert log.bytes_shipped == 2 * first    # the re-ship is metered
    assert [e.t for e in log.journal(0).replayable()] == [0]


def test_ring_buddy_is_next_live_node_past_owner():
    r = HashRing(["a", "b", "c"], seed=3)
    for k in range(50):
        owner = r.owner(k)
        buddy = r.buddy(k, exclude=(owner,))
        assert buddy is not None and buddy != owner
        assert r.preference(k)[1] == buddy   # the failover successor
    r.remove("b")
    r.remove("c")
    assert r.buddy(0, exclude=("a",)) is None    # nobody left to hold it


# ---------------------------------------------------------------------------
# THE acceptance oracle: bounded loss + bit-identical replay
# ---------------------------------------------------------------------------

def _chaos_run(params, *, replicate, flush_every=1, rounds=10,
               n_sessions=4, fail_at=6, seed=3, max_batch=4):
    """One seeded kill-mid-stream run; returns (cluster, infos,
    results-by-(sid, t))."""
    clock = FakeClock()
    members = {"a": _server(params, clock, max_batch=max_batch),
               "b": _server(params, clock, max_batch=max_batch)}
    cl = GatewayCluster(members, seed=seed, snapshot_every=2,
                        replicate=replicate,
                        journal_flush_every=flush_every,
                        injectors={"a": FailureInjector(fail_at=(fail_at,))},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(n_sessions)]
    assert "a" in {cl.session_member(i.sid) for i in infos}
    for t in range(rounds):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())        # ...including mid-chaos
    cl.pump()
    _assert_conserved(cl.stats())
    by = {}
    for r in cl.drain_results():
        assert (r.sid, r.t) not in by        # nothing double-served
        by[(r.sid, r.t)] = r
    return cl, infos, by


def test_replication_bounds_loss_and_replays_bit_identically(params):
    """The PR's acceptance test.  Same seed, same schedule, same kill:

    - replication OFF loses the victim's post-checkpoint frames;
    - replication ON (per-step flush) loses NOTHING — every journaled
      frame replays on the survivor;
    - the recovered embeddings are bit-identical to an unfailed
      sequential replay of the same admitted schedule."""
    cl_off, _, _ = _chaos_run(params, replicate=False)
    lost_off = sum(cl_off.stats().lost_in_flight.values())
    assert lost_off > 0                      # checkpoint-only recovery

    cl_on, infos, by = _chaos_run(params, replicate=True)
    st = cl_on.stats()
    lost_on = sum(st.lost_in_flight.values())
    assert lost_on < lost_off                # the headline inequality
    assert lost_on == 0                      # per-step flush: zero loss
    assert st.failures == 1 and st.failovers > 0
    assert st.replayed_frames > 0 and st.journal_bytes > 0
    assert cl_on.lost_sessions == []
    assert sum(st.shed_expired.values()) == 0
    assert st.served == st.submitted         # every frame came out

    # replay parity: bit-identical to one fresh gateway, same schedule
    oracle = _gw(params, FakeClock(), capacity=8)
    for i in infos:
        osid = oracle.open_session().sid
        for t in range(10):
            oracle.submit(osid, _req(i.sid, t))
            (r,) = oracle.tick()
            got = by[(i.sid, t)]
            np.testing.assert_array_equal(got.z, r.z)     # bitwise
            assert got.k == r.k and got.route == r.route

    # the cluster keeps serving after recovery
    for i in infos:
        cl_on.submit(i.sid, _req(i.sid, 99))
    cl_on.pump()
    _assert_conserved(cl_on.stats())
    for i in infos:
        cl_on.close_session(i.sid)
    _assert_conserved(cl_on.stats())


def test_flush_window_is_the_loss_bound(params):
    """With ``journal_flush_every=2`` a kill on an unflushed step loses
    EXACTLY the victim's frames admitted since the last flush — one
    window, no more (acked entries replay, pending die, all counted)."""
    # fail_at=5: flushes landed at steps 2 and 4, covering rounds 0-3;
    # round-4 admissions are still pending when the injector fires.
    # 8 sessions at max_batch=2 keep an acked backlog alive at the
    # kill, so the run exercises BOTH sides of the bound: replay AND
    # loss (seed 0 homes 4 sessions on the victim).
    cl, infos, _ = _chaos_run(params, replicate=True, flush_every=2,
                              fail_at=5, max_batch=2, n_sessions=8,
                              seed=0)
    st = cl.stats()
    lost = sum(st.lost_in_flight.values())
    homed_on_a = st.failovers                # one failover per a-session
    assert homed_on_a > 0
    assert lost == homed_on_a                # one unflushed round each
    assert cl.lost_sessions == []
    assert st.replayed_frames > 0            # the acked tail came back


# ---------------------------------------------------------------------------
# Heartbeat: hung members fail over like crashed ones
# ---------------------------------------------------------------------------

def test_hung_member_detected_and_failed_over(params):
    """A member that stops completing steps WITHOUT raising is declared
    hung by heartbeat suspicion on the injected clock and recovered
    through the same checkpoint + journal-replay path as a crash."""
    clock = FakeClock()
    members = {"a": _server(params, clock, max_batch=4),
               "b": _server(params, clock, max_batch=4)}
    cl = GatewayCluster(members, seed=3, replicate=True,
                        heartbeat_timeout_s=0.05,
                        injectors={"a": FailureInjector(hang_from=4)},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    assert "a" in {cl.session_member(i.sid) for i in infos}
    for t in range(8):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.02)
        cl.step()
        _assert_conserved(cl.stats())
    st = cl.stats()
    assert st.failures == 1 and st.members == ("b",)
    assert st.failovers > 0 and cl.lost_sessions == []
    assert all(cl.session_member(i.sid) == "b" for i in infos)
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    # journaled frames replayed: the hang lost at most the unflushed
    # window (here: nothing — per-step flush)
    assert sum(st.lost_in_flight.values()) == 0
    assert st.served == st.submitted


def test_hung_member_error_is_typed():
    err = MemberHungError("a", 0.3, 0.05)
    assert err.name == "a" and "no heartbeat" in str(err)
    assert isinstance(err, RuntimeError)


def test_healthy_members_never_suspected(params):
    """An IDLE member still beats — completing a no-op step is
    progress; suspicion keys on completion, not on load."""
    clock = FakeClock()
    cl = GatewayCluster({"a": _server(params, clock),
                         "b": _server(params, clock)},
                        seed=0, heartbeat_timeout_s=0.05, timer=clock)
    for _ in range(20):                      # idle, slow clock
        clock.advance(0.04)                  # under threshold per step
        cl.step()
    assert cl.stats().failures == 0
    assert cl.stats().members == ("a", "b")


# ---------------------------------------------------------------------------
# Retry: transient faults heal, fatal ones fail over
# ---------------------------------------------------------------------------

def test_transient_member_fault_retried_not_killed(params):
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=3, replicate=True,
                        injectors={"a": FailureInjector(
                            transient_at={3: 2})},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    for t in range(6):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.retries == 2                   # both blips retried away
    assert st.failures == 0 and st.members == ("a", "b")
    assert st.served == st.submitted


def test_transient_exhaustion_becomes_a_failover(params):
    """More consecutive transients than the policy's budget: the retry
    wrapper re-raises and the member takes the ordinary death path —
    with replication, its sessions replay on the survivor."""
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=3, replicate=True,
                        retry=RetryPolicy(max_attempts=3),
                        injectors={"a": FailureInjector(
                            transient_at={3: 10})},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(4)]
    for t in range(6):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.retries == 2                   # attempts 1..3, then fatal
    assert st.failures == 1 and st.members == ("b",)
    assert st.failovers > 0 and cl.lost_sessions == []


def test_retry_disabled_makes_transients_fatal(params):
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=3, snapshot_every=2, retry=None,
                        injectors={"a": FailureInjector(
                            transient_at={2: 1})},
                        timer=clock)
    [cl.open_session(qos=S) for _ in range(4)]
    for t in range(4):
        clock.advance(0.01)
        cl.step()
    st = cl.stats()
    assert st.retries == 0 and st.failures == 1


# ---------------------------------------------------------------------------
# Degraded mode
# ---------------------------------------------------------------------------

def test_degraded_mode_refuses_new_sessions_and_bulk(params):
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=3, replicate=True,
                        degraded_below=0.75,
                        injectors={"a": FailureInjector(fail_at=(4,))},
                        timer=clock)
    std = cl.open_session(qos=S)
    blk = cl.open_session(qos=B)
    assert not cl.stats().degraded           # full strength
    for t in range(4):                       # the kill lands on the
        cl.submit(std.sid, _req(std.sid, t))  # LAST step: every loop
        cl.submit(blk.sid, _req(blk.sid, t))  # submit is pre-failure
        clock.advance(0.01)
        cl.step()
    st = cl.stats()
    assert st.failures == 1 and st.degraded  # 1/2 live < 0.75 watermark
    # new sessions refused, typed
    with pytest.raises(ClusterDegradedError, match="new session"):
        cl.open_session(qos=S)
    # BULK shed at the door, typed and counted — NOT in submitted
    before = dict(cl.stats().submitted)
    with pytest.raises(ClusterDegradedError, match="BULK"):
        cl.submit(blk.sid, _req(blk.sid, 99))
    st = cl.stats()
    assert st.submitted == before            # conservation untouched
    assert st.rejected_degraded[B.value] == 1
    # the streams the cluster already holds keep full service
    cl.submit(std.sid, _req(std.sid, 99))
    cl.pump()
    _assert_conserved(cl.stats())
    # capacity returns -> degraded clears itself
    cl.add_member("c", _server(params, clock))
    st = cl.stats()
    assert not st.degraded
    cl.open_session(qos=S)                   # admission resumed
    cl.submit(blk.sid, _req(blk.sid, 100))   # BULK resumed
    cl.pump()
    _assert_conserved(cl.stats())


def test_degraded_off_by_default(params):
    clock = FakeClock()
    members = {"a": _server(params, clock), "b": _server(params, clock)}
    cl = GatewayCluster(members, seed=3, snapshot_every=2,
                        injectors={"a": FailureInjector(fail_at=(1,))},
                        timer=clock)
    cl.open_session(qos=S)
    cl.step()
    assert cl.stats().failures == 1
    assert not cl.stats().degraded           # watermark 0: never
    cl.open_session(qos=S)                   # admission unaffected


# ---------------------------------------------------------------------------
# Repeated chaos: kill -> recover -> kill, conservation at every snapshot
# ---------------------------------------------------------------------------

def test_repeated_failover_conserves_and_loses_no_sessions(params):
    """Sessions that already failed over once fail over AGAIN when
    their new home dies: the journal re-homes with them, the books
    stay conserved at every snapshot, and no session is ever dropped
    while a buddy holds its journal."""
    clock = FakeClock()
    members = {n: _server(params, clock, max_batch=4)
               for n in ("a", "b", "c")}
    cl = GatewayCluster(members, seed=3, replicate=True,
                        injectors={"a": FailureInjector(fail_at=(4,)),
                                   "b": FailureInjector(fail_at=(9,))},
                        timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(6)]
    homes0 = {i.sid: cl.session_member(i.sid) for i in infos}
    assert {"a", "b"} <= set(homes0.values())    # both victims serve
    for t in range(14):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
        clock.advance(0.01)
        cl.step()
        _assert_conserved(cl.stats())        # EVERY snapshot, mid-chaos
        if t == 6:                           # recover capacity between
            cl.add_member("d", _server(params, clock, max_batch=4))
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.failures == 2
    assert "a" not in st.members and "b" not in st.members
    assert st.sessions_open == 6 and cl.lost_sessions == []
    # both kills recovered sessions (the add_member rebalance may have
    # migrated some off the second victim before it died — a migration
    # is not a failover, so only a lower bound is stable here)
    assert st.failovers > 0 and st.failovers + st.migrations >= len(
        [s for s, m in homes0.items() if m in ("a", "b")])
    assert sum(st.lost_in_flight.values()) == 0  # per-step flush
    # every stream is still live end-to-end
    for i in infos:
        cl.submit(i.sid, _req(i.sid, 99))
    cl.pump()
    st = cl.stats()
    _assert_conserved(st)
    assert st.served == st.submitted
    for i in infos:
        cl.close_session(i.sid)
    _assert_conserved(cl.stats())


# ---------------------------------------------------------------------------
# stop(drain): typed timeout summary
# ---------------------------------------------------------------------------

def test_stop_drain_timeout_names_stragglers(params):
    """A drain that cannot finish (here: the only member hangs) raises
    the typed summary naming each stuck session and its outstanding
    count, instead of an anonymous pump error."""
    clock = FakeClock()
    cl = GatewayCluster({"a": _server(params, clock)}, seed=0,
                        injectors={"a": FailureInjector(hang_from=1)},
                        timer=clock)
    info = cl.open_session(qos=S)
    cl.submit(info.sid, _req(info.sid, 0))
    cl.submit(info.sid, _req(info.sid, 1))
    with pytest.raises(ClusterDrainTimeout) as ei:
        cl.stop(drain=True, max_steps=25)
    assert ei.value.stragglers == {info.sid: 2}
    assert "2 outstanding" in str(ei.value)
    assert cl.stats().drain_stragglers == 1


def test_stop_drain_clean_path_unchanged(params):
    clock = FakeClock()
    cl = GatewayCluster({"a": _server(params, clock)}, seed=0,
                        timer=clock)
    info = cl.open_session(qos=S)
    cl.submit(info.sid, _req(info.sid, 0))
    cl.stop(drain=True)                      # drains fine, no raise
    st = cl.stats()
    assert st.served == st.submitted and st.drain_stragglers == 0


# ---------------------------------------------------------------------------
# Replication plumbing through migration
# ---------------------------------------------------------------------------

def test_drain_rehomes_journals_off_the_leaving_member(params):
    """A drained member leaves gracefully: journals it hosted re-ship
    to a new buddy (metered) — no session loses its replication
    protection across a rolling restart."""
    clock = FakeClock()
    members = {n: _server(params, clock) for n in ("a", "b", "c")}
    cl = GatewayCluster(members, seed=5, replicate=True, timer=clock)
    infos = [cl.open_session(qos=S) for _ in range(6)]
    for t in range(2):
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t))
    cl.step()                                # flush: journals acked
    victim = cl.session_member(infos[0].sid)
    cl.drain(victim)
    # every journal now lives on a live non-owner
    log = cl._log
    for i in infos:
        j = log.journal(i.sid)
        owner = cl.session_member(i.sid)
        assert j.buddy is not None
        assert j.buddy != owner and j.buddy in cl.stats().members
    cl.pump()
    _assert_conserved(cl.stats())
    assert cl.stats().served == cl.stats().submitted
