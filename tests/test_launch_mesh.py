"""launch/mesh: session meshes + the ``jax.distributed`` multi-host
on-ramp (``maybe_init_distributed``).

The on-ramp smoke injects a fake ``initialize`` — a real coordinator
needs a multi-process job, which is the follow-up PR's launcher config;
what THIS repo pins is the env contract and the idempotence latch."""
import jax
import pytest

from repro.launch import mesh as mesh_mod
from repro.launch.mesh import (make_sessions_mesh, make_test_mesh,
                               maybe_init_distributed)


def test_sessions_mesh_defaults_to_visible_devices():
    m = make_sessions_mesh()
    assert m.shape == {"sessions": len(jax.devices())}
    assert make_sessions_mesh(1, axis="rows").shape == {"rows": 1}


def test_test_mesh_shape():
    assert make_test_mesh((1, 1)).shape == {"data": 1, "model": 1}


@pytest.fixture
def fresh_latch():
    """Each test sees an un-initialized process latch and restores it."""
    saved = dict(mesh_mod._distributed)
    mesh_mod._distributed["initialized"] = False
    yield mesh_mod._distributed
    mesh_mod._distributed.clear()
    mesh_mod._distributed.update(saved)


def test_maybe_init_distributed_noop_without_coordinator(fresh_latch):
    calls = []
    assert maybe_init_distributed(env={}, initialize=calls.append) is False
    assert calls == [] and not fresh_latch["initialized"]


def test_maybe_init_distributed_reads_env_contract(fresh_latch):
    calls = []

    def fake_init(**kw):
        calls.append(kw)

    env = {"REPRO_COORDINATOR": "10.0.0.1:1234",
           "REPRO_NUM_PROCESSES": "4", "REPRO_PROCESS_ID": "2"}
    assert maybe_init_distributed(env=env, initialize=fake_init) is True
    assert calls == [{"coordinator_address": "10.0.0.1:1234",
                      "num_processes": 4, "process_id": 2}]
    # idempotent: a second call is a no-op returning True
    assert maybe_init_distributed(env=env, initialize=fake_init) is True
    assert len(calls) == 1


def test_maybe_init_distributed_defaults_and_validation(fresh_latch):
    calls = []

    def fake_init(**kw):
        calls.append(kw)

    env = {"REPRO_COORDINATOR": "head:9999"}
    assert maybe_init_distributed(env=env, initialize=fake_init) is True
    # single-entry defaults: one process, id 0 — harmless to join
    assert calls == [{"coordinator_address": "head:9999",
                      "num_processes": 1, "process_id": 0}]
    fresh_latch["initialized"] = False
    with pytest.raises(ValueError, match="REPRO_PROCESS_ID"):
        maybe_init_distributed(
            env={"REPRO_COORDINATOR": "head:9999",
                 "REPRO_NUM_PROCESSES": "2", "REPRO_PROCESS_ID": "2"},
            initialize=fake_init)
    assert len(calls) == 1 and not fresh_latch["initialized"]
