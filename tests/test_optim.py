"""Optimizers: formula checks + convergence + compression parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, sgd_init, sgd_update)
from repro.optim.compression import ErrorFeedback, compress_decompress
from repro.optim.schedules import warmup_cosine


def test_adamw_first_step_formula():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.25])}
    st = adamw_init(p)
    p2, st2 = adamw_update(p, g, st, lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                           grad_clip=0.0)
    # after bias correction the first step is -lr * g/(|g|+eps) = -lr*sign
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p["w"]) - 0.1 * np.sign([0.5, 0.25]),
                               rtol=1e-4)


def _quadratic_losses(update_fn, init_fn, steps=200, lr=0.05, **kw):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (8, 8))
    A = A @ A.T / 8 + jnp.eye(8)
    b = jax.random.normal(jax.random.PRNGKey(1), (8,))
    params = {"x": jnp.zeros((8,)), "W": jnp.zeros((8, 8))}

    def loss(p):
        r = A @ p["x"] - b
        return 0.5 * r @ r + 0.5 * jnp.sum((p["W"] - A) ** 2)

    st = init_fn(params)
    hist = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, st = update_fn(params, g, st, lr=lr, **kw)
        hist.append(float(loss(params)))
    return hist


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgd"])
def test_optimizers_converge_on_quadratic(opt):
    fns = {"adamw": (adamw_update, adamw_init),
           "adafactor": (adafactor_update, adafactor_init),
           "sgd": (sgd_update, sgd_init)}
    upd, init = fns[opt]
    hist = _quadratic_losses(upd, init, lr=0.05 if opt != "sgd" else 0.01)
    assert hist[-1] < hist[0] * 0.05, f"{opt}: {hist[0]} -> {hist[-1]}"


def test_adafactor_memory_is_factored():
    p = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((512,))}
    st = adafactor_init(p)
    n_state = sum(int(x.size) for x in jax.tree.leaves(st["stats"]))
    n_param = 256 * 512 + 512
    assert n_state < n_param * 0.02  # rows+cols << full matrix


def test_schedule_warmup_then_decay():
    lrs = [float(warmup_cosine(s, peak=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[10 - 1]
    assert lrs[20] > lrs[60] > lrs[99]


def test_error_feedback_preserves_signal():
    """EF accumulates what compression drops: sum of applied updates over
    T steps ≈ sum of raw gradients (bounded residual)."""
    key = jax.random.PRNGKey(0)
    g_total = jnp.zeros((64,))
    applied_total = jnp.zeros((64,))
    ef = {"g": jnp.zeros((64,))}
    for t in range(50):
        g = {"g": jax.random.normal(jax.random.PRNGKey(t), (64,)) * 0.1}
        out, ef = ErrorFeedback.apply(g, ef)
        g_total += g["g"]
        applied_total += out["g"]
    resid = float(jnp.max(jnp.abs(g_total - applied_total)))
    # residual is at most one step's quantization error, not O(T)
    assert resid < 0.05


def test_compressed_dp_matches_uncompressed(subproc):
    """int8+EF data-parallel training reaches the same optimum as exact
    psum on a quadratic (4-way DP)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.distributed.grad_sync import make_compressed_dp_step, ef_init
from repro.optim import sgd_init, sgd_update

mesh = make_test_mesh((4,), ('data',))
A = jnp.eye(8)
def loss_fn(params, batch):
    r = batch['x'] @ params['w'] - batch['y']
    return jnp.mean(r * r)
key = jax.random.PRNGKey(0)
w_true = jax.random.normal(key, (8, 4))
X = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
Y = X @ w_true
params = {'w': jnp.zeros((8, 4))}
outs = {}
for compress in (False, True):
    p = {'w': jnp.zeros((8, 4))}
    st = sgd_init(p)
    ef = ef_init(p)
    step = make_compressed_dp_step(mesh, loss_fn, sgd_update, axis='data',
                                   lr=0.1, compress=compress)
    for i in range(200):
        p, st, ef = step(p, st, ef, {'x': X, 'y': Y})
    outs[compress] = float(loss_fn(p, {'x': X, 'y': Y}))
print('exact', outs[False], 'compressed', outs[True])
assert outs[False] < 1e-4
assert outs[True] < 1e-3
""", devices=4)


def test_int8_psum_wire_accuracy(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.launch.mesh import make_test_mesh
from repro.optim.compression import int8_psum
mesh = make_test_mesh((4,), ('data',))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 128))
def f(x):
    return int8_psum(x, 'data'), jax.lax.psum(x, 'data')
got, want = jax.jit(shard_map(f, mesh=mesh, in_specs=P('data'),
    out_specs=(P(), P()), check_vma=False))(x)
rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
print('rel err', rel)
assert rel < 0.05
""", devices=4)
