"""Cascade serving (uncertainty routing) + hybrid-loss variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid import HybridCfg, hybrid_loss


def test_cascade_demo_routes_both_tiers():
    from repro.launch.serve import demo
    stats = demo(n_batches=6, batch=6, seq=32)
    assert stats.served_small + stats.served_large == 36
    assert 0.0 < stats.escalation_rate < 1.0


@pytest.mark.parametrize("variant", ["hybrid", "task_sw", "task_lap",
                                     "mse", "kl"])
def test_hybrid_variants_finite_and_differentiable(variant):
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (4, 20, 16))
    z = z / jnp.linalg.norm(z, axis=-1, keepdims=True)

    def f(z):
        loss, parts = hybrid_loss(jax.random.PRNGKey(1), z,
                                  HybridCfg(), variant=variant)
        return loss

    v, g = jax.value_and_grad(f)(z)
    assert np.isfinite(float(v))
    assert bool(jnp.isfinite(g).all())


def test_hybrid_mask_changes_laplacian_only():
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (2, 30, 8))
    mask = jnp.ones((2, 30)).at[:, 10:20].set(0.0)
    _, p_full = hybrid_loss(jax.random.PRNGKey(1), z, HybridCfg())
    _, p_mask = hybrid_loss(jax.random.PRNGKey(1), z, HybridCfg(), mask=mask)
    assert float(p_full["sw"]) == pytest.approx(float(p_mask["sw"]))
    assert float(p_full["lap"]) != pytest.approx(float(p_mask["lap"]))


def test_audio_stream_structure():
    from repro.data.audio_stream import AudioStream, StreamCfg, mel_frontend
    s = AudioStream(StreamCfg(seed=0))
    groups = []
    for _ in range(300):
        _, label, group = s.next_sample()
        groups.append(group)
    frac_bg = groups.count("background") / len(groups)
    assert 0.45 < frac_bg < 0.75          # ~60% background mix
    mel, label, _ = s.next_mel()
    assert mel.shape[1] == 128 and mel.shape[0] >= 95
    # determinism
    s2 = AudioStream(StreamCfg(seed=0))
    w1, l1, _ = AudioStream(StreamCfg(seed=1)).next_sample()
    w2, l2, _ = AudioStream(StreamCfg(seed=1)).next_sample()
    np.testing.assert_array_equal(w1, w2)
