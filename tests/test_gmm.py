"""Distributional Memory (paper §4.1): streaming EM, uncertainty, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmm as G


def _sphere(key, n, d):
    z = jax.random.normal(key, (n, d))
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def test_responsibilities_normalized():
    key = jax.random.PRNGKey(0)
    st_ = G.init_gmm(key, 8, 16)
    z = _sphere(jax.random.PRNGKey(1), 32, 16)
    r = G.responsibilities(st_, z)
    np.testing.assert_allclose(np.asarray(r.sum(-1)), 1.0, rtol=1e-5)
    assert bool((r >= 0).all())


# seeded sweep over (components, dim, batch) — corners + odd interior sizes
@pytest.mark.parametrize("C,d,B", [
    (2, 2, 1), (2, 64, 48), (32, 2, 1), (32, 64, 48),
    (3, 5, 2), (8, 16, 32), (16, 8, 3), (7, 33, 17),
    (2, 3, 48), (32, 17, 7), (5, 64, 1), (13, 13, 13),
])
def test_entropy_bounds(C, d, B):
    key = jax.random.PRNGKey(C * 1000 + d)
    st_ = G.init_gmm(key, C, d)
    z = _sphere(jax.random.PRNGKey(B), B, d)
    u = G.entropy(st_, z)
    assert bool((u >= -1e-5).all())
    assert bool((u <= np.log(C) + 1e-4).all())
    un = G.normalized_entropy(st_, z)
    assert bool((un <= 1.0 + 1e-5).all())


def test_em_convergence_recovers_clusters():
    """Streaming EM on a 4-cluster synthetic mixture: post-fit likelihood
    must beat the init and responsibilities become confident."""
    key = jax.random.PRNGKey(0)
    d, C = 16, 4
    centers = _sphere(jax.random.PRNGKey(5), C, d)
    st_ = G.init_gmm(key, C, d, var0=0.5)

    def batch(k):
        ks = jax.random.split(k, 2)
        idx = jax.random.randint(ks[0], (64,), 0, C)
        z = centers[idx] + 0.05 * jax.random.normal(ks[1], (64, d))
        return z / jnp.linalg.norm(z, axis=-1, keepdims=True)

    z0 = batch(jax.random.PRNGKey(99))
    ll_before = float(jax.nn.logsumexp(G.log_joint(st_, z0), -1).mean())
    for i in range(150):
        st_ = G.em_update(st_, batch(jax.random.PRNGKey(i)), decay=0.05)
    ll_after = float(jax.nn.logsumexp(G.log_joint(st_, z0), -1).mean())
    assert ll_after > ll_before + 1.0
    u = G.normalized_entropy(st_, z0)
    assert float(u.mean()) < 0.5  # confident assignments


def test_boundary_sampling_excludes_anchor_component():
    key = jax.random.PRNGKey(0)
    st_ = G.init_gmm(key, 8, 16)
    z = _sphere(jax.random.PRNGKey(1), 16, 16)
    c_star = G.assign(st_, z)
    logits = G.boundary_logits(st_, c_star)
    own = jnp.take_along_axis(logits, c_star[:, None], 1)
    assert bool(jnp.all(own == -jnp.inf))


def test_virtual_negatives_on_sphere_and_shape():
    key = jax.random.PRNGKey(0)
    st_ = G.init_gmm(key, 8, 16)
    z = _sphere(jax.random.PRNGKey(1), 4, 16)
    neg = G.sample_virtual_negatives(jax.random.PRNGKey(2), st_, z, 32)
    assert neg.shape == (4, 32, 16)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(neg), axis=-1),
                               1.0, rtol=1e-4)


def test_memory_footprint_under_35kb():
    """Paper Eq. 8: C=64, d=128 fp16 distributional memory ≈ 33 KB."""
    st_ = G.init_gmm(jax.random.PRNGKey(0), 64, 128)
    assert G.size_bytes(st_, dtype_bytes=2) <= 35 * 1024


def test_distributed_em_matches_single(subproc):
    """psum'd sufficient stats == concatenated-batch update."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import gmm as G
mesh = make_mesh((4,), ('data',))
key = jax.random.PRNGKey(0)
st = G.init_gmm(key, 4, 8)
z = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
z = z / jnp.linalg.norm(z, axis=-1, keepdims=True)
ref = G.em_update(st, z, decay=0.1)
def local(st, z):
    return G.em_update(st, z, decay=0.1, axis_name='data')
out = jax.jit(shard_map(local, mesh=mesh,
    in_specs=(P(), P('data')), out_specs=P(), check_vma=False))(st, z)
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6)
print('distributed EM OK')
""", devices=4)
