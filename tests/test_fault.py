"""Direct unit tests for the fault-tolerance primitives
(``runtime/fault.py``) — previously only exercised through the Trainer;
the cluster (``repro.cluster``) now depends on their exact edge
behavior: repeat-fire suppression, warmup gating, and the trailing
window median."""
import pytest

from repro.runtime.fault import (FailureInjector, RetryPolicy,
                                 StragglerEvent, StragglerMonitor,
                                 TransientFault, is_transient)
from repro.serving import FailureInjector as ServingFailureInjector
from repro.cluster import FailureInjector as ClusterFailureInjector


def test_fault_types_exported_from_serving_and_cluster():
    # one implementation, re-exported where it is consumed
    assert ServingFailureInjector is FailureInjector
    assert ClusterFailureInjector is FailureInjector


# ---------------------------------------------------------------------------
# FailureInjector
# ---------------------------------------------------------------------------

def test_injector_fires_at_exactly_the_named_steps():
    inj = FailureInjector(fail_at=(3, 7))
    for step in range(10):
        if step in (3, 7):
            with pytest.raises(RuntimeError, match=f"step {step}"):
                inj.maybe_fail(step)
        else:
            inj.maybe_fail(step)          # no raise
    assert inj.fired == {3, 7}


def test_injector_suppresses_repeat_fire():
    """A recovered-and-retried step must not die again — the injector
    simulates a node loss, not a permanently poisoned step id."""
    inj = FailureInjector(fail_at=(5,))
    with pytest.raises(RuntimeError):
        inj.maybe_fail(5)
    inj.maybe_fail(5)                     # second pass: suppressed
    assert inj.fired == {5}


def test_injector_empty_never_fires():
    inj = FailureInjector()
    for step in range(20):
        inj.maybe_fail(step)
    assert inj.fired == set()


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------

def test_straggler_warmup_gates_detection():
    """The first ``warmup`` samples can NEVER flag — there is no
    trustworthy median yet, even for an enormous outlier."""
    mon = StragglerMonitor(factor=3.0, window=10, warmup=5)
    for step in range(5):
        # 1000x outliers during warmup: silently recorded
        assert not mon.record(step, 1000.0 if step else 0.001)
    assert mon.events == []
    # the 6th sample compares against the (outlier-polluted) median
    assert mon.record(5, 1e7)
    assert mon.events[-1].step == 5


def test_straggler_flags_only_past_factor_times_median():
    mon = StragglerMonitor(factor=3.0, window=50, warmup=3)
    for step in range(6):
        mon.record(step, 0.1)
    assert not mon.record(6, 0.3)          # == 3x median: NOT a straggler
    assert mon.record(7, 0.3001)           # just past: flagged
    (ev,) = mon.events
    assert isinstance(ev, StragglerEvent)
    assert ev.step == 7 and ev.time_s == 0.3001 and ev.median_s == 0.1


def test_straggler_trailing_window_forgets_old_regime():
    """The median is over the trailing ``window`` samples only: after a
    sustained slowdown the monitor adapts — the new normal stops being
    an anomaly."""
    mon = StragglerMonitor(factor=2.0, window=4, warmup=2)
    for step in range(10):
        mon.record(step, 0.1)              # old fast regime
    assert mon.record(10, 0.5)             # first slow step: flagged
    for step in range(11, 16):
        mon.record(step, 0.5)              # slow becomes the norm
    # the window (4) has rolled entirely onto 0.5s samples: the median
    # adapted, and the same duration no longer flags
    assert not mon.record(16, 0.5)
    assert mon.events[-1].step < 16


def test_straggler_record_returns_true_only_for_this_step():
    """``record``'s return value means THIS step fired, not that some
    earlier event exists — the cluster keys the ring bias off it."""
    mon = StragglerMonitor(factor=2.0, window=8, warmup=2)
    for step in range(4):
        mon.record(step, 0.1)
    assert mon.record(4, 1.0)              # fires
    assert not mon.record(5, 0.1)          # healthy again: False
    assert mon.events and mon.events[-1].step == 4


# ---------------------------------------------------------------------------
# TransientFault / RetryPolicy
# ---------------------------------------------------------------------------

def test_transient_fault_taxonomy():
    assert is_transient(TransientFault("blip"))
    assert not is_transient(RuntimeError("fatal"))
    assert isinstance(TransientFault("x"), RuntimeError)  # old seams catch it


def test_retry_policy_deterministic_backoff_schedule():
    pol = RetryPolicy(max_attempts=5, base_s=0.05, factor=2.0,
                      max_backoff_s=0.15)
    # exponential, capped — pure function of the retry index
    assert [pol.backoff_s(i) for i in (1, 2, 3, 4)] == \
        [0.05, 0.1, 0.15, 0.15]


def test_retry_policy_retries_transient_then_succeeds():
    pol = RetryPolicy(max_attempts=3, base_s=0.05, factor=2.0)
    slept = []
    pol.sleep = slept.append
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault(f"blip {calls['n']}")
        return "ok"

    seen = []
    assert pol.call(flaky, on_retry=lambda a, d, e: seen.append((a, d))) \
        == "ok"
    assert calls["n"] == 3 and pol.retries == 2
    assert seen == [(1, 0.05), (2, 0.1)]     # deterministic schedule
    assert slept == [0.05, 0.1]              # injected sleep, no wall clock
    assert pol.backoff_s_total == pytest.approx(0.15)


def test_retry_policy_exhaustion_reraises_last_fault():
    pol = RetryPolicy(max_attempts=3)

    def always():
        raise TransientFault("still down")

    with pytest.raises(TransientFault, match="still down"):
        pol.call(always)
    assert pol.retries == 2                  # attempts 1..3, two waits


def test_retry_policy_does_not_retry_fatal():
    pol = RetryPolicy(max_attempts=5)
    calls = {"n": 0}

    def fatal():
        calls["n"] += 1
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        pol.call(fatal)
    assert calls["n"] == 1 and pol.retries == 0


# ---------------------------------------------------------------------------
# FailureInjector: transient, probabilistic, hang modes
# ---------------------------------------------------------------------------

def test_injector_transient_at_fires_exactly_n_times():
    inj = FailureInjector(transient_at={4: 2})
    with pytest.raises(TransientFault):
        inj.maybe_fail(4)
    with pytest.raises(TransientFault):
        inj.maybe_fail(4)
    inj.maybe_fail(4)                        # budget spent: clean
    assert inj.transients_fired == 2


def test_injector_transient_sequence_form():
    inj = FailureInjector(transient_at=(2, 5))   # once each
    with pytest.raises(TransientFault):
        inj.maybe_fail(2)
    inj.maybe_fail(2)
    with pytest.raises(TransientFault):
        inj.maybe_fail(5)


def test_injector_probabilistic_is_seed_deterministic():
    def pattern(seed):
        inj = FailureInjector(p_transient=0.3, seed=seed)
        out = []
        for step in range(50):
            try:
                inj.maybe_fail(step)
                out.append(False)
            except TransientFault:
                out.append(True)
        return out

    a, b = pattern(11), pattern(11)
    assert a == b and any(a) and not all(a)  # same seed, same chaos
    assert pattern(12) != a                  # different seed, different


def test_injector_hang_window():
    inj = FailureInjector(hang_from=7)
    assert not inj.hanging(6)
    assert inj.hanging(7) and inj.hanging(100)   # hung is forever
    inj.maybe_fail(7)                        # hanging raises nothing —
    #                                          a hang is NOT an exception


def test_straggler_times_bounded_by_window():
    """Regression: ``times`` grew one entry per step forever — a
    week-long serve leaked memory linearly.  The trailing buffer must
    cap at ``window`` while the warmup gate still counts ALL samples."""
    mon = StragglerMonitor(factor=3.0, window=8, warmup=4)
    for step in range(1000):
        mon.record(step, 0.1)
    assert len(mon.times) == 8               # bounded, not 1000
    assert mon.samples == 1000               # warmup bookkeeping intact
    assert mon.record(1000, 1.0)             # detection still live
