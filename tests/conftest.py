import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_python(code, *, devices=1, timeout=420):
    """Run a snippet in a subprocess with N fake host devices.

    Multi-device tests must NOT set --xla_force_host_platform_device_count
    in this process (smoke tests see 1 device) — so they fork."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS_EXTRA", ""))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={r.returncode})\n--- stdout ---\n"
            f"{r.stdout[-4000:]}\n--- stderr ---\n{r.stderr[-4000:]}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_python


def pytest_collection_modifyitems(config, items):
    """Every test that forks a worker via the ``subproc`` fixture pays
    interpreter + jax re-import + XLA recompile per call — tag them all
    ``slow`` so `-m "not slow"` gives the fast tier-1 gate (TESTING.md)."""
    for item in items:
        if "subproc" in getattr(item, "fixturenames", ()):
            item.add_marker(pytest.mark.slow)
