"""Per-arch smoke tests: reduced same-family configs, one fwd/train step on
CPU, output shapes + finiteness; prefill/decode agreement with forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs, smoke_config
from repro.models import lm

ARCHS = [n for n in list_configs() if n != "streamsplit-audio"]


def _batch(cfg, key, B=2, S=33):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jnp.concatenate([toks[:, 1:], -jnp.ones((B, 1), jnp.int32)], 1)
    if cfg.family == "vlm":
        emb = jax.random.normal(key, (B, S, cfg.d_model))
        return {"embeds": emb, "labels": labels}, toks
    return {"tokens": toks, "labels": labels}, toks


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, axes = lm.init_lm(cfg, key)
    batch, _ = _batch(cfg, key)
    loss, metrics = lm.lm_loss(cfg, params, batch)
    assert jnp.isfinite(loss), arch
    assert metrics["hidden"].shape == (2, 33, cfg.d_model)
    # one gradient step moves the loss
    def f(p):
        return lm.lm_loss(cfg, p, batch)[0]
    g = jax.grad(f)(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    p2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, g)
    assert float(f(p2)) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_lm(cfg, key)
    toks = jax.random.randint(key, (2, 17), 0, cfg.vocab)
    if cfg.family == "vlm":
        emb = jax.random.normal(key, (2, 16, cfg.d_model))
        st, lg = lm.prefill(cfg, params, embeds=emb, max_len=24)
        h, _ = lm.forward(cfg, params, embeds=emb)
        full = lm.logits_from_hidden(cfg, params, h)[:, -1]
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full),
                                   atol=2e-4)
        return
    st, lg = lm.prefill(cfg, params, tokens=toks[:, :16], max_len=24)
    h, _ = lm.forward(cfg, params, tokens=toks)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(lm.logits_from_hidden(cfg, params, h)[:, 15]),
        atol=2e-4)
    lg2, st2 = lm.decode_step(cfg, params, st, toks[:, 16])
    np.testing.assert_allclose(
        np.asarray(lg2),
        np.asarray(lm.logits_from_hidden(cfg, params, h)[:, 16]), atol=2e-4)
    assert int(st2["index"]) == 17


def test_full_configs_match_assignment():
    """The registered FULL configs carry the assigned hyperparameters."""
    spec = {
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for name, (L, d, H, KV, ff, V) in spec.items():
        cfg = get_config(name)
        assert cfg.n_layers == L and cfg.d_model == d
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
        ff_actual = cfg.moe.d_ff_expert if (cfg.moe and name.startswith("kimi")) else cfg.d_ff
        assert ff_actual == ff, name
        assert cfg.vocab == V, name
    m = get_config("mamba2-780m")
    assert m.n_layers == 48 and m.d_model == 1536 and m.ssm.d_state == 128
    assert m.vocab == 50304  # 50280 padded to /128 for 16-way vocab TP
    z = get_config("zamba2-1.2b")
    assert z.ssm.d_state == 64 and z.hybrid_period == 6
    k = get_config("kimi-k2-1t-a32b")
    assert k.moe.n_experts == 384 and k.moe.top_k == 8
    a = get_config("arctic-480b")
    assert a.moe.n_experts == 128 and a.moe.top_k == 2 and a.moe.dense_residual


def test_param_counts_in_expected_range():
    """Full-config param counts via eval_shape (no allocation)."""
    import functools
    expected = {
        "qwen3-1.7b": (1.4e9, 2.2e9),
        "qwen1.5-0.5b": (0.4e9, 0.7e9),
        "gemma2-2b": (2.0e9, 3.3e9),
        "nemotron-4-15b": (13e9, 18e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "musicgen-large": (1.8e9, 2.6e9),  # no cross-attn (stub frontend)
        "llava-next-34b": (30e9, 40e9),
        "arctic-480b": (420e9, 520e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
    }
    for name, (lo, hi) in expected.items():
        cfg = get_config(name)
        shapes = jax.eval_shape(
            functools.partial(lambda c, k: lm.init_lm(c, k)[0], cfg),
            jax.random.PRNGKey(0))
        n = sum(int(x.size) for x in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
