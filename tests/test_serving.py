"""Streaming serving runtime: bounded QoS queues, the deadline-aware
preempting TickScheduler (pure-Python deterministic — every decision
pinned with a fake clock), cross-tick pipelined StreamServer bit-parity
against the sequential gateway, QoS behavior under synthetic overload,
and a threaded ingest-vs-close stress test with a sequential-replay
oracle."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.api import (FrameRequest, QoSClass, StreamSplitGateway,
                       make_policy)
from repro.serving import (DEADLINE_MS, MAX_WAIT_MS, QoSQueues,
                           QueueFullError, RateLimitError, SchedulerCfg,
                           StreamServer, TickScheduler, TokenBucket)
from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder

# tiny deep-ish encoder: 2 split points -> up to 3 buckets per tick,
# cheap enough that threaded tests stay fast
CFG = AudioEncCfg(widths=(8, 8), strides=(1, 1), n_mels=8, frames=8,
                  d_embed=16, groups=2)
L = CFG.n_blocks
I, S, B = QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK


@pytest.fixture(scope="module")
def params():
    return init_audio_encoder(CFG, jax.random.PRNGKey(0))


class FakeClock:
    """Manual clock: tests advance ``t`` explicitly, so every queue
    wait, deadline decision and SyncEvent timestamp is exact."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class QuantilePolicy:
    """Deterministic frame-content policy: u quantile -> split index.
    Position-independent, so a replayed schedule reproduces every k."""

    def __init__(self, L):
        self.L = L

    def decide(self, obs_batch):
        return np.clip((obs_batch[:, 0] * (self.L + 1)).astype(np.int64),
                       0, self.L)


def _mel(rng):
    return rng.normal(size=(CFG.frames, CFG.n_mels)).astype(np.float32)


def _req(rng, t, u=None):
    return FrameRequest(t=t, mel=_mel(rng),
                        u=float(rng.random() if u is None else u))


def _gw(params, *, capacity=8, clock=None, policy=None, **kw):
    return StreamSplitGateway(
        CFG, params, policy=policy or QuantilePolicy(L), capacity=capacity,
        window=8, qos_reserve=0,
        **({"clock": clock} if clock is not None else {}), **kw)


# ---------------------------------------------------------------------------
# Queues: bounded, typed backpressure, conservation counters
# ---------------------------------------------------------------------------

def test_queue_bounded_rejects_with_typed_error():
    qs = QoSQueues(maxlen=2)
    f = FrameRequest(t=0, mel=np.zeros((2, 2), np.float32))
    qs.submit(0, f, B, now=0.0, deadline_s=1.0)
    qs.submit(0, f, B, now=0.0, deadline_s=1.0)
    with pytest.raises(QueueFullError) as ei:
        qs.submit(0, f, B, now=0.0, deadline_s=1.0)
    assert ei.value.qos is B and ei.value.maxlen == 2
    # the refusal is counted; the accepted count is untouched
    c = qs.counters()
    assert c["rejected"]["bulk"] == 1 and c["submitted"]["bulk"] == 2
    # other classes unaffected by a full bulk queue
    qs.submit(0, f, I, now=0.0, deadline_s=1.0)
    assert qs.depths() == {"interactive": 1, "standard": 0, "bulk": 2}


def test_queue_per_class_maxlen_override():
    qs = QoSQueues(maxlen=1, maxlens={B: 3})
    f = FrameRequest(t=0, mel=np.zeros((2, 2), np.float32))
    for _ in range(3):
        qs.submit(0, f, B, now=0.0, deadline_s=1.0)
    qs.submit(0, f, I, now=0.0, deadline_s=1.0)
    with pytest.raises(QueueFullError):
        qs.submit(0, f, I, now=0.0, deadline_s=1.0)


def test_requeue_front_preserves_identity_and_counts():
    qs = QoSQueues(maxlen=4)
    f = FrameRequest(t=0, mel=np.zeros((2, 2), np.float32))
    a = qs.submit(0, f, B, now=1.0, deadline_s=3.0)
    qs.submit(1, f, B, now=2.0, deadline_s=4.0)
    with qs.cond:
        got = qs.pop_locked(B)
        assert got is a                      # FIFO
        qs.requeue_front_locked(got)
        again = qs.pop_locked(B)
    assert again is a and again.preemptions == 1
    assert again.enq_s == 1.0 and again.deadline_s == 3.0   # untouched
    c = qs.counters()
    assert c["preempted"]["bulk"] == c["requeued"]["bulk"] == 1


# ---------------------------------------------------------------------------
# TickScheduler: priority, deadline monotonicity, preemption conservation
# (pure-Python deterministic — seeded sweeps in the repo's property style)
# ---------------------------------------------------------------------------

def _rand_submits(qs, cfg, rng, now, n, p=None):
    classes = [I, S, B]
    out = []
    for _ in range(n):
        qos = classes[rng.choice(3, p=p)]
        try:
            out.append(qs.submit(int(rng.integers(8)),
                                 FrameRequest(t=0, mel=np.zeros((1, 1),
                                                               np.float32)),
                                 qos, now=now,
                                 deadline_s=now + cfg.deadline_s(qos)))
        except QueueFullError:
            pass
    return out


@pytest.mark.parametrize("seed", range(6))
def test_scheduler_priority_and_deadline_monotonicity(seed):
    """No admitted BULK frame while a higher-class frame still waits
    (absent aging — waits here never reach ``max_wait_ms``); within a
    class, admission follows nondecreasing deadlines inside a batch
    (the final sort is by arrival), and EDF holds across ticks globally
    for INTERACTIVE/BULK (plain FIFO) but per *session* for STANDARD —
    DRR may serve tenant B's older frame after tenant A's newer one,
    that is exactly the fairness trade."""
    rng = np.random.default_rng(seed)
    cfg = SchedulerCfg(max_batch=4)
    qs, sched = QoSQueues(maxlen=64), TickScheduler(cfg)
    now = 0.0
    last_deadline = {I: -np.inf, B: -np.inf}
    last_std = {}                            # sid -> last deadline
    for _ in range(12):
        _rand_submits(qs, cfg, rng, now, int(rng.integers(0, 9)))
        if rng.random() < 0.5:              # sometimes stage early
            sched.stage(qs)
            now += 0.01
            _rand_submits(qs, cfg, rng, now, int(rng.integers(0, 5)))
        batch = sched.admit(qs, now)
        assert len(batch) <= cfg.max_batch
        assert not any(f.promoted for f in batch), "no aging at these waits"
        if any(f.qos is B for f in batch):
            # the preemption pass emptied every higher-class queue first
            assert qs.depths()["interactive"] == 0
            assert qs.depths()["standard"] == 0
        seen = {q: -np.inf for q in QoSClass}
        for f in batch:
            assert f.deadline_s >= seen[f.qos], "EDF order inside a tick"
            seen[f.qos] = f.deadline_s
            if f.qos is S:
                assert f.deadline_s >= last_std.get(f.sid, -np.inf), \
                    "per-session EDF order across ticks (STANDARD)"
                last_std[f.sid] = f.deadline_s
        for q in (I, B):
            if seen[q] > -np.inf:
                assert seen[q] >= last_deadline[q], "EDF order across ticks"
                last_deadline[q] = seen[q]
        now += 0.02


@pytest.mark.parametrize("seed", range(6))
def test_scheduler_preemption_conserves_frames(seed):
    """Under random overload every accepted frame is admitted exactly
    once or still queued/staged: re-queued frames are re-served, never
    dropped, and ``preempted == requeued`` throughout."""
    rng = np.random.default_rng(100 + seed)
    cfg = SchedulerCfg(max_batch=3)
    qs, sched = QoSQueues(maxlen=64), TickScheduler(cfg)
    admitted, accepted, now = [], 0, 0.0
    for _ in range(20):
        # arrivals before staging skew BULK; arrivals in the
        # stage->admit window skew INTERACTIVE — the preempting mix
        accepted += len(_rand_submits(qs, cfg, rng, now,
                                      int(rng.integers(0, 7)),
                                      p=[0.15, 0.15, 0.7]))
        sched.stage(qs)
        accepted += len(_rand_submits(qs, cfg, rng, now + 0.01,
                                      int(rng.integers(0, 4)),
                                      p=[0.7, 0.15, 0.15]))
        admitted.extend(sched.admit(qs, now + 0.02))
        c = qs.counters()
        assert c["preempted"] == c["requeued"]
        with qs.cond:
            waiting = qs.pending_locked()
        assert len(admitted) + waiting + len(sched.staged) == accepted
        now += 0.05
    # drain completely: conservation must close the books
    for _ in range(64):
        admitted.extend(sched.admit(qs, now))
    assert len(admitted) == accepted
    assert len({id(f) for f in admitted}) == accepted   # no double-serve
    c = qs.counters()
    assert sum(c["preempted"].values()) > 0, "overload must preempt"
    assert all(v == 0 for v in c["preempted"].values()
               if v != c["preempted"]["bulk"]), "only BULK is preemptible"


def test_scheduler_preempts_staged_bulk_for_interactive():
    """The pipelining window, explicitly: BULK frames staged under the
    in-flight tick get bumped (to the FRONT of their queue, deadlines
    intact) when INTERACTIVE frames arrive before launch."""
    cfg = SchedulerCfg(max_batch=2)
    qs, sched = QoSQueues(maxlen=8), TickScheduler(cfg)
    f = FrameRequest(t=0, mel=np.zeros((1, 1), np.float32))
    b1 = qs.submit(0, f, B, now=0.0, deadline_s=2.0)
    b2 = qs.submit(1, f, B, now=0.0, deadline_s=2.0)
    assert sched.stage(qs) == 2        # tick t in flight, both staged
    i1 = qs.submit(2, f, I, now=0.1, deadline_s=0.15)
    batch = sched.admit(qs, 0.2)
    assert batch == [i1, b1]                # newest-staged BULK was bumped
    assert b2.preemptions == 1
    c = qs.counters()
    assert c["preempted"]["bulk"] == 1 and c["requeued"]["bulk"] == 1
    assert sched.admit(qs, 0.3) == [b2]     # ... and served next tick
    misses = sched.deadline_misses
    assert misses["interactive"] == 1       # 0.2 > 0.15: counted at admit
    assert misses["bulk"] == 0


def test_scheduler_no_preemption_when_disabled():
    cfg = SchedulerCfg(max_batch=1, preempt_bulk=False)
    qs, sched = QoSQueues(maxlen=8), TickScheduler(cfg)
    f = FrameRequest(t=0, mel=np.zeros((1, 1), np.float32))
    b = qs.submit(0, f, B, now=0.0, deadline_s=9.0)
    sched.stage(qs)
    qs.submit(1, f, I, now=0.0, deadline_s=1.0)
    assert sched.admit(qs, 0.0) == [b]
    assert qs.counters()["preempted"]["bulk"] == 0


# ---------------------------------------------------------------------------
# SchedulerCfg: partial overrides merge with defaults (regression)
# ---------------------------------------------------------------------------

def test_scheduler_cfg_partial_override_merges_defaults():
    """``SchedulerCfg(deadline_ms={BULK: ...})`` used to lose the other
    classes' budgets and KeyError on their first submit."""
    cfg = SchedulerCfg(deadline_ms={B: 5000.0})
    assert cfg.deadline_s(B) == 5.0
    assert cfg.deadline_s(I) == DEADLINE_MS[I] * 1e-3   # no KeyError
    assert cfg.deadline_s(S) == DEADLINE_MS[S] * 1e-3
    cfg2 = SchedulerCfg(max_wait_ms={B: 100.0})
    assert cfg2.max_wait_s(B) == 0.1
    assert cfg2.max_wait_s(S) == MAX_WAIT_MS[S] * 1e-3
    assert cfg2.max_wait_s(I) is None                   # default: no aging
    # merged dicts are per-instance: mutations must not leak across cfgs
    cfg.deadline_ms[I] = 1.0
    assert SchedulerCfg().deadline_ms[I] == DEADLINE_MS[I]
    with pytest.raises(ValueError):
        SchedulerCfg(promote_quota=0.0)
    with pytest.raises(ValueError):
        SchedulerCfg(drr_quantum=0.0)


def test_server_partial_deadline_override_serves_other_classes(params):
    """End-to-end regression: a server configured with only a BULK
    deadline budget must still accept INTERACTIVE/STANDARD submits."""
    srv = _server(params, max_batch=2, deadline_ms={B: 5000.0})
    rng = np.random.default_rng(20)
    sid_i = srv.open_session(qos=I).sid
    sid_b = srv.open_session(qos=B).sid
    srv.submit(sid_i, _req(rng, 0))          # KeyError before the fix
    srv.submit(sid_b, _req(rng, 0))
    while srv.served_total < 2:
        srv.step()


# ---------------------------------------------------------------------------
# Aging/promotion: bounded BULK wait under sustained higher-class load
# ---------------------------------------------------------------------------

def _zf():
    return FrameRequest(t=0, mel=np.zeros((1, 1), np.float32))


def test_scheduler_bulk_aging_bounds_max_wait_under_flood():
    """Sustained INTERACTIVE load saturates every tick; without aging
    the BULK frame starves forever, with aging it is admitted within
    ``max_wait_ms`` + one tick period, promotion-immune to preemption."""
    # (a) aging ON: bounded
    cfg = SchedulerCfg(max_batch=2, max_wait_ms={B: 500.0})
    qs, sched = QoSQueues(maxlen=64), TickScheduler(cfg)
    bulk = qs.submit(0, _zf(), B, now=0.0, deadline_s=2.0)
    now, admitted_at = 0.0, None
    for _ in range(20):
        for _ in range(2):                   # flood: 2 fresh I per tick
            qs.submit(1, _zf(), I, now=now, deadline_s=now + 0.05)
        if bulk in sched.admit(qs, now):
            admitted_at = now
            break
        now += 0.1
    assert admitted_at is not None, "BULK starved despite aging"
    assert admitted_at <= 0.5 + 0.1 + 1e-9, "bound: max_wait + 1 tick"
    assert bulk.promoted and sched.promoted["bulk"] == 1
    assert qs.counters()["preempted"]["bulk"] == 0  # promotion stuck
    # (b) aging OFF (the old scheduler): starved outright
    cfg = SchedulerCfg(max_batch=2, max_wait_ms={B: None})
    qs, sched = QoSQueues(maxlen=64), TickScheduler(cfg)
    bulk = qs.submit(0, _zf(), B, now=0.0, deadline_s=2.0)
    now = 0.0
    for _ in range(20):
        for _ in range(2):
            qs.submit(1, _zf(), I, now=now, deadline_s=now + 0.05)
        assert bulk not in sched.admit(qs, now)
        now += 0.1
    assert sched.promoted["bulk"] == 0


def test_scheduler_promotion_quota_caps_aged_share():
    """The aging lane cannot invert the starvation: promoted frames
    take at most ``promote_quota`` of a batch, fresh INTERACTIVE
    traffic keeps the rest."""
    cfg = SchedulerCfg(max_batch=4, max_wait_ms={B: 100.0},
                       promote_quota=0.5)
    qs, sched = QoSQueues(maxlen=64), TickScheduler(cfg)
    for i in range(8):                       # deep, long-aged BULK backlog
        qs.submit(i, _zf(), B, now=0.0, deadline_s=10.0)
    for _ in range(4):                       # fresh INTERACTIVE burst
        qs.submit(9, _zf(), I, now=1.0, deadline_s=1.05)
    batch = sched.admit(qs, 1.0)
    assert len(batch) == 4
    assert sum(1 for x in batch if x.promoted) == 2   # quota = 0.5 * 4
    assert sum(1 for x in batch if x.qos is I) == 2
    # the promoted frames are the OLDEST aged ones (FIFO drain -> bound)
    assert sorted(x.seq for x in batch if x.promoted) == [0, 1]


def test_scheduler_promote_slots_is_at_least_one():
    assert SchedulerCfg(max_batch=1, promote_quota=0.5).promote_slots == 1
    assert SchedulerCfg(max_batch=8, promote_quota=0.5).promote_slots == 4


# ---------------------------------------------------------------------------
# DRR: weighted fair sharing between STANDARD tenants
# ---------------------------------------------------------------------------

def test_scheduler_drr_fair_share_between_standard_tenants():
    """A chatty tenant's deep backlog (submitted FIRST — plain FIFO
    would drain it before touching anyone else) cannot monopolize the
    STANDARD slots: while every tenant stays backlogged, service is
    near-equal."""
    cfg = SchedulerCfg(max_batch=4)
    qs, sched = QoSQueues(maxlen=128), TickScheduler(cfg)
    for _ in range(40):                      # chatty tenant 0 floods first
        qs.submit(0, _zf(), S, now=0.0, deadline_s=0.25)
    for _ in range(10):
        qs.submit(1, _zf(), S, now=0.0, deadline_s=0.25)
        qs.submit(2, _zf(), S, now=0.0, deadline_s=0.25)
    served = {0: 0, 1: 0, 2: 0}
    for _ in range(5):                       # 20 slots, all 3 backlogged
        for qf in sched.admit(qs, 0.1):
            served[qf.sid] += 1
    assert sum(served.values()) == 20
    assert served[1] >= 6 and served[2] >= 6, served
    assert served[0] <= 8, f"chatty tenant monopolized: {served}"
    # once the modest tenants drain, the chatty backlog gets every slot
    for _ in range(20):
        for qf in sched.admit(qs, 0.2):
            served[qf.sid] += 1
    assert served == {0: 40, 1: 10, 2: 10}   # conservation: all served


def test_scheduler_drr_weight_biases_share_2_to_1():
    """``QueuedFrame.weight`` is a real weight: a weight-2 tenant gets
    exactly twice the slots of a weight-1 tenant while both are
    backlogged (quantum accounting, not probabilistic)."""
    cfg = SchedulerCfg(max_batch=3)
    qs, sched = QoSQueues(maxlen=128), TickScheduler(cfg)
    for _ in range(30):
        qs.submit(0, _zf(), S, now=0.0, deadline_s=0.25, weight=2.0)
        qs.submit(1, _zf(), S, now=0.0, deadline_s=0.25, weight=1.0)
    served = {0: 0, 1: 0}
    for _ in range(6):                       # 18 slots, both backlogged
        for qf in sched.admit(qs, 0.1):
            served[qf.sid] += 1
    assert served[0] == 2 * served[1], served


# ---------------------------------------------------------------------------
# Shedding: expired frames dropped visibly, bit-reproducibly
# ---------------------------------------------------------------------------

def test_scheduler_shed_expired_visible_and_deterministic():
    """Frames whose deadline expired past the horizon are dropped AND
    counted (shed counter, deadline miss, terminal wait sample); the
    whole decision replayed under the same fake clock is identical."""
    cfg = SchedulerCfg(max_batch=2, deadline_ms={B: 100.0},
                       shed_horizon_ms=200.0)
    runs = []
    for _ in range(2):
        qs, sched = QoSQueues(maxlen=64), TickScheduler(cfg)
        for i in range(6):
            t = i * 0.05
            qs.submit(i, _zf(), B, now=t, deadline_s=t + 0.1)
        batch = sched.admit(qs, 0.45)
        shed = sched.pop_shed()
        runs.append(([f.seq for f in batch], [f.seq for f in shed],
                     dict(sched.deadline_misses), qs.counters(),
                     sched.wait_percentiles()))
    assert runs[0] == runs[1], "shed decisions must be bit-reproducible"
    batch_seqs, shed_seqs, misses, counters, _ = runs[0]
    # deadlines .10/.15/.20/.25/.30/.35; shed iff now > deadline + .2
    assert shed_seqs == [0, 1, 2]
    assert batch_seqs == [3, 4]              # admitted (late: misses)
    assert counters["shed_expired"]["bulk"] == 3
    assert misses["bulk"] == 5               # 3 starved-in-queue + 2 late
    assert sched.pop_shed() == []            # consumed
    # conservation: 6 submitted == 2 admitted + 3 shed + 1 still queued
    assert qs.depths()["bulk"] == 1


def test_scheduler_no_shed_when_horizon_none():
    cfg = SchedulerCfg(max_batch=1, deadline_ms={B: 100.0})
    qs, sched = QoSQueues(maxlen=8), TickScheduler(cfg)
    qs.submit(0, _zf(), B, now=0.0, deadline_s=0.1)
    batch = sched.admit(qs, 1e9)             # absurdly late: still served
    assert len(batch) == 1 and sched.pop_shed() == []
    assert qs.counters()["shed_expired"]["bulk"] == 0


# ---------------------------------------------------------------------------
# TokenBucket: deterministic admission control
# ---------------------------------------------------------------------------

def test_token_bucket_deterministic_refill():
    tb = TokenBucket(10.0, 2, now=0.0)       # 10 tokens/s, burst 2
    assert tb.try_take(0.0) and tb.try_take(0.0)
    assert not tb.try_take(0.0)
    assert tb.retry_after_s(0.0) == pytest.approx(0.1)
    assert tb.try_take(0.1)                  # exactly one token refilled
    assert not tb.try_take(0.1)
    tb.give_back()                           # refund (queue refused it)
    assert tb.try_take(0.1)
    assert tb.try_take(10.0) and tb.try_take(10.0)   # capped at burst
    assert not tb.try_take(10.0)
    with pytest.raises(ValueError):
        TokenBucket(0.0, 2)
    with pytest.raises(ValueError):
        TokenBucket(1.0, 0)


# ---------------------------------------------------------------------------
# StreamServer (stepped, fake clock): parity, pipelining, QoS overload
# ---------------------------------------------------------------------------

def _server(params, *, capacity=8, max_batch=8, clock=None, refine=0,
            deadline_ms=None, queue_maxlen=256, queue_maxlens=None,
            head=None, rate_limit=None, sched_kw=None, **gw_kw):
    kw = dict(refine_every=refine, **gw_kw)
    if head:
        kw.update(head_init=head[0], head_apply=head[1])
    gw = _gw(params, capacity=capacity, clock=clock, **kw)
    cfg = SchedulerCfg(max_batch=max_batch,
                       **({"deadline_ms": deadline_ms} if deadline_ms
                          else {}),
                       **(sched_kw or {}))
    return StreamServer(gw, cfg=cfg, queue_maxlen=queue_maxlen,
                        queue_maxlens=queue_maxlens, rate_limit=rate_limit)


def test_server_pipelined_serving_bit_matches_sequential_gateway(params):
    """THE parity contract: replaying the server's admitted schedule
    through a plain sequential ``submit``/``tick`` gateway reproduces
    every embedding bit-for-bit — and the pipelined server really did
    overlap ticks (``pipelined_ticks`` > 0, one device sync per tick)."""
    rng = np.random.default_rng(0)
    srv = _server(params, max_batch=6)
    sids = [srv.open_session(qos=q).sid for q in (I, S, B, S)]
    frames = {}
    for t in range(5):
        for sid in sids:
            frames[(sid, t)] = _req(rng, t)
            srv.submit(sid, frames[(sid, t)])
        srv.step()
    while srv.stats().frames_served != srv.stats().frames_submitted:
        srv.step()
    results = {(r.sid, r.t): r for r in srv.drain_results()}
    st = srv.stats()
    assert st.ticks >= 5 and st.pipelined_ticks > 0
    assert st.gateway.device_syncs_per_tick == 1
    assert st.gateway.d2h_copies_per_tick == 1
    # replay the EXACT admitted schedule sequentially
    gw = _gw(params)
    replay_sids = [gw.open_session(qos=q).sid for q in (I, S, B, S)]
    assert replay_sids == sids
    for tick in srv.schedule():
        for sid, t in tick:
            gw.submit(sid, frames[(sid, t)])
        for r in gw.tick():
            ref = results[(r.sid, r.t)]
            np.testing.assert_array_equal(r.z, ref.z)
            assert r.k == ref.k and r.wire_bytes == ref.wire_bytes


def test_server_refine_order_matches_sequential_gateway(params):
    """Pipelining must not reorder learning: with ``refine_every`` set
    the server drains its pipeline before a refine tick, so refine
    rounds and losses are bitwise those of the sequential gateway."""
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (CFG.d_embed, 4))}

    def head_apply(p, z):
        return z @ p["w"]

    rng = np.random.default_rng(1)
    srv = _server(params, capacity=2, max_batch=4, refine=2,
                  head=(head_init, head_apply))
    gw = _gw(params, capacity=2, refine_every=2, head_init=head_init,
             head_apply=head_apply)
    ssid = srv.open_session(qos=S).sid
    gsid = gw.open_session(qos=S).sid
    assert ssid == gsid
    for t in range(6):
        f = _req(rng, t)
        f = FrameRequest(t=t, mel=f.mel, u=f.u, label=t % 4)
        srv.submit(ssid, f)
        srv.step()
        gw.submit(gsid, f)
        gw.tick()
    while srv.stats().ticks < 6:
        srv.step()
    ss, gs = srv.stats().gateway, gw.stats()
    assert ss.refine_rounds == gs.refine_rounds == 3
    assert ss.last_refine_loss == gs.last_refine_loss   # bitwise


def test_server_overload_qos_isolation_and_conservation(params):
    """Synthetic 2x overload under a fake clock: INTERACTIVE p95 queue
    wait stays below BULK p50, BULK frames get preempted but conserved
    (requeued == preempted; submitted == served + depth at quiescence),
    and BULK deadline misses are counted while INTERACTIVE never
    misses."""
    clock = FakeClock()
    rng = np.random.default_rng(2)
    srv = _server(params, capacity=8, max_batch=4, clock=clock,
                  deadline_ms={I: 500.0, S: 500.0, B: 150.0})
    sids = {I: [srv.open_session(qos=I).sid],
            S: [srv.open_session(qos=S).sid],
            B: [srv.open_session(qos=B).sid for _ in range(6)]}
    # offered load 8 frames/round vs capacity 4/tick = 2x
    for t in range(10):
        srv.submit(sids[I][0], _req(rng, t))
        srv.submit(sids[S][0], _req(rng, t))
        for sid in sids[B]:
            srv.submit(sid, _req(rng, t))
        clock.t += 0.1
        srv.step()
    st = srv.stats()
    assert sum(st.preempted.values()) == st.preempted["bulk"] > 0
    assert st.requeued == st.preempted
    # backlog is all BULK: the latency classes never queued up
    assert st.queue_depth["interactive"] == st.queue_depth["standard"] == 0
    assert st.queue_depth["bulk"] > 0
    assert st.deadline_misses["bulk"] > 0
    assert st.deadline_misses["interactive"] == 0
    w = st.queue_wait_ms
    assert w["interactive"]["p95"] < w["bulk"]["p50"]
    # drain: conservation closes the books per class
    while True:
        st = srv.stats()
        if st.frames_served == st.frames_submitted:
            break
        clock.t += 0.1
        srv.step()
    assert all(st.queue_depth[c] == 0 for c in st.queue_depth)
    assert st.frames_served["bulk"] == st.frames_submitted["bulk"] == 60


def test_server_bounded_queue_backpressure(params):
    srv = _server(params, capacity=2, max_batch=2, queue_maxlen=3)
    sid = srv.open_session(qos=B).sid
    rng = np.random.default_rng(3)
    for t in range(3):
        srv.submit(sid, _req(rng, t))
    with pytest.raises(QueueFullError):
        srv.submit(sid, _req(rng, 3))
    st = srv.stats()
    assert st.rejected_full["bulk"] == 1
    assert st.frames_submitted["bulk"] == 3   # the rejected frame never counted
    while srv.stats().frames_served["bulk"] < 3:
        srv.step()


def test_server_close_session_drains_then_evicts(params):
    srv = _server(params, capacity=4, max_batch=2)
    rng = np.random.default_rng(4)
    a = srv.open_session(qos=S).sid
    b = srv.open_session(qos=S).sid
    for t in range(3):
        srv.submit(a, _req(rng, t))
    srv.submit(b, _req(rng, 0))
    srv.close_session(a)                    # stepped mode: drains inline
    with pytest.raises(KeyError):
        srv.submit(a, _req(rng, 9))
    st = srv.stats()
    assert st.frames_served["standard"] >= 3   # a's frames all served
    assert srv.gateway.stats().sessions_closed == 1
    # b still serves
    srv.submit(b, _req(rng, 1))
    while srv.stats().frames_served["standard"] < 5:
        srv.step()


def test_server_requires_overlapped_gateway(params):
    with pytest.raises(ValueError):
        StreamServer(_gw(params, overlap=False))


def test_server_pipeline_false_is_sequential_baseline(params):
    """``pipeline=False`` collects tick t before launching t+1: same
    results, zero pipelined ticks — the measured baseline knob."""
    rng = np.random.default_rng(6)
    srv = StreamServer(_gw(params, capacity=2),
                       cfg=SchedulerCfg(max_batch=2), pipeline=False)
    sid = srv.open_session(qos=S).sid
    for t in range(4):
        srv.submit(sid, _req(rng, t))
        srv.step()
    while srv.served_total < 4:
        srv.step()
    st = srv.stats()
    assert st.pipelined_ticks == 0 and st.ticks >= 4
    assert st.gateway.device_syncs_per_tick == 1


def test_server_step_counts_refine_drain_frames(params):
    """step()'s return includes frames delivered by a refine-forced
    pipeline drain, not just the trailing collect."""
    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (CFG.d_embed, 4))}

    def head_apply(p, z):
        return z @ p["w"]

    rng = np.random.default_rng(7)
    srv = _server(params, capacity=2, max_batch=2, refine=2,
                  head=(head_init, head_apply))
    sid = srv.open_session(qos=S).sid
    delivered = 0
    for t in range(6):
        srv.submit(sid, FrameRequest(t=t, mel=_mel(rng), label=0))
        delivered += srv.step()
    while srv.stats().ticks < 6:
        delivered += srv.step()
    assert delivered == 6 == srv.served_total


def test_serving_loop_fault_fails_fast_at_callers(params):
    """If the serving loop dies on an internal error, producers and
    progress pollers raise the stored fault instead of hanging."""
    import time as _time
    srv = _server(params, capacity=2, max_batch=2)
    sid = srv.open_session(qos=S).sid
    boom = RuntimeError("injected tick failure")

    def bad_launch(*a, **k):
        raise boom

    srv.gateway.tick_launch = bad_launch
    rng = np.random.default_rng(10)
    with pytest.raises(RuntimeError):
        with srv:
            srv.submit(sid, _req(rng, 0))
            deadline = _time.time() + 30
            while True:
                assert _time.time() < deadline, "fault never surfaced"
                try:
                    srv.served_total
                except RuntimeError as e:
                    assert e.__cause__ is boom
                    break
                _time.sleep(0.01)
            with pytest.raises(RuntimeError):
                srv.submit(sid, _req(rng, 1))
        # __exit__ -> stop() re-raises the fault (the outer pytest.raises)


def test_gateway_rejects_out_of_order_collect(params):
    gw = _gw(params, capacity=2)
    sid = gw.open_session().sid
    rng = np.random.default_rng(11)
    gw.submit(sid, _req(rng, 0))
    p0 = gw.tick_launch()
    gw.submit(sid, _req(rng, 1))
    p1 = gw.tick_launch()
    with pytest.raises(RuntimeError):
        gw.tick_collect(p1)                  # out of launch order
    gw.tick_collect(p0)
    gw.tick_collect(p1)                      # in order: fine
    with pytest.raises(RuntimeError):
        gw.tick_collect(p1)                  # double collect


def test_on_result_exception_does_not_kill_serving(params):
    """A raising user callback is isolated: serving continues, every
    frame is still delivered to the (faulty) callback."""
    seen = []

    def bad_cb(r):
        seen.append(r.t)
        raise RuntimeError("user bug")

    srv = StreamServer(_gw(params, capacity=2),
                       cfg=SchedulerCfg(max_batch=2), on_result=bad_cb)
    rng = np.random.default_rng(9)
    sid = srv.open_session(qos=S).sid
    for t in range(3):
        srv.submit(sid, _req(rng, t))
        srv.step()
    while srv.served_total < 3:
        srv.step()
    assert sorted(seen) == [0, 1, 2]
    assert srv.drain_results() == []    # callback mode: no buffering


def test_close_session_from_on_result_callback_does_not_deadlock(params):
    """close_session on the serving thread (e.g. closing a session from
    its own result callback) must defer to _process_closes instead of
    waiting on an event only that thread can set."""
    holder = {}

    def on_result(r):
        holder["srv"].close_session(r.sid)   # runs ON the serving thread

    gw = _gw(params, capacity=2)
    srv = StreamServer(gw, cfg=SchedulerCfg(max_batch=2),
                       on_result=on_result)
    holder["srv"] = srv
    rng = np.random.default_rng(8)
    sid = srv.open_session(qos=S).sid
    with srv:
        srv.submit(sid, _req(rng, 0))
        deadline = __import__("time").time() + 30
        while srv.gateway.stats().sessions_closed < 1:
            assert __import__("time").time() < deadline, "close never ran"
            __import__("time").sleep(0.01)
    assert srv.served_total == 1


def test_server_fake_clock_queue_waits_are_exact(params):
    """The whole stack on one fake clock: queue waits and SyncEvent
    timestamps come out exact, covering the async tick path (the clock
    threading satellite)."""
    clock = FakeClock()
    srv = _server(params, capacity=2, max_batch=2, clock=clock)
    rng = np.random.default_rng(5)
    sid = srv.open_session(qos=S).sid
    # charging -> the lazy-sync weights push fires on frame 0
    srv.submit(sid, FrameRequest(t=0, mel=_mel(rng), charging=True))
    clock.t = 0.25
    srv.step()                              # admitted + launched at t=0.25
    srv.step()
    w = srv.stats().queue_wait_ms["standard"]
    assert w["p50"] == w["max"] == 250.0
    assert srv.stats().gateway.last_tick_ms == 0.0   # no clock advance in tick
    # the async tick stamped the SyncEvent off the injected clock
    events = srv.gateway._sessions[sid].sync.events
    assert [e.kind for e in events] == ["weights"]
    assert events[0].at_s == 0.25


# ---------------------------------------------------------------------------
# Server: aging bound, shedding, rate limits (all on the fake clock)
# ---------------------------------------------------------------------------

def _conservation(st):
    """The extended invariant, per class, at THIS snapshot."""
    for c in st.frames_submitted:
        assert st.frames_submitted[c] == (
            st.frames_served[c] + st.queue_depth[c]
            + st.in_flight[c] + st.shed_expired[c]), (c, st)
    assert st.preempted == st.requeued


def test_server_bulk_bounded_wait_under_sustained_flood(params):
    """The whole starvation fix end-to-end: sustained INTERACTIVE load
    saturates every tick, yet the BULK frame is served with its queue
    wait exactly ``max_wait_ms`` on the fake clock."""
    clock = FakeClock()
    srv = _server(params, capacity=4, max_batch=2, clock=clock,
                  deadline_ms={B: 10_000.0},
                  sched_kw={"max_wait_ms": {B: 300.0}})
    rng = np.random.default_rng(21)
    sid_i = srv.open_session(qos=I).sid
    sid_b = srv.open_session(qos=B).sid
    srv.submit(sid_b, _req(rng, 0))
    for t in range(8):
        srv.submit(sid_i, _req(rng, 2 * t))
        srv.submit(sid_i, _req(rng, 2 * t + 1))
        srv.step()
        clock.t += 0.1
        _conservation(srv.stats())
    st = srv.stats()
    assert st.frames_served["bulk"] == 1, "BULK starved despite aging"
    assert st.promoted["bulk"] == 1
    # promoted at the first stage() after aging past 300 ms (t=0.3),
    # admitted at the next tick (t=0.4): the documented bound is
    # max_wait + one stage->admit window, and on the fake clock it is
    # EXACT — preempted on ticks 1-3, promotion-immune afterwards
    assert st.queue_wait_ms["bulk"]["max"] == 400.0
    assert st.preempted["bulk"] == st.requeued["bulk"] == 3


def test_server_shed_visible_conservation_and_close(params):
    """Expired frames are dropped VISIBLY: counted in ``shed_expired``
    and ``deadline_misses`` (starved-in-queue misses used to be
    invisible), the extended conservation invariant holds at every
    snapshot, and a draining close completes once every accepted frame
    is served or shed."""
    clock = FakeClock()
    srv = _server(params, capacity=2, max_batch=2, clock=clock,
                  deadline_ms={B: 100.0},
                  sched_kw={"shed_horizon_ms": 200.0,
                            "max_wait_ms": {B: None}})
    rng = np.random.default_rng(22)
    sid = srv.open_session(qos=B).sid
    for t in range(6):
        srv.submit(sid, _req(rng, t))
    srv.step()                # admits 2, stages 2, 2 still queued
    _conservation(srv.stats())
    clock.t = 10.0            # everything queued is long past deadline
    srv.step()                # shed pass drops the 2 QUEUED frames
    _conservation(srv.stats())
    while srv.stats().in_flight != {c: 0 for c in ("interactive",
                                                   "standard", "bulk")}:
        srv.step()
    st = srv.stats()
    assert st.shed_expired["bulk"] == 2
    assert st.frames_served["bulk"] == 4      # 2 early + 2 staged (late)
    assert st.deadline_misses["bulk"] >= 4    # 2 shed + 2 admitted late
    _conservation(st)
    srv.close_session(sid)                    # completes: served + shed
    assert srv.gateway.stats().sessions_closed == 1


def test_server_rate_limit_token_bucket(params):
    """Per-session admission control on the fake clock: refusals are
    typed, counted per class, never enter ``frames_submitted``, and a
    queue-refused frame refunds its token."""
    clock = FakeClock()
    srv = _server(params, capacity=4, max_batch=2, clock=clock,
                  queue_maxlen=2)
    rng = np.random.default_rng(23)
    sid = srv.open_session(qos=S, rate_limit=(10.0, 2)).sid
    free = srv.open_session(qos=S).sid       # inherits server default: none
    srv.submit(sid, _req(rng, 0))
    srv.submit(sid, _req(rng, 1))            # burst of 2 OK
    with pytest.raises(RateLimitError) as ei:
        srv.submit(sid, _req(rng, 2))
    assert ei.value.retry_after_s == pytest.approx(0.1)
    st = srv.stats()
    assert st.rejected_rate_limited["standard"] == 1
    assert st.frames_submitted["standard"] == 2
    _conservation(st)
    for t in range(5):                       # unlimited session unaffected
        try:
            srv.submit(free, _req(rng, t))
        except QueueFullError:
            break
    clock.t = 0.1                            # exactly one token refills
    # the bounded queue is FULL (maxlen 2): the refusal must refund the
    # token so the retry after serving succeeds without waiting again
    with pytest.raises(QueueFullError):
        srv.submit(sid, _req(rng, 2))
    while srv.stats().queue_depth["standard"] > 0 or \
            sum(srv.stats().in_flight.values()):
        srv.step()
    srv.submit(sid, _req(rng, 2))            # refunded token spends here
    with pytest.raises(RateLimitError):
        srv.submit(sid, _req(rng, 3))
    st = srv.stats()
    assert st.rejected_rate_limited["standard"] == 2
    assert st.rejected_full["standard"] == 2   # free's probe + the refund
    _conservation(st)


def test_server_rate_limit_default_applies_to_all_sessions(params):
    clock = FakeClock()
    srv = _server(params, capacity=2, max_batch=2, clock=clock,
                  rate_limit=(1.0, 1))
    rng = np.random.default_rng(24)
    sid = srv.open_session(qos=S).sid        # inherits (1.0, 1)
    off = srv.open_session(qos=S, rate_limit=None).sid   # opted out
    srv.submit(sid, _req(rng, 0))
    with pytest.raises(RateLimitError):
        srv.submit(sid, _req(rng, 1))
    for t in range(3):
        srv.submit(off, _req(rng, t))        # no bucket, no refusal
    assert srv.stats().rejected_rate_limited["standard"] == 1
    while srv.served_total < 4:
        srv.step()


def test_server_start_stop_race_single_serving_thread(params):
    """start() used to be check-then-act: two racing callers could both
    see a dead thread and spawn two serving loops."""
    srv = _server(params, capacity=2, max_batch=2)
    n = 8
    barrier = threading.Barrier(n)

    def go():
        barrier.wait()
        srv.start()

    threads = [threading.Thread(target=go) for _ in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    alive = [t for t in threading.enumerate()
             if t.name == "streamsplit-serve" and t.is_alive()]
    assert len(alive) == 1, f"{len(alive)} serving loops spawned"
    srv.stop()
    assert not any(t.is_alive() for t in alive)


# ---------------------------------------------------------------------------
# Property-style stress: extended conservation across concurrent snapshots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_stats_conservation_under_concurrent_stress(params, seed):
    """Producers, the serving thread, a closing/reopening tenant and a
    stats() poller all race, with shedding, rate limits, preemption and
    tight deadlines live.  The extended invariant (``submitted ==
    served + queue_depth + in_flight + shed_expired`` per class,
    ``preempted == requeued``) must hold at EVERY concurrent snapshot,
    and the books must close exactly at quiescence."""
    srv = _server(params, capacity=8, max_batch=4, queue_maxlen=16,
                  deadline_ms={I: 50.0, S: 50.0, B: 20.0},
                  sched_kw={"shed_horizon_ms": 30.0,
                            "max_wait_ms": {B: 40.0}},
                  rate_limit=(2000.0, 8))
    errors: list = []
    stop_polling = threading.Event()

    def poller():
        while not stop_polling.is_set():
            try:
                _conservation(srv.stats())
            except BaseException as e:       # surface in the main thread
                errors.append(e)
                return

    def producer(worker):
        rng = np.random.default_rng(3000 + 10 * seed + worker)
        for round_ in range(2):              # churn: open -> stream -> close
            sid = srv.open_session(qos=[I, S, B][worker % 3]).sid
            for t in range(40):
                try:
                    srv.submit(sid, _req(rng, round_ * 100 + t))
                except (QueueFullError, RateLimitError):
                    pass                     # typed refusals: fine, counted
                if rng.random() < 0.2:
                    time.sleep(1e-3)
            srv.close_session(sid, timeout=60.0)

    with srv:
        threads = [threading.Thread(target=producer, args=(w,))
                   for w in range(3)]
        poll = threading.Thread(target=poller)
        poll.start()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        stop_polling.set()
        poll.join()
    if errors:
        raise errors[0]
    st = srv.stats()
    _conservation(st)
    # quiescence: the books close exactly — nothing queued or in flight,
    # every accepted frame either served or visibly shed
    assert sum(st.queue_depth.values()) == 0
    assert sum(st.in_flight.values()) == 0
    for c in st.frames_submitted:
        assert st.frames_submitted[c] == (st.frames_served[c]
                                          + st.shed_expired[c]), (c, st)
    assert srv.gateway.stats().sessions_closed == 6


# ---------------------------------------------------------------------------
# Threaded: ingest racing close_session, oracle = sequential replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_threaded_ingest_races_close_without_losing_frames(params, seed):
    """Producers hammer the queues from their own threads while sessions
    close and reopen mid-stream.  No frame is lost or double-served:
    per-session served == accepted, and replaying the recorded schedule
    through a sequential gateway reproduces every embedding bitwise."""
    srv = _server(params, capacity=8, max_batch=8, queue_maxlen=64)
    frames, flock = {}, threading.Lock()
    accepted = {"n": 0}

    def producer(worker):
        rng = np.random.default_rng(1000 + 10 * seed + worker)
        for round_ in range(3):             # churn: open -> stream -> close
            sid = srv.open_session(qos=[I, S, B][worker % 3]).sid
            # frame indices globally unique per (worker, round): rows are
            # reused across close/reopen, so (sid, t) must still key one
            # frame for the replay oracle below
            base = (worker * 3 + round_) * 100
            for i in range(12):
                t = base + i
                f = _req(rng, t)
                with flock:
                    frames[(sid, t)] = f
                while True:
                    try:
                        srv.submit(sid, f)
                        break
                    except QueueFullError:  # backpressure: retry
                        pass
                with flock:
                    accepted["n"] += 1
            srv.close_session(sid, timeout=60.0)

    with srv:
        threads = [threading.Thread(target=producer, args=(w,))
                   for w in range(3)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    st = srv.stats()
    assert sum(st.frames_served.values()) == accepted["n"] == 3 * 3 * 12
    assert sum(st.queue_depth.values()) == 0
    assert st.gateway.sessions_closed == 9
    results = srv.drain_results()
    assert len(results) == accepted["n"]    # no loss ...
    by_key = {(r.sid, r.t): r for r in results}
    assert len(by_key) == accepted["n"]     # ... and no double-serve
    # sequential replay oracle: same admitted schedule, same embeddings.
    # Rows are reused across close/reopen, so the replay gateway opens
    # rows on demand (its free-list hands out ascending rows) and keys
    # every comparison purely by the globally unique (sid, t)
    gw = _gw(params, capacity=8)
    open_rows = set()
    served = 0
    for tick in srv.schedule():
        for sid, t in tick:
            if sid not in open_rows:
                # force-admit the specific row the server used
                while True:
                    got = gw.open_session().sid
                    open_rows.add(got)
                    if got == sid:
                        break
            gw.submit(sid, frames[(sid, t)])
        for r in gw.tick():
            ref = by_key[(r.sid, r.t)]
            np.testing.assert_array_equal(
                r.z, ref.z, err_msg=f"{(r.sid, r.t)} diverged from replay")
            assert r.k == ref.k
            served += 1
    assert served == accepted["n"]
