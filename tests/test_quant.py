"""INT8 wire format (paper §5) — property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.int8 import dequantize, fake_quant, quant_error, quantize


# seeded sweep over (length, dynamic range, zero-point shift): the scale
# axis spans six decades and the shift axis forces large asymmetric
# zero points in both directions
@pytest.mark.parametrize("n,scale,shift,seed", [
    (2, 1e-3, 0.0, 0), (2, 1e3, 100.0, 1), (500, 1e-3, -100.0, 2),
    (500, 1e3, 0.0, 3), (3, 1.0, -100.0, 4), (17, 0.05, 7.5, 5),
    (64, 10.0, -33.3, 6), (128, 300.0, 99.0, 7), (250, 0.01, 55.0, 8),
    (400, 2.5, -0.1, 9), (31, 1e2, -64.0, 1234), (499, 0.5, 100.0, 10_000),
])
def test_roundtrip_error_bounded_by_half_step(n, scale, shift, seed):
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (n,)) + shift
    t = quantize(x)
    err = float(jnp.max(jnp.abs(dequantize(t) - x)))
    # half-step + fp32 rounding slack (large zero-points lose mantissa bits)
    assert err <= float(t.scale) * 0.51 + float(jnp.max(jnp.abs(x))) * 1e-6


def test_quantize_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    t1 = quantize(x)
    t2 = quantize(dequantize(t1))
    assert bool(jnp.all(jnp.abs(t1.q.astype(jnp.int32)
                                - t2.q.astype(jnp.int32)) <= 1))


def test_wire_is_4x_smaller_than_fp32():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    t = quantize(x)
    assert t.wire_bytes < x.size * 4 / 3.9


def test_fake_quant_straight_through_gradient():
    x = jax.random.normal(jax.random.PRNGKey(0), (32,))
    g = jax.grad(lambda x: jnp.sum(fake_quant(x) ** 2))(x)
    # STE: gradient equals d/dx of sum(q(x)^2) with identity quant jacobian
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(2 * fake_quant(x)), rtol=1e-5)


def test_accuracy_penalty_below_paper_threshold():
    """Paper §5: INT8 wire degrades activations < 0.3% — check relative
    error on realistic activation tensors."""
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (64, 128)))
    rel = float(quant_error(x)) / float(jnp.max(jnp.abs(x)))
    assert rel < 0.003
