"""Affinity metric (paper §3.2): Dirichlet energy + Theorem 3.2 property
tests + §3.3 jitter validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import laplacian as L


def test_constant_embeddings_zero_energy():
    z = jnp.ones((20, 8))
    assert float(L.dirichlet_energy(z, k=5)) == 0.0


def test_matches_dense_oracle():
    rng = np.random.default_rng(0)
    z = rng.normal(size=(30, 6))
    A = L.temporal_adjacency(30, k=4)
    ours = float(L.dirichlet_energy(jnp.asarray(z), k=4))
    # Tr(Z^T L Z) = sum over UNDIRECTED edges of ||zi-zj||²; our energy is
    # normalized by the undirected edge count |E| = A.sum()/2
    Lmat = L.graph_laplacian(A)
    dense = float(np.trace(z.T @ Lmat @ z)) / (A.sum() / 2.0)
    np.testing.assert_allclose(ours, dense, rtol=1e-6)


def test_mask_removes_edges():
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.normal(size=(20, 4)))
    mask = jnp.ones((20,)).at[10].set(0.0)
    e_m = float(L.dirichlet_energy(z, k=2, mask=mask))
    A = L.temporal_adjacency(20, k=2, mask=np.asarray(mask))
    dense = float(np.trace(np.asarray(z).T @ L.graph_laplacian(A)
                           @ np.asarray(z))) / (A.sum() / 2.0)
    np.testing.assert_allclose(e_m, dense, rtol=1e-6)


# seeded sweep over (frames, dim, window, probe index, seed): extremes of
# each range plus interior combinations, probe index wrapping past T.
# The bound assumes a *sparse* temporal graph (2k < T); near-complete
# graphs (e.g. T=6, k=5) genuinely violate Eq. 5 and stay out of range.
@pytest.mark.parametrize("T,d,k,t_star,seed", [
    (6, 1, 1, 0, 0), (11, 8, 5, 39, 1), (40, 1, 1, 39, 2), (40, 8, 5, 0, 3),
    (7, 3, 2, 11, 4), (13, 5, 3, 6, 5), (20, 2, 4, 19, 6), (33, 7, 1, 16, 7),
    (12, 4, 5, 23, 8), (25, 6, 2, 24, 9), (40, 8, 1, 20, 10),
    (13, 1, 5, 38, 1234), (18, 8, 3, 9, 9999), (31, 2, 2, 30, 10_000),
])
def test_theorem_3_2_interpolation_bound(T, d, k, t_star, seed):
    """Property test of Eq. 5: ||z_t - ẑ_t||² <= 2α|E| / (λ₂ |N(t)|)."""
    t_star = t_star % T
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(T, d))
    A = L.temporal_adjacency(T, k=k)
    lhs = float(np.sum((z[t_star] - L.neighbor_average(z, A, t_star)) ** 2))
    rhs = L.interpolation_error_bound(z, A, t_star)
    assert lhs <= rhs * (1 + 1e-8)


@pytest.mark.parametrize("T,k", [(6, 5), (6, 3), (10, 5)])
def test_theorem_3_2_warns_outside_sparse_regime(T, k):
    """2k >= T: the temporal graph is near-complete and Eq. 5 is not a
    valid bound — the implementation must say so instead of returning a
    silently-wrong number."""
    rng = np.random.default_rng(0)
    z = rng.normal(size=(T, 2))
    A = L.temporal_adjacency(T, k=k)
    with pytest.warns(UserWarning, match="sparse-graph regime"):
        L.interpolation_error_bound(z, A, 0)


def test_theorem_3_2_warns_on_masked_near_complete_graph():
    """A masked-out first node must not blind the guard: the remaining
    nodes form a complete graph, which is still outside the regime."""
    rng = np.random.default_rng(0)
    T = 6
    z = rng.normal(size=(T, 2))
    mask = np.ones(T)
    mask[0] = 0.0
    A = L.temporal_adjacency(T, k=T - 1, mask=mask)
    with pytest.warns(UserWarning, match="sparse-graph regime"):
        L.interpolation_error_bound(z, A, 1)


def test_theorem_3_2_silent_inside_sparse_regime():
    import warnings as _w
    rng = np.random.default_rng(0)
    z = rng.normal(size=(20, 2))
    A = L.temporal_adjacency(20, k=4)      # 2k=8 < 20
    with _w.catch_warnings():
        _w.simplefilter("error")
        L.interpolation_error_bound(z, A, 0)


def test_jitter_degrades_spectral_gap():
    """§3.3: temporal shuffling (jitter) raises L_Lap; masking (drops)
    lowers λ₂ — manifold connectivity degrades as predicted."""
    rng = np.random.default_rng(0)
    # smooth trajectory
    t = np.linspace(0, 4 * np.pi, 60)
    z = np.stack([np.cos(t), np.sin(t)], -1) + 0.01 * rng.normal(size=(60, 2))
    e_smooth = float(L.dirichlet_energy(jnp.asarray(z), k=5))
    zj = z.copy()
    for i in range(0, 60, 6):  # shuffle within windows
        seg = zj[i:i + 6]
        rng.shuffle(seg)
    e_jit = float(L.dirichlet_energy(jnp.asarray(zj), k=5))
    assert e_jit > 1.5 * e_smooth
    gap_full = L.spectral_gap(L.temporal_adjacency(60, 5))
    mask = (rng.random(60) > 0.4).astype(float)
    gap_drop = L.spectral_gap(L.temporal_adjacency(60, 5, mask=mask))
    assert gap_drop < gap_full


def test_gradient_flows_batched():
    z = jax.random.normal(jax.random.PRNGKey(0), (3, 25, 8))
    g = jax.grad(lambda z: L.laplacian_loss(z, k=3))(z)
    assert bool(jnp.isfinite(g).all())
