"""Diversity metric (paper §3.1): SWD properties + §3.3 validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import swd as S


def _sphere(key, n, d):
    z = jax.random.normal(key, (n, d))
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def _cone(key, n, d, half_angle_deg):
    """Samples restricted to a spherical cone (the §3.3 degradation)."""
    axis = jnp.zeros((d,)).at[0].set(1.0)
    z = _sphere(key, n, d)
    t = np.cos(np.radians(half_angle_deg))
    # push samples toward the axis
    z = t * axis[None, :] + (1 - t) * z
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def test_swd_self_small():
    key = jax.random.PRNGKey(0)
    z = _sphere(key, 512, 32)
    v = S.swd_loss(jax.random.PRNGKey(1), z, n_dirs=64)
    assert float(v) < 5e-4


def test_swd_detects_collapse_monotonically():
    """§3.3: tighter cones (more collapse) => larger L_SW."""
    key = jax.random.PRNGKey(0)
    vals = []
    for ang in (10, 30, 60, 90):
        z = _cone(jax.random.PRNGKey(ang), 512, 32, ang)
        vals.append(float(S.swd_loss(key, z, n_dirs=64)))
    assert vals[0] > vals[1] > vals[2] > vals[3]


def test_swd_beats_mmd_sensitivity():
    """SWD separates collapse degrees more sharply than MMD (paper §3.3:
    r=-0.96 vs 0.82).  Concretely: the RBF MMD *saturates* in the severe-
    collapse regime (10°..40° cones all read ≈2.0) while SWD still spans
    two orders of magnitude there."""
    key = jax.random.PRNGKey(0)
    prior = _sphere(jax.random.PRNGKey(123), 512, 16)
    sw, mmd = [], []
    for ang in (10, 40):
        z = _cone(jax.random.PRNGKey(ang), 512, 16, ang)
        sw.append(float(S.swd_loss(key, z, n_dirs=64)))
        mmd.append(float(S.mmd_rbf(z, prior)))
    # deterministic seeds: sw ratio ≈ 1.35 vs mmd ratio ≈ 1.14
    assert sw[0] / sw[1] > mmd[0] / mmd[1]


# seeded sweep over (samples, dim, projections) — range corners + interiors
@pytest.mark.parametrize("n,d,m", [
    (8, 2, 1), (8, 32, 32), (128, 2, 1), (128, 32, 32),
    (16, 8, 4), (33, 5, 7), (64, 16, 50), (100, 3, 2),
    (9, 31, 13), (127, 2, 32),
])
def test_sliced_w2_nonneg_and_zero_on_identical(n, d, m):
    key = jax.random.PRNGKey(n * d + m)
    x = jax.random.normal(key, (n, d))
    dirs = S.random_directions(jax.random.PRNGKey(m), m, d)
    assert float(S.sliced_w2(x, x, dirs)) <= 1e-6
    y = jax.random.normal(jax.random.PRNGKey(7), (n, d))
    assert float(S.sliced_w2(x, y, dirs)) >= 0.0


# (samples, dirs) sweep: pow2 / non-pow2 / degenerate heights, with ties
@pytest.mark.parametrize("n,m", [(100, 50), (64, 8), (5, 3), (1, 2),
                                 (128, 1), (33, 7)])
def test_bitonic_diff_sort_matches_diff_sort(n, m):
    """The fleet hot path's sort must equal diff_sort in value AND
    (sub)gradient — including on duplicate values (stable tie-break)."""
    x = jax.random.normal(jax.random.PRNGKey(n * m), (n, m))
    x = jnp.round(x * 4) / 4      # force ties
    np.testing.assert_array_equal(np.asarray(S.bitonic_diff_sort(x)),
                                  np.asarray(S.diff_sort(x, axis=0)))
    tgt = jnp.linspace(-1.0, 1.0, n)[:, None] * jnp.ones((1, m))
    g1 = jax.grad(lambda x: jnp.mean((S.bitonic_diff_sort(x) - tgt) ** 2))(x)
    g2 = jax.grad(lambda x: jnp.mean((S.diff_sort(x, axis=0) - tgt) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-7)


def test_w1_exact_translation():
    """1-D W1 between X and X+c is |c|."""
    x = jnp.linspace(-1, 1, 100)
    assert abs(float(S.wasserstein1_1d(x, x + 0.7)) - 0.7) < 1e-5


def test_swd_gradient_flows():
    z = _sphere(jax.random.PRNGKey(0), 64, 16)
    g = jax.grad(lambda z: S.swd_loss(jax.random.PRNGKey(1), z))(z)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).sum()) > 0
