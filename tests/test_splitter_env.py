"""Split engine exactness + calibrated env anchors + controller semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import Controller, run_episode
from repro.core.env import (EdgeCloudEnv, EnvCfg, battery_hours,
                            utility_to_accuracy)
from repro.core.splitter import SplitEngine
from repro.models.audio_encoder import AudioEncCfg, encode, init_audio_encoder


@pytest.fixture(scope="module")
def enc_setup():
    cfg = AudioEncCfg(widths=(16, 16, 32, 32), strides=(1, 2, 1, 2),
                      n_mels=32, frames=40, d_embed=32, groups=4)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_builds_executables_lazily(enc_setup):
    """A session that only ever uses one k compiles 2 callables, not
    2·(L+1) — this is what keeps gateway startup O(1) in L."""
    cfg, params = enc_setup
    eng = SplitEngine(cfg)
    assert not eng._edge and not eng._server
    mel = jax.random.normal(jax.random.PRNGKey(2), (1, cfg.frames,
                                                    cfg.n_mels))
    eng.run(params, mel, 2)
    assert set(eng._edge) == {2} and set(eng._server) == {2}
    eng.run(params, mel, cfg.n_blocks)       # k=L: edge-only executable
    assert set(eng._edge) == {2, cfg.n_blocks} and set(eng._server) == {2}


def test_split_exact_every_k_fp32(enc_setup):
    cfg, params = enc_setup
    eng = SplitEngine(cfg, quantize_wire=False)
    mel = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.frames, cfg.n_mels))
    full = eng.full(params, mel)
    for k in range(cfg.n_blocks + 1):
        z, wire = eng.run(params, mel, k)
        np.testing.assert_allclose(np.asarray(z), np.asarray(full),
                                   atol=1e-5, err_msg=f"k={k}")
        if k < cfg.n_blocks:
            assert wire > 0


def test_split_int8_wire_small_error(enc_setup):
    cfg, params = enc_setup
    eng = SplitEngine(cfg, quantize_wire=True)
    mel = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.frames, cfg.n_mels))
    full = eng.full(params, mel)
    for k in range(1, cfg.n_blocks):
        z, wire_q = eng.run(params, mel, k)
        cos = float(jnp.sum(z * full, -1).mean())
        assert cos > 0.999, f"k={k} cos={cos}"
        # int8 wire is ~4x smaller
        z2, wire_f = SplitEngine(cfg, quantize_wire=False).run(params, mel, k)
        assert wire_q < wire_f / 3.5


def test_env_calibration_anchors():
    """Table 2 anchors: server-only 187.2 mJ / 5.3 h; edge-only 67.4 mJ."""
    env = EdgeCloudEnv(EnvCfg(net="stable", horizon=400))
    s_srv = run_episode(env, Controller("server", env.L), seed=3)
    assert abs(s_srv["energy_mj"] - 187.2) / 187.2 < 0.05
    assert abs(battery_hours(s_srv["energy_mj"]) - 5.3) < 0.5
    assert abs(s_srv["kb_per_batch"] - 256.0) / 256.0 < 0.05

    env = EdgeCloudEnv(EnvCfg(net="stable", horizon=400))
    s_edge = run_episode(env, Controller("edge", env.L), seed=3)
    assert abs(s_edge["energy_mj"] - 67.4) / 67.4 < 0.08
    # accuracy ordering (Fig. 8): server > static-offload > edge-only
    acc_srv = utility_to_accuracy(s_srv["utility"])
    acc_edge = utility_to_accuracy(s_edge["utility"])
    assert acc_srv > 72.0 and acc_edge < 62.0


def test_static_split_degrades_under_congestion():
    """§1: static split suffers under volatility via latency timeouts."""
    stable = EdgeCloudEnv(EnvCfg(net="stable", horizon=400))
    s1 = run_episode(stable, Controller("static", stable.L, static_k=3),
                     seed=5)
    cong = EdgeCloudEnv(EnvCfg(net="congested", horizon=400))
    s2 = run_episode(cong, Controller("static", cong.L, static_k=3), seed=5)
    assert s2["drop_rate"] > 0.15 > s1["drop_rate"]
    assert utility_to_accuracy(s2["utility"]) < \
        utility_to_accuracy(s1["utility"]) - 3.0


def test_rule_policy_adapts_but_slower():
    """Rule-based backs off under congestion (no catastrophic drops)."""
    cong = EdgeCloudEnv(EnvCfg(net="congested", horizon=400))
    s = run_episode(cong, Controller("rule", cong.L), seed=5)
    assert s["drop_rate"] < 0.2


def test_controller_atomic_transitions():
    env = EdgeCloudEnv(EnvCfg(net="variable", horizon=50))
    c = Controller("rule", env.L)
    obs = env.reset(seed=0)
    ks = []
    for _ in range(50):
        k = c.decide(obs)
        ks.append(k)
        obs, _, done, _ = env.step(k)
    # decisions are per-interval constants (atomicity is structural here):
    # the controller only ever returns the k applied to the *next* block
    assert c.transitions == sum(1 for a, b in zip(ks, ks[1:]) if a != b) + \
        (1 if ks and ks[0] != env.L else 0)
