"""Server Refiner (temporal buffer + hybrid refinement) and Lazy Sync."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server import ServerRefiner, TemporalBuffer
from repro.core.sync import LazySync, SyncCfg


def test_buffer_gaps_and_ordering():
    buf = TemporalBuffer(window=10, dim=4)
    for t in (0, 1, 2, 4, 5, 8):   # 3, 6, 7, 9 missing
        buf.insert(t, np.full(4, float(t)), label=t % 3)
    z, mask, labels = buf.snapshot()
    assert mask.sum() == 6
    present = np.where(mask > 0)[0]
    # temporal order: values equal their timestamps
    got = z[present, 0]
    assert list(got) == [0, 1, 2, 4, 5, 8]


def test_buffer_ring_expiry():
    buf = TemporalBuffer(window=5, dim=2)
    for t in range(12):
        buf.insert(t, np.full(2, float(t)))
    z, mask, _ = buf.snapshot()
    assert mask.sum() == 5
    np.testing.assert_array_equal(z[:, 0], [7, 8, 9, 10, 11])


def test_refiner_reduces_hybrid_loss():
    dim, n_classes = 16, 4

    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (dim, n_classes))}

    def head_apply(p, z):
        return z @ p["w"]

    ref = ServerRefiner(head_init, head_apply, lr=0.5)
    buf = TemporalBuffer(window=32, dim=dim)
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(n_classes, dim))
    for t in range(32):
        c = t % n_classes
        if t % 7 != 3:  # leave gaps
            buf.insert(t, centers[c] + 0.1 * rng.normal(size=dim), label=c)
    losses = [ref.refine(jax.random.PRNGKey(i), buf)[0] for i in range(25)]
    assert losses[-1] < losses[0] * 0.8


def test_lazy_sync_cadence_and_bytes():
    sync = LazySync(SyncCfg(t_sync_frames=100, t_weights_min_frames=500))
    events = []
    for f in range(1000):
        events += sync.on_frame(f, charging=(f == 600),
                                bandwidth_mbps=5.0)
    gmm_events = [e for e in events if e.kind == "gmm"]
    w_events = [e for e in events if e.kind == "weights"]
    assert len(gmm_events) == 9   # every 100 frames after frame 0
    assert len(w_events) == 1 and w_events[0].frame == 600
    assert sync.total_bytes == sum(e.bytes for e in events)
    # paper: GMM sync adds ~0.4 mJ/frame class overhead (order check)
    gmm_only = sum(e.energy_j for e in gmm_events) * 1e3 / 1000
    assert gmm_only < 1.0


def test_lazy_sync_wifi_trigger_throttled():
    sync = LazySync(SyncCfg(t_weights_min_frames=300,
                            wifi_mbps_threshold=25.0))
    n_w = 0
    for f in range(900):
        for e in sync.on_frame(f, bandwidth_mbps=30.0):
            n_w += e.kind == "weights"
    assert n_w == 3  # throttled to once per 300 frames despite wifi
