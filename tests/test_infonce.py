"""Streaming InfoNCE with virtual negatives (paper Eq. 10) + Theorem 3.1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmm as G
from repro.core import infonce as I
from repro.core import swd as S


def _sphere(key, n, d):
    z = jax.random.normal(key, (n, d))
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def test_streaming_infonce_matches_manual():
    key = jax.random.PRNGKey(0)
    z = _sphere(key, 8, 16)
    zp = _sphere(jax.random.PRNGKey(1), 8, 16)
    zn = _sphere(jax.random.PRNGKey(2), 8 * 32, 16).reshape(8, 32, 16)
    tau = 0.2
    loss = float(I.streaming_infonce(z, zp, zn, tau=tau))
    pos = np.sum(np.asarray(z) * np.asarray(zp), -1) / tau
    negs = np.einsum("bd,bnd->bn", np.asarray(z), np.asarray(zn)) / tau
    all_ = np.concatenate([pos[:, None], negs], 1)
    manual = float(np.mean(np.log(np.exp(all_).sum(1)) - pos))
    np.testing.assert_allclose(loss, manual, rtol=1e-5)


def test_perfect_positive_low_loss():
    z = _sphere(jax.random.PRNGKey(0), 8, 32)
    zn = -z[:, None, :].repeat(16, 1)  # antipodal negatives
    loss_good = float(I.streaming_infonce(z, z, zn, tau=0.1))
    zn_hard = _sphere(jax.random.PRNGKey(3), 8 * 16, 32).reshape(8, 16, 32)
    loss_rand = float(I.streaming_infonce(z, z, zn_hard, tau=0.1))
    assert loss_good < loss_rand


def test_virtual_negative_loss_gradient():
    key = jax.random.PRNGKey(0)
    gmm = G.init_gmm(key, 8, 16)
    z = _sphere(jax.random.PRNGKey(1), 8, 16)
    zp = _sphere(jax.random.PRNGKey(2), 8, 16)

    def f(z):
        return I.infonce_with_virtual_negatives(
            jax.random.PRNGKey(3), gmm, z, zp, n_syn=32)

    g = jax.grad(f)(z)
    assert bool(jnp.isfinite(g).all())


def test_batch_infonce_identity_pairs():
    z = _sphere(jax.random.PRNGKey(0), 16, 32)
    l_same = float(I.batch_infonce(z, z, tau=0.1))
    l_rand = float(I.batch_infonce(z, _sphere(jax.random.PRNGKey(9), 16, 32),
                                   tau=0.1))
    assert l_same < l_rand


def test_theorem_3_1_small_batch_bound_trend():
    """|L_N - L_inf| shrinks as N grows, and a diverse (low-ε) distribution
    gives a smaller gap than a collapsed one — the Theorem 3.1 mechanism."""
    key = jax.random.PRNGKey(0)
    d = 16
    anchor = _sphere(jax.random.PRNGKey(42), 1, d)[0]

    def gap(neg_sampler, N, reps=64):
        # L_inf ref: big sample
        big = neg_sampler(jax.random.PRNGKey(999), 8192)
        h = jnp.exp(big @ anchor)
        l_inf = jnp.log(jnp.mean(h))
        gaps = []
        for r in range(reps):
            zn = neg_sampler(jax.random.PRNGKey(r), N)
            ln = jnp.log(jnp.mean(jnp.exp(zn @ anchor)))
            gaps.append(abs(float(ln - l_inf)))
        return np.mean(gaps)

    uni = lambda k, n: _sphere(k, n, d)
    g8, g128 = gap(uni, 8), gap(uni, 128)
    assert g128 < g8  # 1/sqrt(N) shrinkage

    # collapsed sampler (cone) has a bigger W1-to-uniform => bigger bias
    def cone(k, n):
        z = _sphere(k, n, d)
        axis = jnp.zeros((d,)).at[0].set(1.0)
        z = 0.9 * axis[None] + 0.1 * z
        return z / jnp.linalg.norm(z, -1, keepdims=True)

    # compare *bias* against the true uniform population loss
    big_u = uni(jax.random.PRNGKey(999), 8192)
    l_inf_u = float(jnp.log(jnp.mean(jnp.exp(big_u @ anchor))))

    def bias(sampler):
        vals = []
        for r in range(64):
            zn = sampler(jax.random.PRNGKey(r), 64)
            vals.append(float(jnp.log(jnp.mean(jnp.exp(zn @ anchor)))))
        return abs(np.mean(vals) - l_inf_u)

    assert bias(cone) > bias(uni)


def test_stopgrad_negative_drift():
    """One-sided (stop-gradient) repulsion from a shared negative cloud
    drifts embeddings toward its antipode; symmetric in-batch negatives do
    not (the EXPERIMENTS.md §Reproduction finding, distilled)."""
    key = jax.random.PRNGKey(0)
    d, B = 16, 32
    z0 = _sphere(key, B, d)
    # a CONCENTRATED negative cloud (like a GMM fit to semi-collapsed
    # embeddings): its mean direction defines the antipode
    v = jnp.zeros((d,)).at[0].set(1.0)
    cloud = v[None] + 0.3 * jax.random.normal(jax.random.PRNGKey(1), (64, d))
    cloud = cloud / jnp.linalg.norm(cloud, axis=-1, keepdims=True)

    def step(z, stopgrad):
        def loss(z):
            zn = z / jnp.linalg.norm(z, axis=-1, keepdims=True)
            negs = jnp.broadcast_to(cloud[None], (B, 64, d))
            pos = jnp.sum(zn * jax.lax.stop_gradient(zn), -1)  # trivial pos
            if stopgrad:
                logits = jnp.einsum("bd,bnd->bn",
                                    zn, jax.lax.stop_gradient(negs)) / 0.1
            else:
                logits = jnp.einsum("bd,bnd->bn", zn, negs) / 0.1
            return jnp.mean(jax.nn.logsumexp(
                jnp.concatenate([pos[:, None] / 0.1, logits], 1), 1))
        g = jax.grad(loss)(z)
        z = z - 0.5 * g
        return z / jnp.linalg.norm(z, axis=-1, keepdims=True)

    z = z0
    for _ in range(100):
        z = step(z, True)
    drift = float(jnp.mean(z @ (-cloud.mean(0) /
                                jnp.linalg.norm(cloud.mean(0)))))
    drift0 = float(jnp.mean(z0 @ (-cloud.mean(0) /
                                  jnp.linalg.norm(cloud.mean(0)))))
    # with stop-grad negatives the batch drifts toward the cloud's antipode
    assert drift > drift0 + 0.1
