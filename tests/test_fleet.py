"""Fleet serving: buffer edge semantics, admission/eviction, and the
FleetRefiner == ServerRefiner N=1 parity contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import (FleetBuffer, FleetFullError, FleetRefiner,
                              T_SENTINEL)
from repro.core.server import ServerRefiner, TemporalBuffer

DIM = 8


def _head():
    n_classes = 4

    def head_init(key):
        return {"w": 0.01 * jax.random.normal(key, (DIM, n_classes))}

    def head_apply(p, z):
        return z @ p["w"]

    return head_init, head_apply


# ---------------------------------------------------------------------------
# TemporalBuffer edge semantics (single stream)
# ---------------------------------------------------------------------------

def test_temporal_buffer_empty_snapshot():
    buf = TemporalBuffer(window=6, dim=3)
    z, mask, labels = buf.snapshot()
    assert mask.sum() == 0 and (labels == -1).all() and (z == 0).all()
    assert buf.fill_fraction == 0.0


def test_temporal_buffer_single_frame_no_sentinel_collision():
    """With one frame at t=0 the snapshot window spans negative indices
    (-W+1..0); the empty-slot sentinel must never alias those."""
    W = 10
    buf = TemporalBuffer(window=W, dim=2)
    buf.insert(0, np.ones(2))
    z, mask, _ = buf.snapshot()
    assert mask.sum() == 1 and mask[-1] == 1.0  # newest is last
    # sentinel lies far below any reachable window index
    assert T_SENTINEL < -(W + 1) and (buf.t != -W).all()
    assert buf.fill_fraction == pytest.approx(1.0 / W)


@pytest.mark.parametrize("window,n_frames", [(5, 12), (7, 7), (4, 101)])
def test_temporal_buffer_wraparound_keeps_last_window(window, n_frames):
    buf = TemporalBuffer(window=window, dim=1)
    for t in range(n_frames):
        buf.insert(t, [float(t)])
    z, mask, _ = buf.snapshot()
    assert mask.sum() == window
    np.testing.assert_array_equal(
        z[:, 0], np.arange(n_frames - window, n_frames))


def test_temporal_buffer_gaps_after_drops():
    buf = TemporalBuffer(window=8, dim=1)
    kept = [0, 1, 4, 6]          # 2, 3, 5, 7 dropped by the network
    for t in kept:
        buf.insert(t, [float(t)])
    z, mask, labels = buf.snapshot()
    # window spans 0..7 (newest=6 => lo=-1): mask marks exactly the kept
    present = np.where(mask > 0)[0]
    np.testing.assert_array_equal(z[present, 0], kept)
    assert (labels[mask == 0] == -1).all()
    assert buf.fill_fraction == pytest.approx(len(kept) / 8)


def test_temporal_buffer_stale_frames_expire_not_resurface():
    """A slot whose tenant expired must read as a gap even though the slot
    still physically holds the old value."""
    buf = TemporalBuffer(window=4, dim=1)
    buf.insert(0, [0.0])
    buf.insert(5, [5.0])         # slot 1; frames 2..4 never arrived
    z, mask, _ = buf.snapshot()  # window = 2..5
    assert mask.sum() == 1 and z[mask > 0, 0] == [5.0]


# ---------------------------------------------------------------------------
# FleetBuffer: same invariants, plus admission/eviction
# ---------------------------------------------------------------------------

def test_fleet_rows_match_independent_temporal_buffers():
    """Row semantics == TemporalBuffer, for every row, same drop pattern."""
    W, N = 6, 4
    fleet = FleetBuffer(capacity=N, window=W, dim=2)
    singles = [TemporalBuffer(window=W, dim=2) for _ in range(N)]
    sids = [fleet.admit() for _ in range(N)]
    rng = np.random.default_rng(0)
    for t in range(15):
        for i, sid in enumerate(sids):
            if rng.random() < 0.35:      # per-session drops
                continue
            z = rng.normal(size=2)
            fleet.insert(sid, t + i, z, label=t % 3)
            singles[i].insert(t + i, z, label=t % 3)
    zf, mf, lf = fleet.snapshot()
    for i, sid in enumerate(sids):
        zs, ms, ls = singles[i].snapshot()
        np.testing.assert_allclose(zf[sid], zs)
        np.testing.assert_array_equal(mf[sid], ms)
        np.testing.assert_array_equal(lf[sid], ls)
        assert fleet.fill_fraction(sid) == pytest.approx(
            singles[i].fill_fraction)


def test_fleet_admission_eviction_o1_and_reuse():
    fleet = FleetBuffer(capacity=3, window=4, dim=1)
    a, b, c = fleet.admit(), fleet.admit(), fleet.admit()
    assert {a, b, c} == {0, 1, 2} and fleet.n_active == 3
    with pytest.raises(FleetFullError):
        fleet.admit()
    fleet.insert(b, 7, [1.0], label=2)
    fleet.evict(b)
    assert fleet.n_active == 2
    # evicted row contributes nothing to the snapshot
    _, mask, labels = fleet.snapshot()
    assert mask[b].sum() == 0 and (labels[b] == -1).all()
    with pytest.raises(KeyError):
        fleet.insert(b, 8, [2.0])
    with pytest.raises(KeyError):
        fleet.evict(b)
    # the freed row is reused and starts clean (no stale frames)
    b2 = fleet.admit()
    assert b2 == b
    _, mask, _ = fleet.snapshot()
    assert mask[b2].sum() == 0
    assert (fleet.t[b2] == T_SENTINEL).all()


def test_fleet_insert_batch_matches_loop():
    fleet1 = FleetBuffer(capacity=4, window=5, dim=3)
    fleet2 = FleetBuffer(capacity=4, window=5, dim=3)
    for f in (fleet1, fleet2):
        for _ in range(4):
            f.admit()
    rng = np.random.default_rng(1)
    sids = np.array([0, 1, 3])
    ts = np.array([9, 2, 4])
    zs = rng.normal(size=(3, 3))
    labs = np.array([1, -1, 0])
    for s, t, z, l in zip(sids, ts, zs, labs):
        fleet1.insert(s, t, z, label=l)
    fleet2.insert_batch(sids, ts, zs, labs)
    for arr1, arr2 in ((fleet1.z, fleet2.z), (fleet1.t, fleet2.t),
                       (fleet1.label, fleet2.label),
                       (fleet1.newest, fleet2.newest)):
        np.testing.assert_array_equal(arr1, arr2)


def test_fleet_inactive_rows_masked_out_of_refine():
    """Sessions admitted but empty / evicted must not move the shared head:
    per-session losses are finite and the mean covers active rows only."""
    head_init, head_apply = _head()
    fleet = FleetBuffer(capacity=4, window=8, dim=DIM)
    sid = fleet.admit()
    rng = np.random.default_rng(0)
    for t in range(8):
        fleet.insert(sid, t, rng.normal(size=DIM), label=t % 4)
    ref = FleetRefiner(head_init, head_apply, lr=0.1)
    loss, parts, per = ref.refine(jax.random.PRNGKey(0), fleet)
    assert np.isfinite(per).all() and np.isfinite(loss)
    # mean-over-active == the single active session's loss
    assert loss == pytest.approx(float(per[sid]), rel=1e-6)


def test_evict_is_lazy_and_admit_wipes_dirty_row():
    """Eviction must be O(1) in bytes (lazy wipe-on-admit): the freed
    row's arrays still hold the old tenant's bytes after evict, the
    snapshot masks them, and re-admission hands the new tenant a row
    indistinguishable from a never-used one."""
    W, D = 5, 3
    fleet = FleetBuffer(capacity=2, window=W, dim=D)
    sid = fleet.admit()
    rng = np.random.default_rng(0)
    for t in range(W):
        fleet.insert(sid, t, rng.normal(size=D), label=t % 2)
    fleet.evict(sid)
    # lazy: the bytes were NOT wiped at evict time ...
    assert (fleet.z[sid] != 0.0).any() and (fleet.t[sid] != T_SENTINEL).any()
    # ... but the snapshot never exposes them
    z, mask, labels = fleet.snapshot()
    assert mask[sid].sum() == 0 and (z[sid] == 0).all() \
        and (labels[sid] == -1).all()
    # admit onto the dirty row: clean slate, oracle = a fresh buffer row
    sid2 = fleet.admit()
    assert sid2 == sid
    assert (fleet.z[sid2] == 0.0).all()
    assert (fleet.t[sid2] == T_SENTINEL).all()
    assert (fleet.label[sid2] == -1).all()
    assert fleet.newest[sid2] == -1
    oracle = FleetBuffer(capacity=2, window=W, dim=D)
    oracle.admit()
    for f in (fleet, oracle):
        f.insert(sid2 if f is fleet else 0, 2, np.ones(D), label=1)
    zf, mf, lf = fleet.snapshot()
    zo, mo, lo = oracle.snapshot()
    np.testing.assert_array_equal(zf[sid2], zo[0])
    np.testing.assert_array_equal(mf[sid2], mo[0])
    np.testing.assert_array_equal(lf[sid2], lo[0])


# ---------------------------------------------------------------------------
# N=1 parity: FleetRefiner step == ServerRefiner step (fp32 tolerance)
# ---------------------------------------------------------------------------

def test_fleet_refiner_n1_matches_server_refiner():
    head_init, head_apply = _head()
    srv = ServerRefiner(head_init, head_apply, lr=0.5)
    flt = FleetRefiner(head_init, head_apply, lr=0.5)
    buf = TemporalBuffer(window=32, dim=DIM)
    fleet = FleetBuffer(capacity=1, window=32, dim=DIM)
    sid = fleet.admit()
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, DIM))
    for t in range(40):
        if t % 7 == 3:
            continue            # leave gaps
        z = centers[t % 4] + 0.1 * rng.normal(size=DIM)
        buf.insert(t, z, label=t % 4)
        fleet.insert(sid, t, z, label=t % 4)
    for i in range(5):
        key = jax.random.PRNGKey(i)
        loss_s, parts_s = srv.refine(key, buf)
        loss_f, parts_f, _ = flt.refine(key, fleet)
        assert loss_f == pytest.approx(loss_s, abs=1e-5)
        for k in parts_s:
            assert parts_f[k] == pytest.approx(parts_s[k], abs=1e-5)
    for a, b in zip(jax.tree.leaves(srv.state.params),
                    jax.tree.leaves(flt.state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fleet_refiner_reduces_loss_across_sessions():
    head_init, head_apply = _head()
    fleet = FleetBuffer(capacity=8, window=16, dim=DIM)
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, DIM))
    for _ in range(6):
        sid = fleet.admit()
        for t in range(16):
            if (t + sid) % 5 == 2:
                continue
            fleet.insert(sid, t, centers[t % 4] + 0.1 * rng.normal(size=DIM),
                         label=t % 4)
    ref = FleetRefiner(head_init, head_apply, lr=0.5)
    losses = [ref.refine(jax.random.PRNGKey(i), fleet)[0] for i in range(25)]
    assert losses[-1] < losses[0] * 0.8
