"""Per-frame span tracing on the injected clock.

A sampled frame accumulates one ``FrameTrace``: an ordered list of
``(event, t_s, attrs)`` stamps taken at every hop of its life —

    submit → enqueue → admit (or promote → admit) → stage →
    dispatch(shard, bucket) → collect → serve

plus the federation hops (``journal`` / ``migrate_out`` /
``migrate_in`` / ``replay``) and the terminal anomalies (``shed``,
``preempt`` records also land in the flight recorder).  Timestamps come
from whatever clock the owning component was constructed with, so on
the fake-clock suites traces are exactly reproducible and span
durations are assertable to the millisecond.

The contract that matters is the OFF path.  Tracing is disabled by
default (``sample=0.0``) and the pinned overhead budget is <2% serve
throughput (ISSUE 10, ``benchmarks/obs_bench.py``), so the design puts
*nothing* on the hot path but a single attribute test:

- the trace context rides on ``QueuedFrame.trace`` (and
  ``QueuedFrameSnapshot.trace`` across migration), defaulting to
  ``None``; every stamp site is ``if qf.trace is not None: ...`` —
  no dict lookup, no allocation, no clock read when off;
- ``Tracer.maybe_begin`` decides sampling with a **deterministic
  integer hash** of ``(sid, t)`` (no RNG, no state): the same frame is
  sampled on every member it migrates through, replays re-sample
  identically, and ``sample=1.0``/``0.0`` short-circuit without
  hashing.

Finished traces are handed to the ``FlightRecorder`` ring; live ones
are reachable from the frames that carry them.  There is deliberately
no central "active spans" table — it would need rekeying on migration
and would leak entries for shed frames.
"""
from __future__ import annotations

import time

__all__ = ["FrameTrace", "Tracer", "sampled"]

# Knuth multiplicative hash over a (sid, t) mix; 32-bit phase compared
# against sample * 2^32.  Pure function — every member/replay agrees.
_HASH_MUL = 2654435761
_SID_MIX = 1000003
_MASK32 = 0xFFFFFFFF


def sampled(sid: int, t: int, sample: float) -> bool:
    """Deterministic per-frame sampling decision, identical across
    members, migrations and journal replays."""
    if sample >= 1.0:
        return True
    if sample <= 0.0:
        return False
    h = ((sid * _SID_MIX + t + 1) * _HASH_MUL) & _MASK32
    return h < sample * (_MASK32 + 1)


class FrameTrace:
    """One frame's span: an append-only event list.

    Slotted and pickle-friendly (it crosses the ``SessionSnapshot`` /
    journal pickle boundary inside ``QueuedFrameSnapshot``), with no
    references back into live server objects.
    """

    __slots__ = ("sid", "t", "trace_id", "events")

    def __init__(self, sid: int, t: int, trace_id: str):
        self.sid = sid
        self.t = t
        self.trace_id = trace_id
        self.events: list = []   # [(name, t_s, attrs-dict-or-None)]

    def add(self, name: str, t_s: float, **attrs) -> None:
        self.events.append((name, t_s, attrs or None))

    def names(self) -> list:
        return [e[0] for e in self.events]

    def find(self, name: str):
        """First event with this name, or None."""
        for e in self.events:
            if e[0] == name:
                return e
        return None

    def span_ms(self, first: str, last: str) -> float:
        """Clock distance between two stamped events (ms)."""
        a, b = self.find(first), self.find(last)
        if a is None or b is None:
            raise KeyError(f"trace {self.trace_id} missing "
                           f"{first if a is None else last!r}")
        return (b[1] - a[1]) * 1e3

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "sid": self.sid, "t": self.t,
                "events": [{"name": n, "t_s": ts,
                            **({"attrs": at} if at else {})}
                           for n, ts, at in self.events]}

    # pickles cleanly, but be explicit that equality is by identity —
    # a migrated trace is the SAME span continued, not a copy to diff
    def __repr__(self):
        return (f"FrameTrace({self.trace_id}, "
                f"{'>'.join(self.names()) or 'empty'})")


class Tracer:
    """Sampling gate + trace factory for one serving stack.

    ``sample`` is the fraction of frames traced (0.0 = off, the
    default).  ``maybe_begin`` is the only entry point the submit path
    touches; when the frame loses the sampling toss it returns ``None``
    having allocated nothing.
    """

    __slots__ = ("sample", "clock", "recorder", "started", "finished")

    def __init__(self, sample: float = 0.0, *,
                 clock=time.perf_counter, recorder=None):
        if not (0.0 <= sample <= 1.0):
            raise ValueError("sample must be in [0, 1]")
        self.sample = float(sample)
        self.clock = clock
        self.recorder = recorder
        self.started = 0
        self.finished = 0

    @property
    def enabled(self) -> bool:
        return self.sample > 0.0

    def maybe_begin(self, sid: int, t: int, now: float | None = None,
                    **attrs):
        """A new ``FrameTrace`` stamped with ``submit``, or ``None``
        when the frame is not sampled (the zero-allocation path)."""
        if self.sample <= 0.0 or not sampled(sid, t, self.sample):
            return None
        tr = FrameTrace(sid, t, f"{sid:x}-{t:x}")
        tr.add("submit", self.clock() if now is None else now, **attrs)
        self.started += 1
        return tr

    def adopt(self, sid: int, t: int, name: str,
              now: float | None = None, **attrs):
        """Begin a trace at a non-submit hop — journal replay creates
        frames whose original submit already happened on the failed
        member.  Same sampling decision as the original submit."""
        if self.sample <= 0.0 or not sampled(sid, t, self.sample):
            return None
        tr = FrameTrace(sid, t, f"{sid:x}-{t:x}")
        tr.add(name, self.clock() if now is None else now, **attrs)
        self.started += 1
        return tr

    def finish(self, trace, name: str = "serve",
               now: float | None = None, **attrs) -> None:
        """Stamp the terminal event and retire the trace into the
        flight recorder (if one is attached)."""
        if trace is None:
            return
        trace.add(name, self.clock() if now is None else now, **attrs)
        self.retire(trace)

    def retire(self, trace) -> None:
        """Retire an already-terminated trace (its last hop — e.g. the
        scheduler's ``shed`` stamp — was the terminal event)."""
        if trace is None:
            return
        self.finished += 1
        if self.recorder is not None:
            self.recorder.keep_trace(trace)
