"""Typed metrics registry — ONE scoreboard for the whole serving stack.

Before this module the repo's telemetry was three ad-hoc stats
dataclasses (``GatewayStats`` / ``StreamStats`` / ``ClusterStats``),
each backed by loose counter attributes scattered across the layer that
happened to own them.  The registry inverts that: every counter, gauge
and latency distribution lives HERE, keyed by ``(name, labels)``, and
the stats dataclasses become *views* — ``stats()`` reads the same
objects the hot path mutates, so the pinned conservation invariants
(``submitted == served + depth + in_flight + shed_expired [+ lost]``)
hold bit-for-bit exactly as before, while exporters
(``repro.obs.export``: Prometheus text format, JSONL snapshots) and the
``resource_signals()`` control-plane view get a uniform surface for
free (docs/OBSERVABILITY.md).

Three metric types, deliberately minimal:

- ``Counter`` — an integer that (almost always) goes up.  ``inc()``
  accepts negatives because the serving plane has *relocatable
  ledgers*: a migration moves a session's ``submitted`` count to
  another member, which is neither a serve nor a reset.
- ``Gauge`` — a float level: ``set``/``add``/``ewma`` (the EWMA form is
  what keeps always-on stage timings cheap: one multiply-add per tick,
  no samples retained).
- ``Histogram`` — a bounded **streaming quantile sketch**
  (``QuantileSketch``): exact (``numpy.percentile``-identical) below
  ``exact_cap`` samples, deterministic fixed-ratio log bins beyond.
  This replaces the per-class wait-sample deques — a long-running
  server's memory no longer depends on how many frames it has served.

Concurrency contract (same as the counters it replaced): metric
*creation* is locked; metric *mutation* is not — each metric has one
owning component that already serializes its writes under its own lock
(``queues.cond``, the server ``_lock``, the cluster lock), and
``stats()`` snapshots read under those same locks.  The registry adds
no locking to the hot path.
"""
from __future__ import annotations

import math
import threading

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "QuantileSketch"]


class QuantileSketch:
    """Deterministic streaming quantile estimator with bounded memory.

    Two regimes, one contract:

    - while ``count <= exact_cap`` the raw samples are retained and
      ``quantile(q)`` is **bit-identical to** ``numpy.percentile``
      (linear interpolation) — every deterministic fake-clock suite
      lives here, so replacing the old sample deques changed no pinned
      value;
    - past ``exact_cap`` the buffer is dropped and quantiles come from
      fixed-ratio log-spaced bins (``growth`` per bin over
      ``[lo, hi]``), geometrically interpolated within the winning bin
      — relative error is bounded by the bin ratio (~``growth - 1``,
      pinned against ``numpy.percentile`` on seeded distributions in
      ``tests/test_obs.py``), and memory is O(bins), forever.

    ``sum``/``count``/``min``/``max`` are exact in both regimes (the
    pinned "terminal wait == 400 ms" style contracts read ``max``).
    Insertion order never matters: the sketch state is a pure function
    of the multiset of observed values, so replayed runs match bitwise.
    """

    __slots__ = ("lo", "hi", "growth", "exact_cap", "count", "total",
                 "vmin", "vmax", "_exact", "_bins", "_log_growth",
                 "_nbins")

    def __init__(self, *, lo: float = 1e-3, hi: float = 1e7,
                 growth: float = 1.1, exact_cap: int = 4096):
        if not (0.0 < lo < hi):
            raise ValueError("need 0 < lo < hi")
        if growth <= 1.0:
            raise ValueError("growth must be > 1")
        if exact_cap < 0:
            raise ValueError("exact_cap must be >= 0")
        self.lo = float(lo)
        self.hi = float(hi)
        self.growth = float(growth)
        self.exact_cap = int(exact_cap)
        self._log_growth = math.log(self.growth)
        # bin i covers [lo*growth^i, lo*growth^(i+1)); one underflow bin
        # (index 0 holds everything <= lo) and one overflow bin at the
        # top hold the tails, so no value is ever dropped
        self._nbins = int(math.ceil(
            math.log(self.hi / self.lo) / self._log_growth)) + 2
        self._bins = [0] * self._nbins
        self._exact: list | None = []
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # -- ingest --------------------------------------------------------------
    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self._bins[self._bin_of(v)] += 1
        if self._exact is not None:
            if self.count <= self.exact_cap:
                self._exact.append(v)
            else:        # bounded by construction: drop the raw samples
                self._exact = None

    def _bin_of(self, v: float) -> int:
        if v <= self.lo:
            return 0
        if v >= self.hi:
            return self._nbins - 1
        return 1 + min(self._nbins - 3,
                       int(math.log(v / self.lo) / self._log_growth))

    # -- quantiles -----------------------------------------------------------
    def quantile(self, q: float) -> float:
        """The ``q``-th percentile, ``q`` in [0, 100] (numpy
        convention).  Exact below ``exact_cap``; binned geometric
        interpolation beyond, clamped into [min, max]."""
        if self.count == 0:
            return 0.0
        if self._exact is not None:
            return float(np.percentile(
                np.asarray(self._exact, np.float64), q))
        rank = (q / 100.0) * (self.count - 1)
        cum = 0
        for i, n in enumerate(self._bins):
            if n == 0:
                continue
            if cum + n > rank:
                # geometric interpolation inside the winning bin
                frac = (rank - cum + 0.5) / n
                if i == 0:
                    est = self.lo
                else:
                    lo_edge = self.lo * self.growth ** (i - 1)
                    est = lo_edge * self.growth ** min(1.0, max(0.0, frac))
                return float(min(max(est, self.vmin), self.vmax))
            cum += n
        return float(self.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        """The ``StreamStats.queue_wait_ms`` shape: p50/p95/mean/max
        (zeros when empty, like the deques it replaced)."""
        if self.count == 0:
            return {"p50": 0.0, "p95": 0.0, "mean": 0.0, "max": 0.0}
        return {"p50": self.quantile(50), "p95": self.quantile(95),
                "mean": self.mean, "max": float(self.vmax)}

    @property
    def exact(self) -> bool:
        """True while quantiles are still ``numpy.percentile``-exact."""
        return self._exact is not None

    def state(self) -> dict:
        """JSON-able snapshot (exporters): aggregates + regime."""
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "exact": self.exact, **self.summary()}


class _Metric:
    __slots__ = ("name", "labels")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels       # tuple of (key, value), sorted

    @property
    def labels_dict(self) -> dict:
        return dict(self.labels)


class Counter(_Metric):
    """An owned integer.  ``inc`` may be negative — the serving plane
    relocates ledgers (migration moves a session's counts between
    members); exporters still expose it as a counter because within one
    member's lifetime it is monotone for every metric that matters."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge(_Metric):
    """A float level: set, add, or exponentially smooth."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, d: float) -> None:
        self.value += d

    def ewma(self, v: float, alpha: float = 0.2) -> float:
        """One multiply-add: the always-on stage-timing update.  The
        first sample seeds the average (no zero-pull warmup)."""
        self.value = (float(v) if self.value == 0.0
                      else (1.0 - alpha) * self.value + alpha * float(v))
        return self.value

    def try_set_max(self, v: float) -> None:
        if v > self.value:
            self.value = float(v)


class Histogram(_Metric):
    """A named ``QuantileSketch``."""

    __slots__ = ("sketch",)
    kind = "histogram"

    def __init__(self, name, labels, **sketch_kw):
        super().__init__(name, labels)
        self.sketch = QuantileSketch(**sketch_kw)

    def observe(self, v: float) -> None:
        self.sketch.observe(v)

    def summary(self) -> dict:
        return self.sketch.summary()

    def quantile(self, q: float) -> float:
        return self.sketch.quantile(q)

    @property
    def count(self) -> int:
        return self.sketch.count


class MetricsRegistry:
    """All metrics of one serving stack, keyed by ``(name, labels)``.

    Get-or-create accessors (``counter``/``gauge``/``histogram``) are
    idempotent and type-checked: asking for an existing name with a
    different type raises instead of silently shadowing.  One registry
    is shared down a stack (gateway ⊂ server; the cluster keeps its own
    federation-level registry beside the members') — names are
    prefixed per layer (``gateway_*`` / ``stream_*`` / ``cluster_*``)
    so they never collide.
    """

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    def _get_or_create(self, cls, name, labels, **kw):
        key = self._key(name, labels)
        m = self._metrics.get(key)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r}{dict(labels)} is a {m.kind}, "
                    f"not a {cls.kind}")
            return m
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r}{dict(labels)} is a {m.kind}, "
                    f"not a {cls.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get_or_create(Gauge, name, labels)

    def histogram(self, name: str, *, lo: float = 1e-3, hi: float = 1e7,
                  growth: float = 1.1, exact_cap: int = 4096,
                  **labels) -> Histogram:
        return self._get_or_create(Histogram, name, labels, lo=lo, hi=hi,
                                   growth=growth, exact_cap=exact_cap)

    # -- read side -----------------------------------------------------------
    def get(self, name: str, **labels):
        """The metric, or None — never creates."""
        return self._metrics.get(self._key(name, labels))

    def value(self, name: str, **labels):
        """Counter/gauge value (0 for an absent metric — the view
        convention: an untouched counter was never incremented)."""
        m = self.get(name, **labels)
        return 0 if m is None else m.value

    def collect(self) -> list:
        """Every metric, sorted by (name, labels) — the exporter walk.
        The list is a snapshot; the metrics it holds are live."""
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def __len__(self) -> int:
        return len(self._metrics)
