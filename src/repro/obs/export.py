"""Exporters: Prometheus exposition text + JSONL snapshots.

The registry is the source of truth; exporters are pure read-side
walks over ``MetricsRegistry.collect()``:

- ``to_prometheus(registry)`` — the text exposition format scrapers
  expect.  Counters/gauges map directly; histograms export as
  *summaries* (``{quantile="0.5"}``/``{quantile="0.95"}`` plus
  ``_sum``/``_count``/``_max``) because the sketch's log-bins are an
  implementation detail — quantiles are the contract.
- ``validate_prometheus(text)`` — a strict structural validator used
  by CI (``benchmarks/obs_bench.py``): metric-name/label grammar,
  float-parseable values, ``# TYPE`` declared before first sample,
  no duplicate (name, labels) series.
- ``registry_snapshot(registry)`` / ``write_jsonl(...)`` — one
  JSON-able dict per call, appended as a line for offline analysis
  (``BENCH_obs.json`` carries one in CI).

Metric names here are chosen by the components (``gateway_*`` /
``stream_*`` / ``cluster_*``) and are already exposition-legal; label
*values* are arbitrary strings and get escaped.
"""
from __future__ import annotations

import json
import re
import time

from .registry import Counter, Gauge, Histogram

__all__ = ["to_prometheus", "validate_prometheus", "registry_snapshot",
           "write_jsonl"]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^{}]*)\})?"
    r" (?P<value>\S+)(?: (?P<ts>-?\d+))?$")
_LABEL_RE = re.compile(
    r'^(?P<key>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<val>(?:[^"\\]|\\.)*)"$')


def _escape(v) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _label_str(pairs) -> str:
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + inner + "}"


def _fmt(v) -> str:
    # integers stay integral (Prometheus accepts both; keeps diffs
    # clean on deterministic suites), floats use repr round-trip
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def to_prometheus(registry, *, prefix: str = "") -> str:
    """The registry in Prometheus text exposition format."""
    by_name: dict = {}
    for m in registry.collect():
        by_name.setdefault(prefix + m.name, []).append(m)
    out = []
    for name, metrics in by_name.items():
        kind = metrics[0].kind
        if kind == "histogram":
            out.append(f"# TYPE {name} summary")
            for m in metrics:
                s = m.sketch
                for q, qv in (("0.5", s.quantile(50)),
                              ("0.95", s.quantile(95))):
                    pairs = list(m.labels) + [("quantile", q)]
                    out.append(f"{name}{_label_str(pairs)} "
                               f"{_fmt(qv if s.count else 0.0)}")
                out.append(f"{name}_sum{_label_str(m.labels)} "
                           f"{_fmt(s.total)}")
                out.append(f"{name}_count{_label_str(m.labels)} "
                           f"{_fmt(s.count)}")
                out.append(f"{name}_max{_label_str(m.labels)} "
                           f"{_fmt(s.vmax if s.count else 0.0)}")
        else:
            out.append(f"# TYPE {name} {kind}")
            for m in metrics:
                out.append(f"{name}{_label_str(m.labels)} "
                           f"{_fmt(m.value)}")
    return "\n".join(out) + "\n" if out else ""


def validate_prometheus(text: str) -> int:
    """Structurally validate exposition text; returns the number of
    samples.  Raises ``ValueError`` with the offending line on any
    grammar violation, type-before-sample violation, or duplicate
    series."""
    declared: dict = {}
    seen_series = set()
    n_samples = 0
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                name = parts[2]
                if not _NAME_RE.match(name):
                    raise ValueError(f"line {lineno}: bad TYPE name "
                                     f"{name!r}")
                if len(parts) < 4 or parts[3] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped"):
                    raise ValueError(f"line {lineno}: bad TYPE kind")
                declared[name] = parts[3]
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample "
                             f"{line!r}")
        name = m.group("name")
        base = name
        for suf in ("_sum", "_count", "_max", "_bucket"):
            if name.endswith(suf) and name[:-len(suf)] in declared:
                base = name[:-len(suf)]
                break
        if base not in declared:
            raise ValueError(f"line {lineno}: sample {name!r} before "
                             f"its # TYPE declaration")
        labels = m.group("labels")
        if labels:
            for part in labels.split(","):
                if not _LABEL_RE.match(part):
                    raise ValueError(f"line {lineno}: bad label "
                                     f"{part!r}")
        try:
            float(m.group("value"))
        except ValueError:
            raise ValueError(f"line {lineno}: non-numeric value "
                             f"{m.group('value')!r}") from None
        series = (name, labels or "")
        if series in seen_series:
            raise ValueError(f"line {lineno}: duplicate series "
                             f"{series}")
        seen_series.add(series)
        n_samples += 1
    return n_samples


def registry_snapshot(registry, *, clock=None) -> dict:
    """One JSON-able dict: every metric's current value (histograms as
    their ``state()`` summary)."""
    metrics = []
    for m in registry.collect():
        entry = {"name": m.name, "labels": m.labels_dict,
                 "kind": m.kind}
        if isinstance(m, Histogram):
            entry["value"] = m.sketch.state()
        elif isinstance(m, (Counter, Gauge)):
            entry["value"] = m.value
        metrics.append(entry)
    return {"t_s": (clock or time.time)(), "metrics": metrics}


def write_jsonl(registry, path, *, step: int = 0, clock=None) -> dict:
    """Append one snapshot line to ``path``; returns the snapshot."""
    snap = registry_snapshot(registry, clock=clock)
    snap["step"] = step
    with open(path, "a") as fh:
        fh.write(json.dumps(snap) + "\n")
    return snap
