"""Bounded flight recorder: the post-mortem trail.

Two rings and one ledger:

- ``traces`` — the most recent finished ``FrameTrace`` spans (the
  tracer retires sampled frames here);
- ``events`` — every *anomalous* decision the serving plane takes,
  with enough attributes to reconstruct it: shed (which frame, how
  stale, against which deadline), deadline miss, preemption, retry
  (attempt, error), failover (member, sessions lost), degraded
  refusal, rate limit, queue-full rejection, hang detection, drain
  stragglers;
- ``counts`` — a cumulative per-kind tally that is **never evicted**.
  The rings are bounded (`deque(maxlen=...)`), so after a long overload
  the oldest sheds fall off the ring — but the acceptance contract
  ("reconstruct shed/failover counts exactly from a dump") is carried
  by ``counts``, which the rings merely illustrate.

``dump()`` is cheap and safe to call from any thread (one small lock —
the recorder is only touched on anomaly paths and per-sampled-frame
retirement, never per-frame when tracing is off).  The cluster calls it
automatically when a member fails (``GatewayCluster.failover_dumps``),
so the black box survives exactly the event it exists to explain.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque

__all__ = ["FlightRecorder", "EVENT_KINDS"]

# the closed vocabulary of anomalies — exporters and the dump schema
# key off these names; adding one is an API change, document it in
# docs/OBSERVABILITY.md
EVENT_KINDS = (
    "shed", "deadline_miss", "preempt", "requeue", "retry",
    "member_failed", "failover", "member_hung", "degraded_refusal",
    "rate_limited", "queue_full", "lost_in_flight", "drain_straggler",
    "journal_replay", "migrate_out", "migrate_in",
)


class FlightRecorder:
    """Ring of recent spans + anomaly events with exact cumulative
    counts."""

    def __init__(self, *, trace_capacity: int = 256,
                 event_capacity: int = 2048, clock=time.perf_counter):
        self.trace_capacity = int(trace_capacity)
        self.event_capacity = int(event_capacity)
        self.clock = clock
        self._traces: deque = deque(maxlen=self.trace_capacity)
        self._events: deque = deque(maxlen=self.event_capacity)
        self._counts: dict = {}
        self._lock = threading.Lock()

    # -- write side ----------------------------------------------------------
    def record(self, kind: str, t_s: float | None = None, **attrs) -> None:
        """One anomalous event.  ``t_s`` defaults to the injected
        clock; attrs are kept verbatim (must be JSON-able)."""
        if t_s is None:
            t_s = self.clock()
        with self._lock:
            self._counts[kind] = self._counts.get(kind, 0) + 1
            self._events.append({"kind": kind, "t_s": t_s, **attrs})

    def keep_trace(self, trace) -> None:
        """Retire a finished ``FrameTrace`` into the span ring."""
        with self._lock:
            self._traces.append(trace)

    # -- read side -----------------------------------------------------------
    def counts(self) -> dict:
        """Cumulative per-kind event counts — exact for the whole run,
        regardless of ring eviction."""
        with self._lock:
            return dict(self._counts)

    def events(self, kind: str | None = None) -> list:
        with self._lock:
            evs = list(self._events)
        return evs if kind is None else [e for e in evs
                                         if e["kind"] == kind]

    def traces(self) -> list:
        with self._lock:
            return list(self._traces)

    def dump(self, *, reason: str = "on_demand") -> dict:
        """JSON-able snapshot of the whole black box."""
        with self._lock:
            return {
                "reason": reason,
                "t_s": self.clock(),
                "counts": dict(self._counts),
                "events": list(self._events),
                "traces": [tr.to_dict() for tr in self._traces],
                "evicted_events": max(
                    0, sum(self._counts.values()) - len(self._events)),
            }

    def dump_json(self, path=None, *, reason: str = "on_demand") -> str:
        """The dump as a JSON string; also written to ``path`` if
        given."""
        text = json.dumps(self.dump(reason=reason), default=str)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
        return text
