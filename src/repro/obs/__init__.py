"""Unified telemetry plane (docs/OBSERVABILITY.md).

- ``registry`` — typed counters/gauges/histograms with a bounded
  streaming quantile sketch; the stats dataclasses are views over it.
- ``trace`` — per-frame span tracing on the injected clock,
  deterministic sampling, zero-cost when off.
- ``recorder`` — bounded flight recorder for anomalies + recent spans,
  auto-dumped on cluster failover.
- ``export`` — Prometheus text format and JSONL snapshots.
"""
from .export import (registry_snapshot, to_prometheus,
                     validate_prometheus, write_jsonl)
from .recorder import EVENT_KINDS, FlightRecorder
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       QuantileSketch)
from .trace import FrameTrace, Tracer, sampled

__all__ = [
    "Counter", "EVENT_KINDS", "FlightRecorder", "FrameTrace", "Gauge",
    "Histogram", "MetricsRegistry", "QuantileSketch", "Tracer",
    "registry_snapshot", "sampled", "to_prometheus",
    "validate_prometheus", "write_jsonl",
]
