"""Fault-tolerance utilities: a transient/fatal fault taxonomy with a
deterministic retry policy, failure injection (tests/chaos), straggler
detection with deadline policy, and an elastic-restart helper.

Everything here is deterministic by construction: the injector's
probabilistic mode is seeded, the retry policy computes its backoff
schedule as a pure function of the attempt index and "waits" through an
injectable ``sleep`` (``None`` in stepped/test mode — no wall-clock
sleeps anywhere), and the straggler monitor keeps only the trailing
``window`` of step durations.
"""
from __future__ import annotations

import random
import statistics
from collections import deque
from dataclasses import dataclass, field


class TransientFault(RuntimeError):
    """A fault worth retrying — a network blip, a preempted RPC, a
    briefly unreachable member.  The fault taxonomy the cluster's
    ``RetryPolicy`` keys on: a ``TransientFault`` raised by a member
    call (submit / step / checkpoint) is retried with backoff; any
    other exception is FATAL and fails the member over immediately.
    """


def is_transient(exc: BaseException) -> bool:
    """The taxonomy predicate ``RetryPolicy`` applies."""
    return isinstance(exc, TransientFault)


@dataclass
class RetryPolicy:
    """Deterministic exponential backoff over ``TransientFault``s.

    ``call(fn)`` invokes ``fn`` up to ``max_attempts`` times total,
    retrying only transient faults (``is_transient``); the backoff
    before retry ``i`` (1-based) is ``base_s * factor**(i-1)`` capped
    at ``max_backoff_s`` — a pure function of the attempt index, no
    jitter, so a chaos test replays the exact same schedule.  The wait
    itself goes through the injectable ``sleep`` callable; the default
    ``None`` waits nothing (stepped mode — the cluster advances on an
    injected clock and must never block the step loop on wall time),
    but the schedule is still computed, reported to ``on_retry`` and
    accumulated in ``backoff_s_total``.

    Exhausting the attempts re-raises the LAST transient fault — the
    caller's fatal path (e.g. ``GatewayCluster._member_failed``) takes
    over, so a persistently "transient" member is eventually treated
    as dead rather than retried forever.
    """

    max_attempts: int = 3          # total attempts, including the first
    base_s: float = 0.05
    factor: float = 2.0
    max_backoff_s: float = 1.0
    sleep: object = None           # callable(delay_s) or None (no wait)
    retries: int = field(default=0, init=False)        # cumulative
    backoff_s_total: float = field(default=0.0, init=False)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_s < 0 or self.factor < 1.0 or self.max_backoff_s < 0:
            raise ValueError("backoff schedule must be non-negative and "
                             "non-decreasing (factor >= 1)")

    def backoff_s(self, retry_index: int) -> float:
        """Backoff before the ``retry_index``-th retry (1-based)."""
        return min(self.base_s * self.factor ** (retry_index - 1),
                   self.max_backoff_s)

    def call(self, fn, *, on_retry=None):
        """Run ``fn`` with retries; transient-only, capped, deterministic.

        ``on_retry(retry_index, backoff_s, exc)`` is invoked before
        each retry (the cluster counts ``ClusterStats.retries`` here).
        """
        attempt = 1
        while True:
            try:
                return fn()
            except TransientFault as e:
                if attempt >= self.max_attempts:
                    raise
                delay = self.backoff_s(attempt)
                self.retries += 1
                self.backoff_s_total += delay
                if on_retry is not None:
                    on_retry(attempt, delay, e)
                if self.sleep is not None:
                    self.sleep(delay)
                attempt += 1


class FailureInjector:
    """Deterministic chaos source for the cluster's member-call seams.

    Three independent modes, all keyed by the caller's step counter:

    - ``fail_at``: raise a FATAL ``RuntimeError`` at the named steps
      (once each — a node loss, not a poisoned step id);
    - ``transient_at``: raise ``TransientFault`` at the named steps; a
      set/sequence fires once per step, a ``{step: n}`` dict fires the
      first ``n`` attempts at that step — so a retry policy with
      ``max_attempts > n`` recovers the member and one with
      ``max_attempts <= n`` exhausts into the fatal path;
    - ``p_transient``: seeded probabilistic mode — every ``maybe_fail``
      call independently raises ``TransientFault`` with probability
      ``p`` from a private ``random.Random(seed)`` stream, so a chaos
      sweep with the same seed replays the exact same fault pattern;
    - ``hang_from``: from that step on, ``hanging(step)`` is True — the
      member is STUCK, not raising: the cluster must skip its turn and
      let heartbeat suspicion (``cluster/health.py``) detect it.
    """

    def __init__(self, fail_at=(), *, transient_at=(), p_transient: float = 0.0,
                 seed: int = 0, hang_from: int | None = None):
        if not 0.0 <= p_transient < 1.0:
            raise ValueError("p_transient must be in [0, 1)")
        self.fail_at = set(fail_at)
        if isinstance(transient_at, dict):
            self.transient_at = {int(s): int(n)
                                 for s, n in transient_at.items()}
        else:
            self.transient_at = {int(s): 1 for s in transient_at}
        self.p_transient = float(p_transient)
        self.hang_from = hang_from
        self.fired = set()
        self.transients_fired = 0
        self._rng = random.Random(seed)

    def hanging(self, step) -> bool:
        """True once the member is stuck (never raises — a hung member
        makes no progress AND reports no error)."""
        return self.hang_from is not None and step >= self.hang_from

    def maybe_fail(self, step):
        remaining = self.transient_at.get(step, 0)
        if remaining > 0:
            self.transient_at[step] = remaining - 1
            self.transients_fired += 1
            raise TransientFault(
                f"injected transient fault at step {step}")
        if self.p_transient and self._rng.random() < self.p_transient:
            self.transients_fired += 1
            raise TransientFault(
                f"injected probabilistic transient fault at step {step}")
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerEvent:
    step: int
    time_s: float
    median_s: float


class StragglerMonitor:
    """Flags steps slower than ``factor`` x trailing-median.

    On a real fleet the policy would be: re-issue the slow shard's work to
    a hot spare / drop the slow host from the next mesh (see
    checkpoint/elastic.py).  Here we record the event and expose it to the
    trainer and tests.

    Retention is bounded: only the trailing ``window`` step durations
    are kept (that is all the median ever reads) — an always-on cluster
    must not grow host state with uptime.
    """

    def __init__(self, factor=3.0, window=50, warmup=5):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.samples = 0                       # total recorded, ever
        self.times = deque(maxlen=window)      # trailing window only
        self.events: list[StragglerEvent] = []

    def record(self, step, dt):
        if self.samples >= self.warmup:
            med = statistics.median(self.times)
            if dt > self.factor * med:
                self.events.append(StragglerEvent(step, dt, med))
        self.times.append(dt)
        self.samples += 1
        return bool(self.events and self.events[-1].step == step)
