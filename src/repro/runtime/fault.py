"""Fault-tolerance utilities: failure injection (tests/chaos), straggler
detection with deadline policy, and an elastic-restart helper."""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field


class FailureInjector:
    """Raises RuntimeError at the given steps — simulates node loss."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerEvent:
    step: int
    time_s: float
    median_s: float


class StragglerMonitor:
    """Flags steps slower than ``factor`` x trailing-median.

    On a real fleet the policy would be: re-issue the slow shard's work to
    a hot spare / drop the slow host from the next mesh (see
    checkpoint/elastic.py).  Here we record the event and expose it to the
    trainer and tests."""

    def __init__(self, factor=3.0, window=50, warmup=5):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.times = []
        self.events: list[StragglerEvent] = []

    def record(self, step, dt):
        if len(self.times) >= self.warmup:
            med = statistics.median(self.times[-self.window:])
            if dt > self.factor * med:
                self.events.append(StragglerEvent(step, dt, med))
        self.times.append(dt)
        return bool(self.events and self.events[-1].step == step)
