"""Minimal metrics logging: JSONL sink + rolling means.

The structured registry lives in ``repro.obs`` (counters, gauges,
quantile sketches, exporters); this logger is the lightweight
*training/benchmark* sink — a JSONL line per ``log()`` call plus a
rolling window mean per key, nothing else.  ``repro.obs.export
.write_jsonl`` snapshots a whole registry through the same file
format, so the two compose: benchmarks log their own scalars here and
dump the serving registry beside them (``benchmarks/obs_bench.py``).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque


class MetricsLogger:
    """JSONL sink + rolling means.

    Parameters
    ----------
    path : append-target JSONL file (parent dirs created); ``None``
        keeps the rolling means only.
    window : samples per key retained for ``mean()``.
    clock : timestamp source for the ``t`` field — injectable so
        deterministic suites and fake-clock benchmarks stamp
        reproducible times (defaults to ``time.time``).

    Context-manager friendly: ``with MetricsLogger(p) as m: ...``
    closes the sink on exit, exceptions included.
    """

    def __init__(self, path=None, window=50, *, clock=time.time):
        self.path = path
        self.window = window
        self.clock = clock
        self.buf: dict = {}            # key -> deque(maxlen=window)
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._f = open(path, "a")
        else:
            self._f = None

    def log(self, step, **kv):
        for k, v in kv.items():
            b = self.buf.get(k)
            if b is None:
                b = self.buf[k] = deque(maxlen=self.window)
            b.append(float(v))
        if self._f:
            self._f.write(json.dumps({"step": step, "t": self.clock(),
                                      **{k: float(v)
                                         for k, v in kv.items()}}) + "\n")
            self._f.flush()

    def mean(self, key):
        """Rolling mean of the last ``window`` samples; NaN for a key
        never logged — and asking does NOT create the key (the old
        defaultdict grew an empty deque per typo'd lookup)."""
        b = self.buf.get(key)
        return sum(b) / len(b) if b else float("nan")

    def close(self):
        if self._f:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
