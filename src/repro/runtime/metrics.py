"""Minimal metrics logging: JSONL sink + rolling means."""
from __future__ import annotations

import json
import os
import time
from collections import defaultdict, deque


class MetricsLogger:
    def __init__(self, path=None, window=50):
        self.path = path
        self.window = window
        self.buf = defaultdict(lambda: deque(maxlen=window))
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._f = open(path, "a")
        else:
            self._f = None

    def log(self, step, **kv):
        for k, v in kv.items():
            self.buf[k].append(float(v))
        if self._f:
            self._f.write(json.dumps({"step": step, "t": time.time(), **{
                k: float(v) for k, v in kv.items()}}) + "\n")
            self._f.flush()

    def mean(self, key):
        b = self.buf[key]
        return sum(b) / len(b) if b else float("nan")

    def close(self):
        if self._f:
            self._f.close()
