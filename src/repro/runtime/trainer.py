"""Training runtime: jitted train step (microbatch accumulation, optional
StreamSplit hybrid auxiliary loss) + a fault-tolerant loop (atomic
checkpoints, auto-restore, straggler monitoring).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.laplacian import laplacian_loss
from repro.core.swd import swd_loss
from repro.models import lm
from repro.optim import get_optimizer
from repro.optim.schedules import SCHEDULES
from repro.runtime.fault import StragglerMonitor
from repro.checkpoint.manager import CheckpointManager


@dataclass(frozen=True)
class TrainCfg:
    optimizer: str = "adamw"
    lr: float = 3e-4
    schedule: str = "cosine"
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    microbatches: int = 1
    # StreamSplit hybrid loss as a first-class training feature: pooled
    # hidden-state "frames" get the diversity (SWD) + affinity (Laplacian)
    # regularizers of Eq. 13.
    hybrid: bool = False
    hybrid_lam_sw: float = 0.1
    hybrid_lam_lap: float = 0.01
    hybrid_pool: int = 64
    seed: int = 0


def make_loss_fn(cfg, tcfg: TrainCfg):
    def loss_fn(params, batch, key):
        loss, metrics = lm.lm_loss(cfg, params, batch)
        hidden = metrics.pop("hidden")
        if tcfg.hybrid:
            B, S, d = hidden.shape
            P = tcfg.hybrid_pool
            T = S // P
            z = hidden[:, : T * P].reshape(B, T, P, d).mean(2)
            z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True),
                                1e-6)
            sw = swd_loss(key, z.reshape(-1, d).astype(jnp.float32))
            lap = laplacian_loss(z.astype(jnp.float32))
            loss = loss + tcfg.hybrid_lam_sw * sw + tcfg.hybrid_lam_lap * lap
            metrics = {**metrics, "swd": sw, "lap": lap}
        return loss, metrics
    return loss_fn


def make_train_step(cfg, tcfg: TrainCfg):
    """(params, opt_state, batch, step, key) -> (params, opt_state, metrics).

    This is the function the dry-run lowers — it contains the full
    fwd+bwd+optimizer graph including any MoE all-to-alls."""
    _, opt_update = get_optimizer(tcfg.optimizer)
    loss_fn = make_loss_fn(cfg, tcfg)
    schedule = SCHEDULES[tcfg.schedule]

    def upd_kwargs():
        if tcfg.optimizer == "adamw":
            return dict(weight_decay=tcfg.weight_decay,
                        grad_clip=tcfg.grad_clip)
        if tcfg.optimizer == "sgd":
            return dict(momentum=0.9)
        return {}

    def train_step(params, opt_state, batch, step, key):
        if tcfg.microbatches > 1:
            n = tcfg.microbatches
            mb = jax.tree.map(
                lambda t: t.reshape((n, t.shape[0] // n) + t.shape[1:]),
                batch)
            keys = jax.random.split(key, n)

            def body(carry, xs):
                g_acc, l_acc = carry
                mb_i, k_i = xs
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_i, k_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32),
                              params)
            (grads, loss), ms = jax.lax.scan(body, (g0, jnp.float32(0.0)),
                                             (mb, keys))
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, key)

        lr = schedule(step, peak=tcfg.lr, warmup=tcfg.warmup,
                      total=tcfg.total_steps)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr,
                                       **upd_kwargs())
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return params, opt_state, {**metrics, "loss": loss, "lr": lr,
                                   "grad_norm": gnorm}

    return train_step


def init_train_state(cfg, tcfg: TrainCfg, key):
    params, axes = lm.init_lm(cfg, key)
    opt_init, _ = get_optimizer(tcfg.optimizer)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}, axes


class Trainer:
    """Fault-tolerant loop: periodic atomic checkpoints, restore-on-failure,
    straggler detection (deadline = factor x trailing median step time)."""

    def __init__(self, cfg, tcfg: TrainCfg, data_fn, *, ckpt_dir=None,
                 ckpt_every=50, keep=3, async_ckpt=True,
                 straggler_factor=3.0, failure_injector=None):
        self.cfg, self.tcfg = cfg, tcfg
        self.data_fn = data_fn
        self.key = jax.random.PRNGKey(tcfg.seed)
        self.state, self.axes = init_train_state(cfg, tcfg, self.key)
        self.train_step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep,
                                       async_save=async_ckpt)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.monitor = StragglerMonitor(factor=straggler_factor)
        self.failure_injector = failure_injector
        self.history = []
        self.restarts = 0
        if self.ckpt:
            restored, step = self.ckpt.restore_latest(self.state)
            if restored is not None:
                self.state = restored
                print(f"[trainer] restored checkpoint at step {step}")

    @property
    def step(self):
        return int(self.state["step"])

    def _one_step(self):
        step = self.step
        if self.failure_injector is not None:
            self.failure_injector.maybe_fail(step)
        batch = self.data_fn(step)
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        params, opt, metrics = self.train_step(
            self.state["params"], self.state["opt"], batch,
            jnp.int32(step), sub)
        metrics = jax.tree.map(float, jax.device_get(metrics))
        dt = time.perf_counter() - t0
        self.monitor.record(step, dt)
        self.state = {"params": params, "opt": opt,
                      "step": jnp.int32(step + 1)}
        self.history.append({"step": step, "time_s": dt, **metrics})
        if self.ckpt and (step + 1) % self.ckpt_every == 0:
            self.ckpt.save(step + 1, self.state, block=False)
        return metrics

    def run(self, n_steps, *, log_every=10, max_restarts=3):
        target = self.step + n_steps
        while self.step < target:
            try:
                m = self._one_step()
            except RuntimeError as e:
                # node failure path: restore latest committed checkpoint
                if self.restarts >= max_restarts or self.ckpt is None:
                    raise
                self.restarts += 1
                self.ckpt.wait()
                restored, step = self.ckpt.restore_latest(self.state)
                if restored is None:
                    self.state, self.axes = init_train_state(
                        self.cfg, self.tcfg, self.key)
                else:
                    self.state = restored
                print(f"[trainer] FAILURE at step ~{self.step} ({e}); "
                      f"restored step {step}, restart #{self.restarts}")
                continue
            if log_every and self.step % log_every == 0:
                print(f"[trainer] step {self.step:5d} "
                      f"loss {m['loss']:.4f} lr {m['lr']:.2e}")
        if self.ckpt:
            self.ckpt.wait()
        return self.history
