"""jax API compatibility shims.

The codebase targets current jax (`jax.shard_map`, ``check_vma``,
``make_mesh(..., axis_types=...)``); CI and some dev boxes pin older
jaxlibs where shard_map still lives in ``jax.experimental`` with the
``check_rep`` spelling and meshes have no axis types.  Route every
mesh/shard_map construction through here instead of sniffing versions at
call sites.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` with graceful fallback to the experimental API
    (where ``check_vma`` was named ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))
