"""Public typed API of the StreamSplit pipeline.

    from repro.api import (StreamSplitGateway, FrameRequest, QoSClass,
                           make_policy)

See docs/API.md for the one-pipeline call flow.
"""
from repro.api.gateway import StreamSplitGateway
from repro.core.fleet_backend import (FleetBackend, HostFleetBackend,
                                      ShardedFleetBackend, make_backend)
from repro.api.policies import (EntropyThresholdPolicy, FixedKPolicy,
                                RLPolicy, RulePolicy, SplitPolicy,
                                make_policy)
from repro.api.types import (AdmissionError, ClusterStats, FrameRequest,
                             FrameResult, GatewayStats, QoSClass,
                             ServerSessionSnapshot, SessionInfo,
                             SessionSnapshot, StreamStats)

__all__ = [
    "StreamSplitGateway",
    "FleetBackend", "HostFleetBackend", "ShardedFleetBackend",
    "make_backend",
    "SplitPolicy", "make_policy", "FixedKPolicy", "RulePolicy", "RLPolicy",
    "EntropyThresholdPolicy",
    "FrameRequest", "FrameResult", "SessionInfo", "GatewayStats",
    "QoSClass", "AdmissionError", "StreamStats",
    "SessionSnapshot", "ServerSessionSnapshot", "ClusterStats",
]
