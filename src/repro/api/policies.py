"""Split policies behind ONE batched interface.

The repo previously exposed placement decisions through two unrelated
conventions: ``core.controller.Controller.decide(obs)`` (rl / rule /
static / edge / server, one observation at a time) and the cascade
server's inline entropy-threshold routing.  ``SplitPolicy`` unifies them:

    decide(obs_batch (B, 3)) -> k_batch (B,)

where each observation row is the control-plane state
``[U_t, R_cpu/100, B_net]`` and each output is the split index for that
frame's NEXT dispatch (the atomic-transition boundary — the gateway never
switches k mid-dispatch; frames bucketed per k each run a whole compiled
program).

Batched decisions are what make k-bucketed dispatch possible: the
gateway asks once per tick for the whole pending set, not once per frame
per session.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class SplitPolicy(Protocol):
    """Anything with a batched ``decide``; ``L`` bounds the action space."""

    L: int

    def decide(self, obs_batch: np.ndarray) -> np.ndarray:
        """obs_batch (B, 3) -> int k_batch (B,) with 0 <= k <= L."""
        ...


class FixedKPolicy:
    """static / edge-only (k=L) / server-only (k=0) in one class."""

    def __init__(self, L: int, k: int):
        self.L = L
        self.k = int(np.clip(k, 0, L))

    def decide(self, obs_batch):
        return np.full(len(obs_batch), self.k, np.int64)


class RulePolicy:
    """The Table 1/4 heuristic, vectorized: offload (shallow k) iff
    bandwidth high AND cpu free, else run fully local.

    Unlike the edge-side ``core.controller.RulePolicy`` this keeps no
    probe EMA: the gateway reads fresh per-frame client telemetry, so the
    slow bandwidth estimate the on-device rule needs (and that costs it
    ~3.5x the RL agent's adaptation time) has nothing to smooth.
    """

    def __init__(self, L, *, bw_threshold=0.12, cpu_threshold=0.6,
                 offload_k=2):
        self.L = L
        self.bw_threshold = bw_threshold
        self.cpu_threshold = cpu_threshold
        self.offload_k = offload_k

    def decide(self, obs_batch):
        obs = np.asarray(obs_batch, np.float32)
        offload = (obs[:, 2] > self.bw_threshold) & \
                  (obs[:, 1] < self.cpu_threshold)
        return np.where(offload, self.offload_k, self.L).astype(np.int64)


class RLPolicy:
    """Greedy PPO policy (core/ppo.py), batched over the tick in one
    forward instead of one ``greedy_action`` call per frame."""

    def __init__(self, L, params):
        self.L = L
        self.params = params

    def decide(self, obs_batch):
        import jax.numpy as jnp
        from repro.core.ppo import policy_apply
        logits, _ = policy_apply(self.params,
                                 jnp.asarray(obs_batch, jnp.float32))
        return np.asarray(jnp.argmax(logits, axis=-1), np.int64)


class EntropyThresholdPolicy:
    """The cascade server's routing as a split policy (paper §6.5.2:
    offload when U_t > 0.7 regardless of platform).

    Low-entropy (easy) frames stay fully local (k=L, the "small tier");
    high-entropy (hard) frames escalate — the edge runs only a shallow
    prefix and the server finishes the stack (k=offload_k, the "large
    tier").  With two possible k values every tick collapses into at most
    two bucketed dispatches, the serving analogue of ``CascadeServer``'s
    two padded sub-batches.
    """

    def __init__(self, L, *, threshold=0.7, offload_k=2):
        self.L = L
        self.threshold = threshold
        self.offload_k = offload_k

    def decide(self, obs_batch):
        obs = np.asarray(obs_batch, np.float32)
        hard = obs[:, 0] > self.threshold
        return np.where(hard, self.offload_k, self.L).astype(np.int64)


def make_policy(kind, L, *, rl_params=None, static_k=3, threshold=0.7,
                offload_k=2, bw_threshold=0.12,
                cpu_threshold=0.6) -> SplitPolicy:
    """One constructor for every placement convention in the repo.

    kind ∈ {"rl", "rule", "static", "edge", "server", "entropy"} — the
    five ``Controller`` kinds plus the cascade's entropy routing.
    """
    if kind == "rl":
        if rl_params is None:
            raise ValueError("rl policy needs rl_params")
        return RLPolicy(L, rl_params)
    if kind == "rule":
        return RulePolicy(L, bw_threshold=bw_threshold,
                          cpu_threshold=cpu_threshold, offload_k=offload_k)
    if kind == "static":
        return FixedKPolicy(L, static_k)
    if kind == "edge":
        return FixedKPolicy(L, L)
    if kind == "server":
        return FixedKPolicy(L, 0)
    if kind == "entropy":
        return EntropyThresholdPolicy(L, threshold=threshold,
                                      offload_k=offload_k)
    raise ValueError(f"unknown policy kind: {kind!r}")
