"""Typed request/response surface of the StreamSplit gateway.

One pipeline, one vocabulary: a client session ``submit``s
``FrameRequest``s, the gateway ``tick`` turns them into ``FrameResult``s
(embedding, route, split index, wire bytes, dispatch latency), and the
aggregate state of the serving plane is a ``GatewayStats``.  Everything
here is a frozen dataclass — values cross the API boundary, never shared
mutable state.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from enum import Enum

import numpy as np

from repro.core.fleet import FleetFullError
from repro.core.sync import SyncCfg, SyncEvent


class QoSClass(Enum):
    """Admission class of a session (ROADMAP: load-aware placement).

    ``INTERACTIVE`` sessions may use every fleet row; ``STANDARD`` and
    ``BULK`` are refused progressively earlier so that headroom remains
    for latency-sensitive tenants (see ``StreamSplitGateway.open_session``).
    """

    INTERACTIVE = "interactive"
    STANDARD = "standard"
    BULK = "bulk"


class AdmissionError(FleetFullError):
    """Typed admission failure of ``open_session``.

    Subclasses ``FleetFullError`` so callers already guarding the raw
    fleet keep working; carries the admission context the raw error
    lacks.  ``qos`` is the class that was refused; ``n_active`` /
    ``capacity`` describe the fleet at refusal time.
    """

    def __init__(self, qos: QoSClass, n_active: int, capacity: int):
        self.qos = qos
        self.n_active = n_active
        self.capacity = capacity
        super().__init__(
            f"admission refused for {qos.value} session: "
            f"{n_active}/{capacity} fleet rows in use")


class ClusterDegradedError(RuntimeError):
    """Typed degraded-mode refusal of a ``GatewayCluster``
    (``repro.cluster``; docs/FEDERATION.md): live capacity fell below
    the configured watermark, so new sessions are refused and BULK
    frames are shed at the door — the surviving members' headroom is
    reserved for the streams they already hold.  Counted
    (``ClusterStats.rejected_degraded``), never silent; the refused
    work never enters ``submitted``, so conservation is untouched."""

    def __init__(self, live: int, expected: int, watermark: float,
                 what: str = "admission"):
        self.live = live
        self.expected = expected
        self.watermark = watermark
        super().__init__(
            f"cluster degraded: {live}/{expected} members live "
            f"(watermark {watermark:.2f}) — {what} refused until "
            "capacity recovers")


class ClusterDrainTimeout(RuntimeError):
    """Typed drain-stall summary of ``GatewayCluster.stop(drain=True)``:
    the step budget ran out with frames still outstanding.  ``stragglers``
    maps each stuck session's global sid to its outstanding frame count
    (submitted but neither served, shed, nor counted lost) — before
    this error a stalled drain exited only through an untyped pump
    failure with no record of WHICH streams were stuck."""

    def __init__(self, stragglers: dict, steps: int):
        self.stragglers = dict(stragglers)
        self.steps = steps
        super().__init__(
            f"cluster drain stalled after {steps} steps: "
            f"{len(self.stragglers)} session(s) still hold "
            f"{sum(self.stragglers.values())} outstanding frame(s) "
            f"(gsids {sorted(self.stragglers)})")


@dataclass(frozen=True)
class FrameRequest:
    """One client frame: the mel payload plus the client-side telemetry
    the split policy consumes.

    ``t`` is the session-local absolute frame index (the temporal-buffer
    key — gaps in ``t`` become gap-mask zeros on the server).  ``u`` /
    ``cpu`` are normalized to [0, 1] like the control-plane observation
    ``s_t = [U_t, R_cpu, B_net]``; ``bandwidth_mbps`` is raw so the lazy
    sync protocol can apply its Wi-Fi threshold.
    """

    t: int
    mel: np.ndarray            # (frames, n_mels) — one sample, no batch dim
    label: int = -1
    u: float = 0.5             # GMM-entropy uncertainty U_t
    cpu: float = 0.25          # edge CPU load fraction
    bandwidth_mbps: float = 10.0
    charging: bool = False     # lazy-sync weight-push eligibility


@dataclass(frozen=True)
class FrameResult:
    """What came back for one frame after the tick's bucketed dispatch."""

    sid: int
    t: int
    z: np.ndarray              # (d_embed,) l2-normalized embedding
    route: str                 # "edge" (k=L) | "server" (k=0) | "split"
    k: int                     # split index the policy chose
    wire_bytes: int            # synchronous split-link payload (0 at k=L)
    # dispatch wall-clock per frame.  On the overlapped data plane the
    # tick is one staged H2D + async bucket chains + ONE sync, so this is
    # the measured per-TICK figure (tick dispatch time / frames served);
    # ``tick(profile=True)`` restores per-bucket timing (one sync per
    # bucket — a diagnostic mode, not the serving path).
    latency_ms: float
    bucket_size: int           # how many frames shared this dispatch
    shard: int = 0             # dispatch shard that ran this frame's
    #                            chain (0 on the unsharded plane) — also
    #                            stamped on the frame's trace span


@dataclass(frozen=True)
class SessionInfo:
    """Point-in-time snapshot of one session (returned by ``open_session``,
    ``session`` and ``close_session`` — never live state)."""

    sid: int
    platform: str
    qos: QoSClass
    frames: int                # frames served through the gateway
    wire_bytes: int            # cumulative split-link bytes
    sync_bytes: int            # cumulative lazy-sync downlink bytes
    sync_events: int
    transitions: int           # split-index changes (atomic transitions)
    last_k: int                # -1 before the first served frame
    fill_fraction: float       # of the server-side temporal ring


@dataclass(frozen=True)
class QueuedFrameSnapshot:
    """One queued-but-unserved frame inside a ``SessionSnapshot`` —
    enough to re-enqueue it on another gateway with its ORIGINAL arrival
    time and deadline (migration must not grant waiting frames a fresh
    deadline budget, nor steal the wait they already paid)."""

    frame: FrameRequest
    enq_s: float               # original submit time (caller clock)
    deadline_s: float          # original deadline — survives migration
    preemptions: int = 0
    promoted: bool = False
    weight: float = 1.0
    # the frame's live FrameTrace (repro.obs.trace) when it is sampled —
    # the span itself migrates, so a trace begun on the source member
    # continues seamlessly on the target (None when tracing is off)
    trace: object = None


@dataclass(frozen=True)
class ServerSessionSnapshot:
    """The streaming-runtime half of a ``SessionSnapshot``: per-session
    conservation books, fair-share weight, token-bucket level, and every
    frame still waiting in the QoS queues (oldest first)."""

    submitted: int             # frames accepted into the queues
    served: int                # frames delivered as FrameResults
    shed: int                  # frames visibly shed past the horizon
    weight: float              # STANDARD DRR fair-share weight
    # (rate_per_s, burst, tokens, last_refill_s) or None — the bucket
    # level migrates so a rate-limited tenant cannot reset its budget by
    # riding a rebalance
    bucket: tuple | None = None
    queued: tuple = ()         # QueuedFrameSnapshot, oldest first


@dataclass(frozen=True)
class SessionSnapshot:
    """Everything one session *is*, frozen and serializable — the unit
    of live migration between gateways (``repro.cluster``;
    docs/FEDERATION.md).

    Three layers: the gateway's per-session books (frames, wire bytes,
    split transitions, last k), the lazy-sync protocol counters
    (``core/sync.py`` — cadence state plus emitted events, so the
    downlink timeline continues instead of restarting), and the fleet
    ring row (``(W, d)`` embeddings + timestamps + labels + newest, in
    the host representation so a row exported from any ``FleetBackend``
    implants into any other).  ``server`` carries the streaming
    runtime's half when the session was exported from a ``StreamServer``
    (None from a bare gateway).  Restoring a snapshot onto a fresh
    gateway and replaying the same admitted schedule reproduces every
    embedding and refine loss bit-for-bit (``tests/test_cluster.py``'s
    sequential-replay oracle)."""

    platform: str
    qos: QoSClass
    # gateway per-session books
    frames: int
    wire_bytes: int
    transitions: int
    last_k: int
    # lazy-sync protocol state (core/sync.py)
    sync_cfg: SyncCfg
    sync_last_gmm: int
    sync_last_weights: int
    sync_total_bytes: int
    sync_total_energy_j: float
    sync_events: tuple         # emitted SyncEvents, oldest first
    # fleet ring row (host representation; see FleetBackend.export_row)
    ring_z: np.ndarray         # (W, d) float32
    ring_t: np.ndarray         # (W,) int64, T_SENTINEL marks empty slots
    ring_label: np.ndarray     # (W,) int64
    ring_newest: int
    server: ServerSessionSnapshot | None = None
    version: int = 1

    def to_bytes(self) -> bytes:
        """Wire form of the migration transfer (also what the cluster
        meters as ``ClusterStats.migrated_bytes``)."""
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(payload: bytes) -> "SessionSnapshot":
        snap = pickle.loads(payload)
        if not isinstance(snap, SessionSnapshot):
            raise TypeError("payload is not a SessionSnapshot")
        return snap

    @property
    def nbytes(self) -> int:
        return len(self.to_bytes())


@dataclass(frozen=True)
class GatewayStats:
    """Aggregate serving-plane counters (one pipeline, one scoreboard)."""

    ticks: int
    frames: int
    sessions_open: int
    sessions_opened: int
    sessions_closed: int
    admission_refusals: int
    dispatches: int            # k-bucket SplitEngine dispatches issued
    wire_bytes: int
    sync_bytes: int            # lazy-sync downlink across all sessions
    sync_events: int
    refine_rounds: int
    last_refine_loss: float    # nan before the first round
    routed: dict               # route -> frame count ("edge"/"split"/"server")
    # fleet-backend data plane (host vs device-resident sharded)
    backend: str = "host"      # FleetBackend.kind
    shards: int = 1            # session mesh-axis size (1 on host backend)
    shard_frames: tuple = ()   # frames ingested per session shard
    # sharded dispatch plane (StreamSplitGateway shard_dispatch=True,
    # docs/SHARDING.md): the per-tick edge→wire→server chains themselves
    # run per device, co-located with each session's fleet shard
    dispatch_shards: int = 1   # devices the tick dispatch spreads over
    dispatch_shard_frames: tuple = ()  # frames dispatched per shard
    snapshot_h2d_bytes: int = 0  # fleet snapshot bytes copied per refine
    ingest_h2d_bytes: int = 0  # frame payload bytes moved host->device
    # overlapped tick data plane (docs/PERF.md): the dispatch chain is
    # issued asynchronously and synced ONCE per tick, so a mixed-k tick
    # costs one device round-trip regardless of bucket count.  Both
    # counters cover the DISPATCH plane only — a periodic refine round
    # blocks on its own loss read outside this scoreboard.
    device_syncs_per_tick: int = 0   # dispatch-plane waits, last tick
    d2h_copies_per_tick: int = 0     # embedding D2H copies, last tick
    staged_h2d_bytes: int = 0  # cumulative mel bytes staged host->device
    # deterministic under an injected clock= (see StreamSplitGateway)
    uptime_s: float = 0.0      # clock() - clock() at construction
    # wall-clock of the most recent tick, launch -> collect.  Under the
    # streaming runtime's cross-tick pipelining this span deliberately
    # INCLUDES the next tick's interleaved staging/launch — it is the
    # tick's in-flight lifetime, not its exclusive compute cost.
    last_tick_ms: float = 0.0
    # live-migration seams (repro.cluster): sessions that left/arrived
    # via export_session/import_session — distinct from opened/closed, a
    # migration is neither an admission decision nor a client departure
    sessions_exported: int = 0
    sessions_imported: int = 0

    @property
    def frames_per_dispatch(self) -> float:
        """The batching win: 1.0 is the per-frame loop; N/buckets when
        k-bucketing collapses a tick into few dispatches."""
        return self.frames / self.dispatches if self.dispatches else 0.0


@dataclass(frozen=True)
class StreamStats:
    """Point-in-time scoreboard of the streaming serving runtime
    (``repro.serving.StreamServer``; docs/STREAMING.md).

    Every per-class dict is keyed by the ``QoSClass.value`` strings
    (``"interactive"``/``"standard"``/``"bulk"``) so the whole snapshot
    is JSON-serializable as-is (``benchmarks/stream_serve.py`` writes
    it).  Conservation is an invariant, not a hope: per class,
    ``frames_submitted == frames_served + queue_depth + in_flight
    + shed_expired`` at every snapshot, and ``preempted == requeued``
    always — a preempted frame goes back to the front of its queue; a
    frame only ever leaves the system as a served ``FrameResult`` or as
    a *counted* shed (deadline expired past the configured horizon),
    never silently.  Frames refused at submit raise a typed error and
    count WITHOUT entering ``frames_submitted``: ``QueueFullError`` →
    ``rejected_full`` (bounded queue) and ``RateLimitError`` →
    ``rejected_rate_limited`` (per-session token bucket).
    """

    running: bool              # serving thread alive right now
    ticks: int                 # ticks the runtime has collected
    pipelined_ticks: int       # launched while the previous tick's chains
    #                            were still in flight (cross-tick overlap)
    frames_submitted: dict     # class -> frames accepted into the queues
    frames_served: dict        # class -> frames delivered as FrameResults
    queue_depth: dict          # class -> frames waiting (queued + staged)
    in_flight: dict            # class -> frames launched, not yet collected
    rejected_full: dict        # class -> bounded-queue refusals at submit
    rejected_rate_limited: dict  # class -> token-bucket refusals at submit
    preempted: dict            # class -> frames bumped from a staged tick
    requeued: dict             # class -> preempted frames put back (== preempted)
    shed_expired: dict         # class -> frames dropped visibly: deadline
    #                            expired past SchedulerCfg.shed_horizon_ms
    promoted: dict             # class -> frames staged via the aging lane
    #                            (waited past SchedulerCfg.max_wait_ms)
    deadline_misses: dict      # class -> frames admitted past their deadline
    #                            PLUS shed frames (starved-in-queue misses
    #                            are counted at shed time, not hidden)
    queue_wait_ms: dict        # class -> {"p50","p95","mean","max"} wait
    #                            between submit and tick admission (shed
    #                            frames sample their terminal wait too)
    gateway: GatewayStats      # the dispatch-plane scoreboard underneath


@dataclass(frozen=True)
class ClusterStats:
    """Cluster-wide scoreboard of a ``GatewayCluster``
    (``repro.cluster``; docs/FEDERATION.md).

    Per-class dicts are keyed by ``QoSClass.value`` strings, like
    ``StreamStats``.  The cluster keeps its OWN conservation books at
    the federation boundary — ``submitted`` counts accepted
    ``GatewayCluster.submit`` calls, ``served``/``shed_expired`` count
    delivery/shed callbacks — so the invariant survives member death
    (a dead member's counters are unreadable; the frames it held are
    never silently forgotten, they land in ``lost_in_flight``):

        submitted == served + queue_depth + in_flight
                     + shed_expired + lost_in_flight      (per class)

    at every snapshot, where ``queue_depth``/``in_flight`` sum over the
    LIVE members.  ``conserved`` checks it.
    """

    members: tuple             # live member names, routing order
    sessions_open: int
    submitted: dict            # class -> frames accepted by the cluster
    served: dict               # class -> FrameResults delivered
    queue_depth: dict          # class -> waiting frames over live members
    in_flight: dict            # class -> launched-not-collected frames
    shed_expired: dict         # class -> visible sheds (cluster-tracked)
    lost_in_flight: dict       # class -> frames lost to member failure —
    #                            explicitly counted, never silent
    rejected_full: dict        # class -> bounded-queue refusals
    rejected_rate_limited: dict  # class -> token-bucket refusals
    migrations: int            # sessions moved between members
    migrated_frames: int       # queued frames replayed on a new owner
    migrated_bytes: int        # serialized SessionSnapshot payload bytes
    migration_pause_ms: dict   # {"p50","p95","max"} per-session pause
    drains: int                # completed drain() calls
    failures: int              # members lost and recovered from
    ring_share: dict           # member -> owned fraction of hash space
    member_stats: dict         # member -> StreamStats (live members)
    # self-healing federation (PR 9; cluster/{replication,health}.py):
    degraded: bool = False     # live capacity below the watermark NOW
    failovers: int = 0         # sessions restored onto a survivor
    retries: int = 0           # transient member faults retried away
    replayed_frames: int = 0   # journal entries re-queued by failovers
    journal_bytes: int = 0     # bytes shipped over the owner->buddy seam
    rejected_degraded: dict = field(default_factory=dict)
    #                            class -> degraded-mode door refusals
    #                            (not in ``submitted``, like other rejects)
    drain_stragglers: int = 0  # sessions stuck at a stop(drain=True)
    #                            timeout (see ClusterDrainTimeout)

    @property
    def conserved(self) -> bool:
        """The cluster-wide per-class conservation identity."""
        return all(
            self.submitted[c] == self.served[c] + self.queue_depth[c]
            + self.in_flight[c] + self.shed_expired[c]
            + self.lost_in_flight[c]
            for c in (q.value for q in QoSClass))


@dataclass(frozen=True)
class ResourceSignals:
    """The serving plane's resource state as a control-plane
    observation (``StreamServer.resource_signals()``;
    docs/OBSERVABILITY.md).

    This is the view the paper's RL splitter needs beside embedding
    ambiguity — "real-time resource monitoring" (PAPER.md §1) — and the
    view the ROADMAP's open autoscaler item is blocked on.  Everything
    is derived from the metrics registry at call time: queue pressure
    (depth over capacity), tail latency (p95 admission wait + the
    always-on EWMA stage timings), and loss pressure (shed/reject
    fraction of recent submissions).  ``as_observation()`` flattens to
    a normalized float vector shaped like the existing ``SplitPolicy``
    observation convention (each component in [0, 1] or clamped there).
    """

    queue_depth: int           # frames waiting across all classes
    queue_fill: float          # depth / total capacity, in [0, 1]
    in_flight: int             # frames launched, not yet collected
    wait_p95_ms: float         # p95 submit->admit wait (sketch)
    stage_ewma_ms: float       # EWMA tick launch+collect span
    shed_rate: float           # shed / submitted (cumulative), [0, 1]
    reject_rate: float         # refused / offered at the door, [0, 1]
    throughput_fps: float      # frames served per second of uptime

    def as_observation(self) -> "np.ndarray":
        """Normalized float32 vector for a ``SplitPolicy``: load,
        latency (saturating at 1s), and loss pressure."""
        return np.asarray(
            [min(1.0, max(0.0, self.queue_fill)),
             min(1.0, self.wait_p95_ms / 1e3),
             min(1.0, self.stage_ewma_ms / 1e3),
             min(1.0, max(0.0, self.shed_rate)),
             min(1.0, max(0.0, self.reject_rate))],
            dtype=np.float32)
