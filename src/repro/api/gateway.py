"""``StreamSplitGateway`` — THE way to run the StreamSplit pipeline.

One typed surface over what used to be six hand-wired call conventions:

    gw = StreamSplitGateway(enc_cfg, params, policy=make_policy("rule", L))
    info = gw.open_session(platform="pi4", qos=QoSClass.STANDARD)
    gw.submit(info.sid, FrameRequest(t=0, mel=mel, u=0.3, ...))
    results = gw.tick()          # decide -> k-bucketed dispatch -> ingest
    gw.close_session(info.sid)

Internally the gateway owns admission into a ``FleetBackend``, per-tick
**k-bucketed batched split execution**, periodic fleet refinement
rounds, and per-session ``LazySync`` accounting.  The fleet data plane
is pluggable (``backend=``): the default ``HostFleetBackend`` keeps the
session rings in host numpy, while ``ShardedFleetBackend`` keeps them
device-resident and sharded over a ``sessions`` mesh axis, refining the
whole fleet in one ``shard_map`` step (see ``core/fleet_backend.py`` and
``docs/SHARDING.md``).  The serving hot path: every frame whose policy
decision landed on the same split index k rides ONE padded
``SplitEngine`` dispatch (the serving analogue of
``CascadeServer.handle``'s two sub-batches) instead of one ``run()`` per
frame — embeddings stay bit-identical to the per-frame path
(``benchmarks/gateway_serve.py`` measures the speedup and asserts the
bit-parity; ``tests/test_gateway.py`` pins it).

The tick itself is an **overlapped, single-sync data plane**
(docs/PERF.md): the whole tick's frames are staged host→device as ONE
``(B, frames, n_mels)`` transfer, each k-bucket gathers its rows on
device (``jnp.take``) and issues its edge→wire→server chain
asynchronously, and the tick blocks exactly once on the concatenated
embeddings — one device sync and one device→host copy per tick, however
many buckets the policy produced.  ``overlap=False`` restores the PR-3
per-bucket-sync dispatch (the benchmark baseline), and
``tick(profile=True)`` trades the single sync for per-bucket timing.

All wall-clock reads go through the injectable ``clock=`` callable
(default ``time.perf_counter``), so latency/uptime numbers in
``FrameResult``/``GatewayStats`` are deterministic under a fake clock in
tests.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policies import SplitPolicy
from repro.api.types import (AdmissionError, FrameRequest, FrameResult,
                             GatewayStats, QoSClass, SessionInfo)
from repro.core.env import EdgeCloudEnv
from repro.core.fleet import FleetFullError, HostFleetBackend, pad_pow2
from repro.core.splitter import SplitEngine
from repro.core.sync import LazySync, SyncCfg


class _Session:
    """Mutable per-session record (internal — the API hands out frozen
    ``SessionInfo`` snapshots only)."""

    __slots__ = ("sid", "platform", "qos", "sync", "frames", "wire_bytes",
                 "transitions", "last_k")

    def __init__(self, sid, platform, qos, sync_cfg):
        self.sid = sid
        self.platform = platform
        self.qos = qos
        self.sync = LazySync(sync_cfg)
        self.frames = 0
        self.wire_bytes = 0
        self.transitions = 0
        self.last_k = -1


class StreamSplitGateway:
    """Session/gateway layer over the whole edge–cloud pipeline.

    Parameters
    ----------
    enc_cfg, params : the audio encoder config + weights the split engine
        executes (``core/*`` semantics unchanged — the gateway is a
        dispatch layer, not a new model).
    policy : a batched ``SplitPolicy`` (see ``api/policies.py``).
    backend : a ``FleetBackend`` owning the session rings + refinement.
        Defaults to a ``HostFleetBackend`` built from ``capacity`` /
        ``window`` / ``head_init`` / ``head_apply`` / ``refine_lr`` /
        ``seed``; pass a ``ShardedFleetBackend`` to shard the fleet over
        a ``sessions`` mesh (those ctor args are then ignored — the
        backend already owns them).
    capacity, window : fleet dimensions; the server-side temporal rings
        are ``(capacity, window, enc_cfg.d_embed)``.
    head_init, head_apply : optional task head for fleet refinement;
        without them the gateway serves embeddings but never refines.
    refine_every : run one fleet-wide refinement round every this many
        ticks (0 disables).
    qos_reserve : fleet rows held back from BULK (2x) and STANDARD (1x)
        admissions so INTERACTIVE tenants always find room; defaults to
        ``capacity // 8``.
    overlap : serve ticks through the overlapped single-sync data plane
        (default).  ``False`` restores the PR-3 per-bucket-sync dispatch
        — one host staging + device round-trip per k-bucket — kept as
        the measured baseline of ``benchmarks/gateway_serve.py`` and the
        bit-parity reference of ``tests/test_gateway.py``.
    clock : zero-arg callable returning seconds (default
        ``time.perf_counter``) — every timing stat derives from it.
    """

    def __init__(self, enc_cfg, params, *, policy: SplitPolicy,
                 backend=None, capacity=64, window=100, head_init=None,
                 head_apply=None, refine_every=0, quantize_wire=True,
                 sync_cfg=None, qos_reserve=None, refine_lr=1e-2, seed=0,
                 overlap=True, clock=time.perf_counter):
        if policy.L != enc_cfg.n_blocks:
            raise ValueError(
                f"policy action space L={policy.L} != encoder "
                f"n_blocks={enc_cfg.n_blocks}")
        self.cfg = enc_cfg
        self.params = params
        self.policy = policy
        self.engine = SplitEngine(enc_cfg, quantize_wire=quantize_wire)
        if backend is None:
            backend = HostFleetBackend(
                capacity=capacity, window=window, dim=enc_cfg.d_embed,
                head_init=head_init, head_apply=head_apply, lr=refine_lr,
                seed=seed)
        elif backend.dim != enc_cfg.d_embed:
            raise ValueError(
                f"backend dim={backend.dim} != encoder "
                f"d_embed={enc_cfg.d_embed}")
        self.backend = backend
        self.sync_cfg = sync_cfg or SyncCfg()
        self.qos_reserve = (backend.capacity // 8 if qos_reserve is None
                            else qos_reserve)
        self.refine_every = refine_every
        self.overlap = overlap
        self._clock = clock
        self._t_start = clock()
        self._key = jax.random.PRNGKey(seed)
        self._sessions: dict[int, _Session] = {}
        # (sid, request, validated float32 mel) — converted ONCE at submit
        self._pending: list[tuple[int, FrameRequest, np.ndarray]] = []
        # aggregate counters (surfaced as GatewayStats)
        self._ticks = 0
        self._frames = 0
        self._opened = 0
        self._closed = 0
        self._refusals = 0
        self._dispatches = 0
        self._wire_bytes = 0
        self._sync_bytes = 0
        self._sync_events = 0
        self._refine_rounds = 0
        self._last_refine_loss = float("nan")
        self._last_tick_ms = 0.0
        self._routed = {"edge": 0, "split": 0, "server": 0}
        self._shard_frames = np.zeros(backend.shards, np.int64)
        # overlapped data plane instrumentation: every blocking wait and
        # every embedding D2H copy inside tick() goes through _block/_d2h,
        # so the single-sync contract is countable (and pinned by test)
        self._staged_h2d = 0
        self._tick_syncs = 0
        self._tick_d2h = 0

    # -- session lifecycle ---------------------------------------------------
    def open_session(self, platform="pi4",
                     qos: QoSClass = QoSClass.STANDARD) -> SessionInfo:
        """Admit a session into the fleet; raises ``AdmissionError`` (a
        ``FleetFullError``) when its QoS class finds no headroom."""
        free = self.backend.capacity - self.backend.n_active
        need = {QoSClass.INTERACTIVE: 1,
                QoSClass.STANDARD: 1 + self.qos_reserve,
                QoSClass.BULK: 1 + 2 * self.qos_reserve}[qos]
        if free < need:
            self._refusals += 1
            raise AdmissionError(qos, self.backend.n_active,
                                 self.backend.capacity)
        try:
            sid = self.backend.admit()
        except FleetFullError:
            self._refusals += 1
            raise AdmissionError(qos, self.backend.n_active,
                                 self.backend.capacity) from None
        self._sessions[sid] = _Session(sid, platform, qos, self.sync_cfg)
        self._opened += 1
        return self.session(sid)

    def session(self, sid) -> SessionInfo:
        s = self._require(sid)
        return SessionInfo(
            sid=s.sid, platform=s.platform, qos=s.qos, frames=s.frames,
            wire_bytes=s.wire_bytes, sync_bytes=s.sync.total_bytes,
            sync_events=len(s.sync.events), transitions=s.transitions,
            last_k=s.last_k, fill_fraction=self.backend.fill_fraction(sid))

    def close_session(self, sid) -> SessionInfo:
        """Evict the session (O(1) — the fleet row is wiped lazily on its
        next admission).  Unserved pending frames are discarded."""
        info = self.session(sid)
        self._pending = [p for p in self._pending if p[0] != sid]
        self.backend.evict(sid)
        del self._sessions[sid]
        self._closed += 1
        return info

    def _require(self, sid) -> _Session:
        if sid not in self._sessions:
            raise KeyError(f"session {sid} is not open")
        return self._sessions[sid]

    # -- ingest --------------------------------------------------------------
    def submit(self, sid, frame: FrameRequest) -> None:
        """Queue one frame for the next ``tick``.

        The mel payload is validated AND converted to float32 here, once
        — ``tick`` stages the stored array directly, so no frame is ever
        converted twice (the seed path re-ran ``np.asarray`` per
        dispatch)."""
        self._require(sid)
        mel = np.asarray(frame.mel, np.float32)
        if mel.shape != (self.cfg.frames, self.cfg.n_mels):
            raise ValueError(
                f"frame.mel shape {mel.shape} != "
                f"({self.cfg.frames}, {self.cfg.n_mels}) — submit one "
                "unbatched sample per FrameRequest")
        self._pending.append((sid, frame, mel))

    # -- the pipeline tick ---------------------------------------------------
    def tick(self, *, profile=False) -> list[FrameResult]:
        """Decide -> k-bucketed batched dispatch -> ingest -> sync ->
        (periodic) refine.  Returns results in submission order.

        On the overlapped plane (``overlap=True``) the dispatch costs one
        staged H2D transfer, one device sync and one D2H embedding copy
        per tick — every bucket's chain runs asynchronously in between.
        ``profile=True`` syncs after each bucket instead, so
        ``FrameResult.latency_ms`` is per-bucket (diagnostics; the tick
        then pays one round-trip per bucket like ``overlap=False``)."""
        t0 = self._clock()
        pending, self._pending = self._pending, []
        results: list[FrameResult | None] = [None] * len(pending)
        self._tick_dev: list = []     # (bucket idx, device z) per dispatch
        self._tick_syncs = 0
        self._tick_d2h = 0
        if pending:
            # normalize bandwidth exactly like the control-plane env so RL
            # policies see the feature scale they were trained on
            bw_norm = EdgeCloudEnv.BW_NORM
            obs = np.array([[f.u, f.cpu, min(f.bandwidth_mbps / bw_norm, 1.0)]
                            for _, f, _ in pending], np.float32)
            ks = np.clip(np.asarray(self.policy.decide(obs), np.int64),
                         0, self.cfg.n_blocks)
            buckets: dict[int, list[int]] = {}
            for i, k in enumerate(ks):
                buckets.setdefault(int(k), []).append(i)
            if self.overlap:
                # handles its own ingest: fleet scatter + lazy-sync
                # accounting are issued BEFORE the sync point so they
                # overlap the in-flight device chains
                self._dispatch_overlapped(buckets, pending, results,
                                          profile)
            else:
                for k, idx in sorted(buckets.items()):
                    self._dispatch(k, idx, pending, results)
                self._ingest(pending, results)
        self._ticks += 1
        if (self.backend.can_refine and self.refine_every
                and self._ticks % self.refine_every == 0
                and self.backend.n_active):
            key = jax.random.fold_in(self._key, self._refine_rounds)
            loss, _, _ = self.backend.refine(key)
            self._refine_rounds += 1
            self._last_refine_loss = loss
        self._last_tick_ms = (self._clock() - t0) * 1e3
        return results  # type: ignore[return-value]

    # instrumented sync points: every blocking wait and embedding D2H
    # copy in the DISPATCH plane routes through these two, so the
    # single-sync contract is a counted fact
    # (GatewayStats.device_syncs_per_tick / d2h_copies_per_tick), not an
    # assumption.  A periodic backend.refine() blocks on its own loss
    # read and is deliberately outside this scoreboard.
    def _block(self, x):
        self._tick_syncs += 1
        return jax.block_until_ready(x)

    def _d2h(self, x):
        self._tick_d2h += 1
        return np.asarray(x)

    def _dispatch_overlapped(self, buckets, pending, results, profile):
        """The overlapped tick data plane: ONE staged H2D for the whole
        tick, device-side bucket gathers, async edge→wire→server chains,
        then exactly one sync + one D2H of the concatenated embeddings.

        Everything the host can do without the embedding *values* —
        session/wire counters, lazy-sync accounting, and (on a
        device-resident backend) the fleet ring scatter — is issued
        BEFORE the sync point, hiding that work under the in-flight
        device chains.  Only ``FrameResult`` construction (which needs
        the host values) and a host backend's ring insert wait."""
        t_d0 = self._clock()
        # (1) stage the whole tick's frames as ONE host->device transfer
        mel_host = np.stack([m for _, _, m in pending])
        staged = jax.device_put(mel_host)
        self._staged_h2d += mel_host.nbytes
        # (2) per-bucket device-side gathers + async dispatch chains
        launched = []   # (k, idx, padded z_dev, wire, per-bucket ms)
        pos = np.empty(len(pending), np.int32)   # frame i -> row in concat
        offset = 0
        for k, idx in sorted(buckets.items()):
            t_b = self._clock() if profile else None
            padded = pad_pow2(len(idx))
            gather = np.asarray(idx + idx[:1] * (padded - len(idx)),
                                np.int32)
            mel_b = jnp.take(staged, gather, axis=0)
            z_dev, wire = self.engine.run_batch_async(self.params, mel_b, k)
            ms = None
            if profile:   # diagnostic mode: per-bucket round-trips
                self._block(z_dev)
                ms = (self._clock() - t_b) * 1e3 / len(idx)
            launched.append((k, idx, z_dev, wire, ms))
            pos[idx] = offset + np.arange(len(idx), dtype=np.int32)
            offset += padded
        # (3) reassemble into submission order ON DEVICE — one gather
        # straight out of the padded concat (drops pad rows + un-buckets
        # in the same op)
        z_all = jnp.take(
            jnp.concatenate([z for _, _, z, _, _ in launched]), pos, axis=0)
        # (4) host bookkeeping + device-resident fleet scatter, all while
        # the chains are still in flight
        for k, idx, _, wire, _ in launched:
            self._account_bucket(k, idx, pending, wire)
        if self.backend.device_ingest:
            self._ingest_fleet(pending, z_all)     # async device scatter
        self._sync_accounting(pending)
        # (5) THE tick's one device sync + one D2H copy.  In profile
        # mode the bucket chains are already done, but the reassembly
        # gather still needs its own (counted) wait — np.asarray would
        # otherwise block uncounted inside _d2h.
        z_all = self._block(z_all)
        z_host = self._d2h(z_all)
        tick_ms = (self._clock() - t_d0) * 1e3 / len(pending)
        if not self.backend.device_ingest:
            self._ingest_fleet(pending, z_host)
        for k, idx, _, wire, ms in launched:
            route = self._route(k)
            for i in idx:
                sid, req, _ = pending[i]
                results[i] = FrameResult(
                    sid=sid, t=req.t, z=z_host[i], route=route, k=k,
                    wire_bytes=wire, latency_ms=ms if profile else tick_ms,
                    bucket_size=len(idx))

    def _route(self, k):
        return ("edge" if k >= self.cfg.n_blocks
                else "server" if k == 0 else "split")

    def _account_bucket(self, k, idx, pending, wire):
        """Per-bucket serving counters + per-session accounting (pure
        host state — needs no embedding values, so the overlapped plane
        runs it under the in-flight dispatches; the PR-3 path shares it
        so the two planes can never drift apart in what they report)."""
        route = self._route(k)
        self._dispatches += 1
        self._frames += len(idx)
        self._wire_bytes += wire * len(idx)
        self._routed[route] += len(idx)
        for i in idx:
            sid = pending[i][0]
            s = self._sessions[sid]
            if s.last_k >= 0 and k != s.last_k:
                s.transitions += 1
            s.last_k = k
            s.frames += 1
            s.wire_bytes += wire

    def _dispatch(self, k, idx, pending, results):
        """The PR-3 per-bucket-sync dispatch (``overlap=False``): host
        staging, one ``run_batch``, one blocking round-trip — per bucket.
        Kept behaviorally identical to PR 3 as the measured baseline +
        bit-parity reference (it shares ``_account_bucket`` with the
        overlapped plane so the two can never drift in what they
        report)."""
        t0 = self._clock()
        mel = np.stack([pending[i][2] for i in idx])
        pad = pad_pow2(len(idx))
        if pad > len(idx):   # repeat-pad: shape buckets stay compiled
            mel = np.concatenate(
                [mel, np.broadcast_to(mel[:1], (pad - len(idx),)
                                      + mel.shape[1:])])
        z_dev, wire = self.engine.run_batch(self.params, mel, k)
        if self.backend.device_ingest:   # fleet ingest skips the host hop
            self._tick_dev.append((idx, z_dev[:len(idx)]))
        z = self._d2h(self._block(z_dev))[:len(idx)]
        ms = (self._clock() - t0) * 1e3 / len(idx)
        self._account_bucket(k, idx, pending, wire)
        route = self._route(k)
        for j, i in enumerate(idx):
            sid, req, _ = pending[i]
            results[i] = FrameResult(
                sid=sid, t=req.t, z=z[j], route=route, k=k,
                wire_bytes=wire, latency_ms=ms, bucket_size=len(idx))

    def _ingest_fleet(self, pending, zs):
        """Fleet-backend ingest of the tick's submission-ordered
        embeddings.  On a device-resident backend ``zs`` is the
        ``jax.Array`` the dispatches produced — the payload flows
        dispatch → rings without ever touching the host (the host copy
        in ``results`` exists only for the clients); on a host backend
        it is the host copy the tick already made."""
        sids = np.array([sid for sid, _, _ in pending], np.int64)
        ts = np.array([f.t for _, f, _ in pending], np.int64)
        labels = np.array([f.label for _, f, _ in pending], np.int64)
        self.backend.insert_batch(sids, ts, zs, labels)
        self._shard_frames += np.bincount(
            self.backend.shards_of(sids), minlength=self.backend.shards)

    def _sync_accounting(self, pending):
        """Per-session lazy-sync protocol accounting (host state only —
        the overlapped plane runs it under the in-flight dispatches)."""
        for sid, req, _ in pending:
            s = self._sessions[sid]
            for ev in s.sync.on_frame(req.t, charging=req.charging,
                                      bandwidth_mbps=req.bandwidth_mbps):
                self._sync_bytes += ev.bytes
                self._sync_events += 1

    def _ingest(self, pending, results):
        """The PR-3 composite ingest (``overlap=False`` only): reassemble
        the per-dispatch device slices into submission order, insert,
        then run lazy-sync accounting."""
        if self.backend.device_ingest:
            order = np.concatenate(
                [np.asarray(idx) for idx, _ in self._tick_dev])
            zs = jnp.concatenate([z for _, z in self._tick_dev])[
                np.argsort(order)]
        else:
            zs = np.stack([r.z for r in results])
        self._ingest_fleet(pending, zs)
        self._sync_accounting(pending)

    # -- observability -------------------------------------------------------
    def stats(self) -> GatewayStats:
        return GatewayStats(
            ticks=self._ticks, frames=self._frames,
            sessions_open=len(self._sessions), sessions_opened=self._opened,
            sessions_closed=self._closed,
            admission_refusals=self._refusals,
            dispatches=self._dispatches, wire_bytes=self._wire_bytes,
            sync_bytes=self._sync_bytes, sync_events=self._sync_events,
            refine_rounds=self._refine_rounds,
            last_refine_loss=self._last_refine_loss,
            routed=dict(self._routed),
            backend=self.backend.kind, shards=self.backend.shards,
            shard_frames=tuple(int(v) for v in self._shard_frames),
            snapshot_h2d_bytes=self.backend.snapshot_h2d_bytes,
            ingest_h2d_bytes=self.backend.ingest_h2d_bytes,
            device_syncs_per_tick=self._tick_syncs,
            d2h_copies_per_tick=self._tick_d2h,
            staged_h2d_bytes=self._staged_h2d,
            uptime_s=self._clock() - self._t_start,
            last_tick_ms=self._last_tick_ms)
