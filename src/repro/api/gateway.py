"""``StreamSplitGateway`` — THE way to run the StreamSplit pipeline.

One typed surface over what used to be six hand-wired call conventions:

    gw = StreamSplitGateway(enc_cfg, params, policy=make_policy("rule", L))
    info = gw.open_session(platform="pi4", qos=QoSClass.STANDARD)
    gw.submit(info.sid, FrameRequest(t=0, mel=mel, u=0.3, ...))
    results = gw.tick()          # decide -> k-bucketed dispatch -> ingest
    gw.close_session(info.sid)

Internally the gateway owns admission into a ``FleetBackend``, per-tick
**k-bucketed batched split execution**, periodic fleet refinement
rounds, and per-session ``LazySync`` accounting.  The fleet data plane
is pluggable (``backend=``): the default ``HostFleetBackend`` keeps the
session rings in host numpy, while ``ShardedFleetBackend`` keeps them
device-resident and sharded over a ``sessions`` mesh axis, refining the
whole fleet in one ``shard_map`` step (see ``core/fleet_backend.py`` and
``docs/SHARDING.md``).  The serving hot path: every frame whose policy
decision landed on the same split index k rides ONE padded
``SplitEngine`` dispatch (the serving analogue of
``CascadeServer.handle``'s two sub-batches) instead of one ``run()`` per
frame — embeddings stay bit-identical to the per-frame path
(``benchmarks/gateway_serve.py`` measures the speedup and asserts the
bit-parity; ``tests/test_gateway.py`` pins it).

The tick itself is an **overlapped, single-sync data plane**
(docs/PERF.md): the whole tick's frames are staged host→device as ONE
``(B, frames, n_mels)`` transfer, each k-bucket gathers its rows on
device (``jnp.take``) and issues its edge→wire→server chain
asynchronously, and the tick blocks exactly once on the concatenated
embeddings — one device sync and one device→host copy per tick, however
many buckets the policy produced.  ``overlap=False`` restores the PR-3
per-bucket-sync dispatch (the benchmark baseline), and
``tick(profile=True)`` trades the single sync for per-bucket timing.

The overlapped tick is split into two public phases —
``tick_launch() -> TickPlan`` (stage + async dispatch + overlapped host
bookkeeping, never blocks) and ``tick_collect(plan)`` (the one sync +
D2H + delivery) — with ``tick()`` simply composing them.  The streaming
runtime (``repro.serving.StreamServer``, docs/STREAMING.md) exploits the
seam for cross-tick pipelining: tick t+1 launches while tick t's chains
are still in flight, and ``device_syncs_per_tick`` stays 1.

On a multi-device ``ShardedFleetBackend`` the overlapped plane goes one
step further and **shards the dispatch itself** (``shard_dispatch``,
docs/SHARDING.md): each session's frames are staged into the block of a
single sharded H2D transfer owned by its fleet shard (placement was
decided at ``admit`` by the least-loaded free lists), every k-bucket's
edge→wire→server chain executes per device against a per-shard replica
of the encoder weights, per-shard embeddings reassemble into one global
sharded array with zero cross-device copies
(``jax.make_array_from_single_device_arrays``), and the fleet ring
scatter (``insert_batch_placed``) is a ``shard_map`` over the same axis
— so no frame's payload ever crosses a shard boundary and the
one-sync/one-D2H contract survives verbatim at every shard count.

All wall-clock reads go through the injectable ``clock=`` callable
(default ``time.perf_counter``), so latency/uptime numbers in
``FrameResult``/``GatewayStats`` are deterministic under a fake clock in
tests.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policies import SplitPolicy
from repro.api.types import (AdmissionError, FrameRequest, FrameResult,
                             GatewayStats, QoSClass, SessionInfo,
                             SessionSnapshot)
from repro.core.env import EdgeCloudEnv
from repro.core.fleet import FleetFullError, HostFleetBackend, pad_pow2
from repro.core.splitter import SplitEngine
from repro.core.sync import LazySync, SyncCfg
from repro.obs import MetricsRegistry, to_prometheus


class TickPlan:
    """One in-flight overlapped tick, between ``tick_launch`` and
    ``tick_collect``: the launched device chains plus the host context
    needed to deliver their results.  Opaque to callers — the streaming
    runtime (``serving/server.py``) holds at most one while it stages
    the NEXT tick under this one's chains (cross-tick pipelining)."""

    __slots__ = ("pending", "t0", "profile", "launched", "z_all", "t_d0",
                 "syncs", "d2h", "seq", "rowmap")

    def __init__(self, pending, t0, profile=False, seq=0):
        self.pending = pending     # [(sid, FrameRequest, mel f32)] served
        self.t0 = t0               # clock at tick_launch entry
        self.profile = profile
        self.launched = []         # (k, idx, wire bytes, bucket ms, shard)
        self.z_all = None          # unmaterialized (B, d) device embeddings
        self.t_d0 = t0             # clock at dispatch start
        self.syncs = 0             # launch-phase waits (profile mode only)
        self.d2h = 0
        self.seq = seq             # launch order — collect must match
        self.rowmap = None         # sharded plane: submission idx -> row

    def __len__(self):
        return len(self.pending)


class _Session:
    """Mutable per-session record (internal — the API hands out frozen
    ``SessionInfo`` snapshots only)."""

    __slots__ = ("sid", "platform", "qos", "sync", "frames", "wire_bytes",
                 "transitions", "last_k")

    def __init__(self, sid, platform, qos, sync_cfg):
        self.sid = sid
        self.platform = platform
        self.qos = qos
        self.sync = LazySync(sync_cfg)
        self.frames = 0
        self.wire_bytes = 0
        self.transitions = 0
        self.last_k = -1


class StreamSplitGateway:
    """Session/gateway layer over the whole edge–cloud pipeline.

    Parameters
    ----------
    enc_cfg, params : the audio encoder config + weights the split engine
        executes (``core/*`` semantics unchanged — the gateway is a
        dispatch layer, not a new model).
    policy : a batched ``SplitPolicy`` (see ``api/policies.py``).
    backend : a ``FleetBackend`` owning the session rings + refinement.
        Defaults to a ``HostFleetBackend`` built from ``capacity`` /
        ``window`` / ``head_init`` / ``head_apply`` / ``refine_lr`` /
        ``seed``; pass a ``ShardedFleetBackend`` to shard the fleet over
        a ``sessions`` mesh (those ctor args are then ignored — the
        backend already owns them).
    capacity, window : fleet dimensions; the server-side temporal rings
        are ``(capacity, window, enc_cfg.d_embed)``.
    head_init, head_apply : optional task head for fleet refinement;
        without them the gateway serves embeddings but never refines.
    refine_every : run one fleet-wide refinement round every this many
        ticks (0 disables).
    qos_reserve : fleet rows held back from BULK (2x) and STANDARD (1x)
        admissions so INTERACTIVE tenants always find room; defaults to
        ``capacity // 8``.
    overlap : serve ticks through the overlapped single-sync data plane
        (default).  ``False`` restores the PR-3 per-bucket-sync dispatch
        — one host staging + device round-trip per k-bucket — kept as
        the measured baseline of ``benchmarks/gateway_serve.py`` and the
        bit-parity reference of ``tests/test_gateway.py``.
    shard_dispatch : run the overlapped plane sharded over the backend's
        ``sessions`` mesh axis — per-device edge→wire→server chains
        co-located with each session's fleet shard, shard-local ring
        scatter, same one-sync/one-D2H contract.  Default ``None``
        auto-enables on a device-resident sharded backend with > 1
        shard; ``True`` forces it (valid on 1 shard too — the bitwise
        parity configuration); ``False`` keeps the single-device plane.
    clock : zero-arg callable returning seconds (default
        ``time.perf_counter``) — every timing stat derives from it.
    """

    def __init__(self, enc_cfg, params, *, policy: SplitPolicy,
                 backend=None, capacity=64, window=100, head_init=None,
                 head_apply=None, refine_every=0, quantize_wire=True,
                 sync_cfg=None, qos_reserve=None, refine_lr=1e-2, seed=0,
                 overlap=True, shard_dispatch=None, clock=time.perf_counter,
                 registry: MetricsRegistry | None = None):
        if policy.L != enc_cfg.n_blocks:
            raise ValueError(
                f"policy action space L={policy.L} != encoder "
                f"n_blocks={enc_cfg.n_blocks}")
        self.cfg = enc_cfg
        self.params = params
        self.policy = policy
        self.engine = SplitEngine(enc_cfg, quantize_wire=quantize_wire)
        if backend is None:
            backend = HostFleetBackend(
                capacity=capacity, window=window, dim=enc_cfg.d_embed,
                head_init=head_init, head_apply=head_apply, lr=refine_lr,
                seed=seed)
        elif backend.dim != enc_cfg.d_embed:
            raise ValueError(
                f"backend dim={backend.dim} != encoder "
                f"d_embed={enc_cfg.d_embed}")
        self.backend = backend
        self.sync_cfg = sync_cfg or SyncCfg()
        self.qos_reserve = (backend.capacity // 8 if qos_reserve is None
                            else qos_reserve)
        self.refine_every = refine_every
        self.overlap = overlap
        if shard_dispatch is None:
            shard_dispatch = bool(
                overlap and backend.device_ingest
                and getattr(backend, "mesh", None) is not None
                and backend.shards > 1)
        if shard_dispatch:
            if not overlap:
                raise ValueError("shard_dispatch shards the overlapped "
                                 "data plane; it needs overlap=True")
            if not (backend.device_ingest
                    and getattr(backend, "mesh", None) is not None):
                raise ValueError(
                    "shard_dispatch co-locates dispatch with fleet shards; "
                    "it needs a device-resident sharded backend "
                    "(ShardedFleetBackend)")
            from repro.distributed.sharding import sessions_sharding
            mesh = backend.mesh
            self._mesh_devices = list(mesh.devices.flat)
            self._staged_sharding = sessions_sharding(mesh, backend.axis)
            # one replica of the encoder weights per dispatch shard,
            # committed once at construction: a per-shard chain whose
            # params already live on its device never pulls a weight
            # byte cross-device at dispatch time
            self._params_by_shard = [jax.device_put(params, d)
                                     for d in self._mesh_devices]
            # an idle shard still owes its (block, d) slice of the global
            # reassembly; zeros blocks are immutable, so one upload per
            # (shard, size) is cached and reused for every idle tick
            self._zeros_blocks = {}
        self.shard_dispatch = shard_dispatch
        self._dispatch_shard_frames = np.zeros(
            backend.shards if shard_dispatch else 1, np.int64)
        self._last_profile = None
        self._clock = clock
        self._t_start = clock()
        self._key = jax.random.PRNGKey(seed)
        self._sessions: dict[int, _Session] = {}
        # (sid, request, validated float32 mel) — converted ONCE at submit
        self._pending: list[tuple[int, FrameRequest, np.ndarray]] = []
        # aggregate counters — live in the shared MetricsRegistry
        # (repro.obs; docs/OBSERVABILITY.md) so GatewayStats is a VIEW
        # over the same objects the hot path mutates and exporters walk
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        R = self.registry
        self._ticks = R.counter("gateway_ticks")
        self._frames = R.counter("gateway_frames")
        self._opened = R.counter("gateway_sessions_opened")
        self._closed = R.counter("gateway_sessions_closed")
        # sessions migrated out/in (repro.cluster)
        self._exported = R.counter("gateway_sessions_exported")
        self._imported = R.counter("gateway_sessions_imported")
        self._refusals = R.counter("gateway_admission_refusals")
        self._dispatches = R.counter("gateway_dispatches")
        self._wire_bytes = R.counter("gateway_wire_bytes")
        self._sync_bytes = R.counter("gateway_sync_bytes")
        self._sync_events = R.counter("gateway_sync_events")
        self._refine_rounds = R.counter("gateway_refine_rounds")
        self._last_refine_loss = float("nan")
        self._last_tick_ms = 0.0
        self._routed = {r: R.counter("gateway_routed_frames", route=r)
                        for r in ("edge", "split", "server")}
        self._shard_frames = np.zeros(backend.shards, np.int64)
        # always-on cheap stage timings: one EWMA multiply-add per tick
        # (alpha 0.2), so launch/collect/tick spans are a live registry
        # signal even with profiling off — tick(profile=True) and
        # last_profile are debug detail now, not the only timing source
        self._stage_ewma = {
            stage: R.gauge("gateway_stage_ewma_ms", stage=stage)
            for stage in ("launch", "collect", "tick")}
        self._g_last_tick_ms = R.gauge("gateway_last_tick_ms")
        self._g_syncs = R.gauge("gateway_device_syncs_per_tick")
        self._g_d2h = R.gauge("gateway_d2h_copies_per_tick")
        # overlapped data plane instrumentation: every blocking wait and
        # every embedding D2H copy inside tick() goes through _block/_d2h,
        # so the single-sync contract is countable (and pinned by test)
        self._staged_h2d = R.counter("gateway_staged_h2d_bytes")
        self._tick_syncs = 0
        self._tick_d2h = 0
        # launch/collect sequence numbers: plans MUST collect in launch
        # order (the fleet rings see launch-order scatters) — a
        # violation raises instead of silently corrupting parity
        self._launch_seq = 0
        self._collect_seq = 0

    # -- session lifecycle ---------------------------------------------------
    def _admit_row(self, qos: QoSClass) -> int:
        """QoS-headroom-checked fleet-row admission shared by
        ``open_session`` and ``import_session`` — a migrating session
        obeys the same reserve policy as a fresh one."""
        free = self.backend.capacity - self.backend.n_active
        need = {QoSClass.INTERACTIVE: 1,
                QoSClass.STANDARD: 1 + self.qos_reserve,
                QoSClass.BULK: 1 + 2 * self.qos_reserve}[qos]
        if free < need:
            self._refusals.inc()
            raise AdmissionError(qos, self.backend.n_active,
                                 self.backend.capacity)
        try:
            return self.backend.admit()
        except FleetFullError:
            self._refusals.inc()
            raise AdmissionError(qos, self.backend.n_active,
                                 self.backend.capacity) from None

    def open_session(self, platform="pi4",
                     qos: QoSClass = QoSClass.STANDARD) -> SessionInfo:
        """Admit a session into the fleet; raises ``AdmissionError`` (a
        ``FleetFullError``) when its QoS class finds no headroom."""
        sid = self._admit_row(qos)
        self._sessions[sid] = _Session(sid, platform, qos, self.sync_cfg)
        self._opened.inc()
        return self.session(sid)

    def session(self, sid) -> SessionInfo:
        s = self._require(sid)
        return SessionInfo(
            sid=s.sid, platform=s.platform, qos=s.qos, frames=s.frames,
            wire_bytes=s.wire_bytes, sync_bytes=s.sync.total_bytes,
            sync_events=len(s.sync.events), transitions=s.transitions,
            last_k=s.last_k, fill_fraction=self.backend.fill_fraction(sid))

    def close_session(self, sid) -> SessionInfo:
        """Evict the session (O(1) — the fleet row is wiped lazily on its
        next admission).  Unserved pending frames are discarded."""
        info = self.session(sid)
        self._pending = [p for p in self._pending if p[0] != sid]
        self.backend.evict(sid)
        del self._sessions[sid]
        self._closed.inc()
        return info

    def _require(self, sid) -> _Session:
        if sid not in self._sessions:
            raise KeyError(f"session {sid} is not open")
        return self._sessions[sid]

    # -- live migration seams (repro.cluster; docs/FEDERATION.md) ------------
    def export_session(self, sid, *, remove: bool = True) -> SessionSnapshot:
        """Freeze everything this session *is* into a ``SessionSnapshot``:
        per-session books, lazy-sync protocol state, and the fleet ring
        row (host representation — implants into any backend kind).

        ``remove=True`` (the migration move) also evicts the row —
        counted in ``sessions_exported``, NOT ``sessions_closed``: the
        stream continues elsewhere.  ``remove=False`` is the
        non-destructive copy the cluster's failure-recovery checkpoints
        use.  Pending (submitted-but-unticked) frames are NOT part of a
        gateway snapshot — tick or discard them first; exporting under
        them raises instead of silently dropping frames."""
        s = self._require(sid)
        if any(p[0] == sid for p in self._pending):
            raise RuntimeError(
                f"session {sid} has pending frames awaiting tick(): a "
                "snapshot taken now would silently drop them — tick "
                "first (the streaming runtime quiesces its pipeline "
                "before exporting)")
        ring_z, ring_t, ring_label, newest = self.backend.export_row(sid)
        snap = SessionSnapshot(
            platform=s.platform, qos=s.qos, frames=s.frames,
            wire_bytes=s.wire_bytes, transitions=s.transitions,
            last_k=s.last_k,
            sync_cfg=s.sync.cfg, sync_last_gmm=s.sync.last_gmm,
            sync_last_weights=s.sync.last_weights,
            sync_total_bytes=s.sync.total_bytes,
            sync_total_energy_j=s.sync.total_energy_j,
            sync_events=tuple(s.sync.events),
            ring_z=ring_z, ring_t=ring_t, ring_label=ring_label,
            ring_newest=newest)
        if remove:
            self.backend.evict(sid)
            del self._sessions[sid]
            self._exported.inc()
        return snap

    def import_session(self, snap: SessionSnapshot) -> SessionInfo:
        """Restore an exported session into THIS gateway: admit a fleet
        row under the same QoS headroom policy as ``open_session``
        (raises ``AdmissionError`` when the class finds no room),
        implant the ring row, and resume the per-session books and
        lazy-sync cadence exactly where the source left them.  The
        session gets a fresh local ``sid`` — cross-gateway identity is
        the cluster's job (``repro.cluster``), not the row index's."""
        sid = self._admit_row(snap.qos)
        s = _Session(sid, snap.platform, snap.qos, snap.sync_cfg)
        s.frames = snap.frames
        s.wire_bytes = snap.wire_bytes
        s.transitions = snap.transitions
        s.last_k = snap.last_k
        s.sync.last_gmm = snap.sync_last_gmm
        s.sync.last_weights = snap.sync_last_weights
        s.sync.total_bytes = snap.sync_total_bytes
        s.sync.total_energy_j = snap.sync_total_energy_j
        s.sync.events = list(snap.sync_events)
        self.backend.import_row(sid, snap.ring_z, snap.ring_t,
                                snap.ring_label, snap.ring_newest)
        self._sessions[sid] = s
        self._imported.inc()
        return self.session(sid)

    # -- ingest --------------------------------------------------------------
    def validate_mel(self, mel) -> np.ndarray:
        """Validate one frame's mel payload and return it as float32.
        THE validation — shared with the streaming runtime
        (``serving/server.py`` runs it on the client's thread) so the
        two surfaces can never drift.  A no-op copy-wise when the input
        is already a float32 ndarray."""
        mel = np.asarray(mel, np.float32)
        if mel.shape != (self.cfg.frames, self.cfg.n_mels):
            raise ValueError(
                f"frame.mel shape {mel.shape} != "
                f"({self.cfg.frames}, {self.cfg.n_mels}) — submit one "
                "unbatched sample per FrameRequest")
        return mel

    def submit(self, sid, frame: FrameRequest) -> None:
        """Queue one frame for the next ``tick``.

        The mel payload is validated AND converted to float32 here, once
        — ``tick`` stages the stored array directly, so no frame is ever
        converted twice (the seed path re-ran ``np.asarray`` per
        dispatch)."""
        self._require(sid)
        self._pending.append((sid, frame, self.validate_mel(frame.mel)))

    def submit_validated(self, sid, frame: FrameRequest) -> None:
        """``submit`` minus the re-validation: ``frame.mel`` MUST
        already be a float32 ndarray of shape (frames, n_mels) — i.e.
        have passed ``validate_mel``.  The streaming runtime validates
        at enqueue time on the client's thread and uses this on the
        serving hot path so no frame is checked twice."""
        self._require(sid)
        self._pending.append((sid, frame, frame.mel))

    # -- the pipeline tick ---------------------------------------------------
    def tick(self, *, profile=False) -> list[FrameResult]:
        """Decide -> k-bucketed batched dispatch -> ingest -> sync ->
        (periodic) refine.  Returns results in submission order.

        On the overlapped plane (``overlap=True``) the dispatch costs one
        staged H2D transfer, one device sync and one D2H embedding copy
        per tick — every bucket's chain runs asynchronously in between.
        ``tick()`` is exactly ``tick_collect(tick_launch())``: the
        streaming runtime (``serving/server.py``) calls the two phases
        separately so tick t+1 can stage and launch while tick t's
        chains are still in flight (cross-tick pipelining).
        ``profile=True`` syncs after each bucket instead, so
        ``FrameResult.latency_ms`` is per-bucket (diagnostics; the tick
        then pays one round-trip per bucket like ``overlap=False``)."""
        if self.overlap:
            return self.tick_collect(self.tick_launch(profile=profile))
        t0 = self._clock()
        pending, self._pending = self._pending, []
        results: list[FrameResult | None] = [None] * len(pending)
        self._tick_dev: list = []     # (bucket idx, device z) per dispatch
        self._tick_syncs = 0
        self._tick_d2h = 0
        if pending:
            for k, idx in sorted(self._decide(pending).items()):
                self._dispatch(k, idx, pending, results)
            self._ingest(pending, results, now=t0)
        self._finish_tick(t0)
        return results  # type: ignore[return-value]

    def tick_launch(self, *, profile=False) -> TickPlan:
        """Launch phase of the overlapped tick: decide, stage the tick's
        mels as ONE H2D transfer, issue every k-bucket's async
        edge→wire→server chain, and run all the host bookkeeping that
        needs no embedding values — WITHOUT ever blocking on the device.

        Returns the in-flight ``TickPlan``; pass it to ``tick_collect``
        to pay the tick's one sync and receive the ``FrameResult``s.
        Between the two calls the chains run on the device, so a caller
        may stage and launch the NEXT tick first — the cross-tick
        pipelining of ``serving.StreamServer``.  At most the launched
        plan's own frames are taken from the pending queue; ``submit``s
        that arrive after the launch ride the next plan."""
        if not self.overlap:
            raise RuntimeError(
                "tick_launch/tick_collect phase the overlapped data plane; "
                "construct the gateway with overlap=True")
        t0 = self._clock()
        pending, self._pending = self._pending, []
        self._tick_syncs = 0
        self._tick_d2h = 0
        plan = TickPlan(pending, t0, profile, seq=self._launch_seq)
        self._launch_seq += 1
        if pending:
            self._launch_overlapped(plan, self._decide(pending))
        plan.syncs, plan.d2h = self._tick_syncs, self._tick_d2h
        self._stage_ewma["launch"].ewma((self._clock() - t0) * 1e3)
        return plan

    def tick_collect(self, plan: TickPlan) -> list[FrameResult]:
        """Collect phase: the tick's ONE device sync + ONE D2H embedding
        copy, ``FrameResult`` delivery in submission order, host-backend
        ingest, tick counters and the periodic refine round.  Plans MUST
        be collected in launch order — the fleet rings already saw the
        launch-order scatters — and out-of-order (or double) collection
        raises instead of silently corrupting parity."""
        if plan.seq != self._collect_seq:
            raise RuntimeError(
                f"tick_collect out of launch order: plan #{plan.seq} "
                f"offered, #{self._collect_seq} expected (plans collect "
                "exactly once, oldest first)")
        self._collect_seq += 1
        # the per-tick sync scoreboard restarts from THIS plan's launch
        # counts: with another tick launched in between (pipelining), the
        # gateway counters were reset by that launch — a collected tick
        # still reports exactly its own waits/copies
        self._tick_syncs, self._tick_d2h = plan.syncs, plan.d2h
        t_c0 = self._clock()
        results: list[FrameResult | None] = [None] * len(plan.pending)
        if plan.pending:
            self._collect_overlapped(plan, results)
        self._stage_ewma["collect"].ewma((self._clock() - t_c0) * 1e3)
        self._finish_tick(plan.t0)
        return results  # type: ignore[return-value]

    def _decide(self, pending):
        """Policy decision for one tick's pending frames -> {k: [frame
        indices]} buckets.  Bandwidth is normalized exactly like the
        control-plane env so RL policies see the feature scale they were
        trained on."""
        bw_norm = EdgeCloudEnv.BW_NORM
        obs = np.array([[f.u, f.cpu, min(f.bandwidth_mbps / bw_norm, 1.0)]
                        for _, f, _ in pending], np.float32)
        ks = np.clip(np.asarray(self.policy.decide(obs), np.int64),
                     0, self.cfg.n_blocks)
        buckets: dict[int, list[int]] = {}
        for i, k in enumerate(ks):
            buckets.setdefault(int(k), []).append(i)
        return buckets

    def _finish_tick(self, t0):
        """Tick epilogue shared by every plane: counters, the periodic
        fleet refine round, the clock-derived tick latency, and the
        always-on EWMA tick-span gauge."""
        self._ticks.inc()
        if (self.backend.can_refine and self.refine_every
                and self._ticks.value % self.refine_every == 0
                and self.backend.n_active):
            key = jax.random.fold_in(self._key, self._refine_rounds.value)
            loss, _, _ = self.backend.refine(key)
            self._refine_rounds.inc()
            self._last_refine_loss = loss
        self._last_tick_ms = (self._clock() - t0) * 1e3
        self._g_last_tick_ms.set(self._last_tick_ms)
        self._stage_ewma["tick"].ewma(self._last_tick_ms)
        self._g_syncs.set(self._tick_syncs)
        self._g_d2h.set(self._tick_d2h)

    def refine_due_next_tick(self) -> bool:
        """True when the NEXT collected tick will run a fleet refine
        round — the streaming runtime drains its pipeline first so the
        refine sees exactly the frames a sequential gateway would have
        ingested by that tick (``serving/server.py``).  Mirrors
        ``_finish_tick``'s condition exactly, including ``n_active`` —
        an idle fleet never forces a pipeline drain."""
        return bool(self.backend.can_refine and self.refine_every
                    and (self._ticks.value + 1) % self.refine_every == 0
                    and self.backend.n_active)

    # instrumented sync points: every blocking wait and embedding D2H
    # copy in the DISPATCH plane routes through these two, so the
    # single-sync contract is a counted fact
    # (GatewayStats.device_syncs_per_tick / d2h_copies_per_tick), not an
    # assumption.  A periodic backend.refine() blocks on its own loss
    # read and is deliberately outside this scoreboard.
    def _block(self, x):
        self._tick_syncs += 1
        return jax.block_until_ready(x)

    def _d2h(self, x):
        self._tick_d2h += 1
        return np.asarray(x)

    def _launch_overlapped(self, plan, buckets):
        """Launch half of the overlapped tick data plane: ONE staged H2D
        for the whole tick, device-side bucket gathers, async
        edge→wire→server chains, plus everything the host can do without
        the embedding *values* — session/wire counters, lazy-sync
        accounting, and (on a device-resident backend) the fleet ring
        scatter — all issued WITHOUT a sync, so the work hides under the
        in-flight device chains (and, pipelined, under the PREVIOUS
        tick's chains too)."""
        if self.shard_dispatch:
            return self._launch_sharded(plan, buckets)
        pending, profile = plan.pending, plan.profile
        plan.t_d0 = self._clock()
        # (1) stage the whole tick's frames as ONE host->device transfer,
        # repeat-padded to a pow2 row count: a streaming scheduler ticks
        # at arbitrary batch sizes, and every device-side bucket gather
        # below is compiled against the staged shape — pow2 padding keeps
        # that cache at O(log capacity) executables instead of one per
        # distinct tick size (pad rows are never gathered: bitwise no-op)
        mel_host = np.stack([m for _, _, m in pending])
        pad_rows = pad_pow2(len(pending)) - len(pending)
        if pad_rows:
            mel_host = np.concatenate(
                [mel_host, np.broadcast_to(mel_host[:1], (pad_rows,)
                                           + mel_host.shape[1:])])
        staged = jax.device_put(mel_host)
        self._staged_h2d.inc(mel_host.nbytes)
        # (2) per-bucket device-side gathers + async dispatch chains
        z_bufs = []
        # frame i -> row in the padded concat; itself pow2-padded (pad
        # entries re-read row 0 and are dropped on the host) so the
        # reassembly gather is also compiled per pow2 size, not per
        # arbitrary streaming tick size
        pos = np.zeros(pad_pow2(len(pending)), np.int32)
        offset = 0
        for k, idx in sorted(buckets.items()):
            t_b = self._clock() if profile else None
            padded = pad_pow2(len(idx))
            gather = np.asarray(idx + idx[:1] * (padded - len(idx)),
                                np.int32)
            mel_b = jnp.take(staged, gather, axis=0)
            z_dev, wire = self.engine.run_batch_async(self.params, mel_b, k)
            ms = None
            if profile:   # diagnostic mode: per-bucket round-trips
                self._block(z_dev)
                ms = (self._clock() - t_b) * 1e3 / len(idx)
            z_bufs.append(z_dev)
            plan.launched.append((k, idx, wire, ms, 0))
            pos[idx] = offset + np.arange(len(idx), dtype=np.int32)
            offset += padded
        # (3) reassemble into submission order ON DEVICE — one gather
        # straight out of the padded concat (drops pad rows + un-buckets
        # in the same op)
        plan.z_all = jnp.take(jnp.concatenate(z_bufs), pos, axis=0)
        # (4) host bookkeeping + device-resident fleet scatter, all while
        # the chains are still in flight.  The scatter slices z_all to
        # the real row count — one trivial slice executable per distinct
        # tick size, which is the cheapest option: handing the padded
        # array over instead would duplicate (sid, slot) keys and push
        # insert_batch down its duplicate-fold path, whose own gather is
        # per-size too AND pays a host-side fold per tick
        for k, idx, wire, _, s in plan.launched:
            self._account_bucket(k, idx, pending, wire, shard=s)
        if self.backend.device_ingest:
            self._ingest_fleet(pending,            # async device scatter
                               plan.z_all[:len(pending)])
        self._sync_accounting(pending, now=plan.t_d0)

    def _launch_sharded(self, plan, buckets):
        """The sharded launch half (``shard_dispatch``): same contract as
        the single-device plane — one staged H2D, async chains, zero
        launch-phase syncs — but laid out over the backend's ``sessions``
        mesh axis so every frame is dispatched ON the device that owns
        its session's fleet shard:

        (1) the tick's frames are grouped by fleet shard into EQUAL
            pow2-padded blocks of one host array and staged with a single
            sharded ``device_put`` — still ONE H2D, each block landing
            shard-local (``plan.rowmap`` remembers submission idx → row);
        (2) each shard's k-buckets gather from their zero-copy local view
            (``addressable_shards``) and run against that shard's
            committed weight replica, so every edge→wire→server chain —
            fused Pallas wire kernel included — executes per device;
        (3) per-shard reassembly gathers restore block order on each
            device and ``make_array_from_single_device_arrays`` binds the
            blocks into one global sharded ``(S·block, d)`` array — no
            cross-device copy, and ``tick_collect`` still pays exactly
            one sync + one D2H on it;
        (4) the fleet scatter goes through ``insert_batch_placed`` — a
            ``shard_map`` over the same axis, so ring ingest never
            crosses a shard either."""
        pending, profile = plan.pending, plan.profile
        plan.t_d0 = self._clock()
        S = self.backend.shards
        sids = np.fromiter((sid for sid, _, _ in pending), np.int64,
                           len(pending))
        shard = self.backend.shards_of(sids)
        by_shard = [np.flatnonzero(shard == s) for s in range(S)]
        block = pad_pow2(max(1, max(len(b) for b in by_shard)))
        mels = np.stack([m for _, _, m in pending])
        mel_host = np.empty((S * block,) + mels.shape[1:], np.float32)
        rowmap = np.empty(len(pending), np.int64)
        for s, idx_s in enumerate(by_shard):
            base = s * block
            mel_host[base:base + len(idx_s)] = mels[idx_s]
            # pad rows: any real frame's mel — never gathered by a chain,
            # dropped by the placed scatter, so the content is free
            mel_host[base + len(idx_s):base + block] = mels[0]
            rowmap[idx_s] = base + np.arange(len(idx_s))
        staged = jax.device_put(mel_host, self._staged_sharding)
        self._staged_h2d.inc(mel_host.nbytes)
        by_dev = {sh.device: sh.data for sh in staged.addressable_shards}
        z_blocks = []
        for s in range(S):
            local = by_dev[self._mesh_devices[s]]
            idx_s = by_shard[s]
            if not len(idx_s):
                z = self._zeros_blocks.get((s, block))
                if z is None:
                    z = jax.device_put(
                        np.zeros((block, self.cfg.d_embed), np.float32),
                        self._mesh_devices[s])
                    self._zeros_blocks[(s, block)] = z
                z_blocks.append(z)
                continue
            z_bufs = []
            pos = np.zeros(block, np.int32)
            offset = 0
            for k in sorted(buckets):
                in_shard = [i for i in buckets[k] if shard[i] == s]
                if not in_shard:
                    continue
                t_b = self._clock() if profile else None
                loc = (rowmap[in_shard] - s * block).astype(np.int32)
                padded = pad_pow2(len(loc))
                gather = np.concatenate(
                    [loc, np.broadcast_to(loc[:1], (padded - len(loc),))])
                mel_b = jnp.take(local, gather, axis=0)
                z_dev, wire = self.engine.run_batch_async(
                    self._params_by_shard[s], mel_b, k)
                ms = None
                if profile:   # diagnostic mode: per-chain round-trips
                    self._block(z_dev)
                    ms = (self._clock() - t_b) * 1e3 / len(in_shard)
                z_bufs.append(z_dev)
                plan.launched.append((k, in_shard, wire, ms, s))
                pos[loc] = offset + np.arange(len(loc), dtype=np.int32)
                offset += padded
            z_blocks.append(jnp.take(jnp.concatenate(z_bufs), pos, axis=0))
        plan.z_all = jax.make_array_from_single_device_arrays(
            (S * block, self.cfg.d_embed), self._staged_sharding, z_blocks)
        plan.rowmap = rowmap
        for k, idx, wire, _, s in plan.launched:
            self._account_bucket(k, idx, pending, wire, shard=s)
        self.backend.insert_batch_placed(
            sids,
            np.fromiter((f.t for _, f, _ in pending), np.int64, len(sids)),
            plan.z_all,
            np.fromiter((f.label for _, f, _ in pending), np.int64,
                        len(sids)),
            rowmap)
        self._shard_frames += np.bincount(shard, minlength=S)
        self._sync_accounting(pending, now=plan.t_d0)

    def _collect_overlapped(self, plan, results):
        """Collect half: THE tick's one device sync + one D2H copy, then
        ``FrameResult`` delivery (which needs the host values) and a host
        backend's ring insert.  In profile mode the bucket chains are
        already done, but the reassembly gather still needs its own
        (counted) wait — np.asarray would otherwise block uncounted
        inside ``_d2h``."""
        pending = plan.pending
        z_host = self._d2h(self._block(plan.z_all))
        tick_ms = (self._clock() - plan.t_d0) * 1e3 / len(pending)
        if plan.rowmap is not None:
            # sharded plane: un-block the per-shard layout back into
            # submission order (host-side permutation of the ONE copy)
            z_host = z_host[plan.rowmap]
        if not self.backend.device_ingest:
            self._ingest_fleet(pending, z_host[:len(pending)])
        for k, idx, wire, ms, _s in plan.launched:
            route = self._route(k)
            for i in idx:
                sid, req, _ = pending[i]
                results[i] = FrameResult(
                    sid=sid, t=req.t, z=z_host[i], route=route, k=k,
                    wire_bytes=wire,
                    latency_ms=ms if plan.profile else tick_ms,
                    bucket_size=len(idx), shard=_s)
        if plan.profile:
            self._last_profile = self._build_profile(plan)
            for k, ms in self._last_profile["per_bucket_ms"].items():
                self.registry.gauge("gateway_profile_bucket_ms",
                                    k=str(k)).set(ms)

    def _build_profile(self, plan):
        """Fold a profiled plan's per-chain timings into the
        ``last_profile`` dict: per-bucket ms (summed across shards, so
        the field means what it always did) plus per-shard totals —
        frames, chains, total ms and that shard's own per-bucket split —
        so cross-shard skew is visible without a profiler."""
        per_bucket: dict[int, float] = {}
        per_shard: dict[int, dict] = {}
        for k, idx, _wire, ms, s in plan.launched:
            total = (ms or 0.0) * len(idx)
            per_bucket[k] = per_bucket.get(k, 0.0) + total
            ps = per_shard.setdefault(
                s, {"frames": 0, "chains": 0, "ms": 0.0,
                    "per_bucket_ms": {}})
            ps["frames"] += len(idx)
            ps["chains"] += 1
            ps["ms"] += total
            ps["per_bucket_ms"][k] = ps["per_bucket_ms"].get(k, 0.0) + total
        return {"per_bucket_ms": per_bucket, "per_shard": per_shard}

    @property
    def last_profile(self):
        """Per-bucket AND per-shard stage timings of the most recent
        ``tick(profile=True)`` on the overlapped plane (``None`` until
        one runs).  Shape: ``{"per_bucket_ms": {k: ms}, "per_shard":
        {shard: {"frames", "chains", "ms", "per_bucket_ms"}}}`` — the
        single-device plane reports everything under shard 0."""
        return self._last_profile

    def _route(self, k):
        return ("edge" if k >= self.cfg.n_blocks
                else "server" if k == 0 else "split")

    def _account_bucket(self, k, idx, pending, wire, shard=0):
        """Per-bucket serving counters + per-session accounting (pure
        host state — needs no embedding values, so the overlapped plane
        runs it under the in-flight dispatches; the PR-3 path shares it
        so the two planes can never drift apart in what they report).
        On the sharded plane each (shard, k) chain is one dispatch;
        ``shard`` feeds the per-shard dispatch counters."""
        route = self._route(k)
        self._dispatches.inc()
        self._frames.inc(len(idx))
        self._wire_bytes.inc(wire * len(idx))
        self._routed[route].inc(len(idx))
        self._dispatch_shard_frames[shard] += len(idx)
        for i in idx:
            sid = pending[i][0]
            s = self._sessions[sid]
            if s.last_k >= 0 and k != s.last_k:
                s.transitions += 1
            s.last_k = k
            s.frames += 1
            s.wire_bytes += wire

    def _dispatch(self, k, idx, pending, results):
        """The PR-3 per-bucket-sync dispatch (``overlap=False``): host
        staging, one ``run_batch``, one blocking round-trip — per bucket.
        Kept behaviorally identical to PR 3 as the measured baseline +
        bit-parity reference (it shares ``_account_bucket`` with the
        overlapped plane so the two can never drift in what they
        report)."""
        t0 = self._clock()
        mel = np.stack([pending[i][2] for i in idx])
        pad = pad_pow2(len(idx))
        if pad > len(idx):   # repeat-pad: shape buckets stay compiled
            mel = np.concatenate(
                [mel, np.broadcast_to(mel[:1], (pad - len(idx),)
                                      + mel.shape[1:])])
        z_dev, wire = self.engine.run_batch(self.params, mel, k)
        if self.backend.device_ingest:   # fleet ingest skips the host hop
            self._tick_dev.append((idx, z_dev[:len(idx)]))
        z = self._d2h(self._block(z_dev))[:len(idx)]
        ms = (self._clock() - t0) * 1e3 / len(idx)
        self._account_bucket(k, idx, pending, wire)
        route = self._route(k)
        for j, i in enumerate(idx):
            sid, req, _ = pending[i]
            results[i] = FrameResult(
                sid=sid, t=req.t, z=z[j], route=route, k=k,
                wire_bytes=wire, latency_ms=ms, bucket_size=len(idx))

    def _ingest_fleet(self, pending, zs):
        """Fleet-backend ingest of the tick's submission-ordered
        embeddings.  On a device-resident backend ``zs`` is the
        ``jax.Array`` the dispatches produced — the payload flows
        dispatch → rings without ever touching the host (the host copy
        in ``results`` exists only for the clients); on a host backend
        it is the host copy the tick already made."""
        sids = np.array([sid for sid, _, _ in pending], np.int64)
        ts = np.array([f.t for _, f, _ in pending], np.int64)
        labels = np.array([f.label for _, f, _ in pending], np.int64)
        self.backend.insert_batch(sids, ts, zs, labels)
        self._shard_frames += np.bincount(
            self.backend.shards_of(sids), minlength=self.backend.shards)

    def _sync_accounting(self, pending, now=0.0):
        """Per-session lazy-sync protocol accounting (host state only —
        the overlapped plane runs it under the in-flight dispatches).
        ``now`` is the tick's dispatch timestamp from the injected
        ``clock=``, stamped onto every emitted ``SyncEvent.at_s`` so sync
        timelines stay deterministic under a fake clock."""
        for sid, req, _ in pending:
            s = self._sessions[sid]
            for ev in s.sync.on_frame(req.t, charging=req.charging,
                                      bandwidth_mbps=req.bandwidth_mbps,
                                      now=now):
                self._sync_bytes.inc(ev.bytes)
                self._sync_events.inc()

    def _ingest(self, pending, results, now=0.0):
        """The PR-3 composite ingest (``overlap=False`` only): reassemble
        the per-dispatch device slices into submission order, insert,
        then run lazy-sync accounting."""
        if self.backend.device_ingest:
            order = np.concatenate(
                [np.asarray(idx) for idx, _ in self._tick_dev])
            zs = jnp.concatenate([z for _, z in self._tick_dev])[
                np.argsort(order)]
        else:
            zs = np.stack([r.z for r in results])
        self._ingest_fleet(pending, zs)
        self._sync_accounting(pending, now=now)

    # -- observability -------------------------------------------------------
    @property
    def clock(self):
        """The injected timing source (``clock=``) — the serving runtime
        defaults to it so one fake clock drives the whole stack."""
        return self._clock

    @property
    def ticks(self) -> int:
        """Collected-tick count (a launched-but-uncollected ``TickPlan``
        is not a tick yet)."""
        return self._ticks.value

    def stats(self) -> GatewayStats:
        """The gateway scoreboard as a frozen view over the registry —
        every counter field reads the same live metric the hot path
        mutates, so the numbers exporters scrape and the numbers this
        dataclass reports can never drift."""
        # per-shard frame gauges are synced lazily here (stats/export
        # time), not per tick: the numpy arrays ARE the hot-path
        # accumulators and a per-tick loop over shards would tax the
        # S=1 common case for nothing
        for s, v in enumerate(self._shard_frames):
            self.registry.gauge("gateway_shard_frames",
                                shard=str(s)).set(int(v))
        for s, v in enumerate(self._dispatch_shard_frames):
            self.registry.gauge("gateway_dispatch_shard_frames",
                                shard=str(s)).set(int(v))
        return GatewayStats(
            ticks=self._ticks.value, frames=self._frames.value,
            sessions_open=len(self._sessions),
            sessions_opened=self._opened.value,
            sessions_closed=self._closed.value,
            admission_refusals=self._refusals.value,
            dispatches=self._dispatches.value,
            wire_bytes=self._wire_bytes.value,
            sync_bytes=self._sync_bytes.value,
            sync_events=self._sync_events.value,
            refine_rounds=self._refine_rounds.value,
            last_refine_loss=self._last_refine_loss,
            routed={r: c.value for r, c in self._routed.items()},
            backend=self.backend.kind, shards=self.backend.shards,
            shard_frames=tuple(int(v) for v in self._shard_frames),
            dispatch_shards=(self.backend.shards if self.shard_dispatch
                             else 1),
            dispatch_shard_frames=tuple(
                int(v) for v in self._dispatch_shard_frames),
            snapshot_h2d_bytes=self.backend.snapshot_h2d_bytes,
            ingest_h2d_bytes=self.backend.ingest_h2d_bytes,
            device_syncs_per_tick=self._tick_syncs,
            d2h_copies_per_tick=self._tick_d2h,
            staged_h2d_bytes=self._staged_h2d.value,
            uptime_s=self._clock() - self._t_start,
            last_tick_ms=self._last_tick_ms,
            sessions_exported=self._exported.value,
            sessions_imported=self._imported.value)

    def metrics(self) -> str:
        """The gateway's registry in Prometheus text exposition format
        (``repro.obs.export``; docs/OBSERVABILITY.md).  When the
        gateway runs under a ``StreamServer`` the registry is shared, so
        the server's ``metrics()`` supersedes this one."""
        self.stats()                 # sync the lazy per-shard gauges
        return to_prometheus(self.registry)
