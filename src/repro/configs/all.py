"""Import every arch config so registration side-effects run."""
from repro.configs import (arctic_480b, gemma2_2b, kimi_k2_1t, llava_next_34b,
                           mamba2_780m, musicgen_large, nemotron_4_15b,
                           qwen1p5_0p5b, qwen3_1p7b, streamsplit_audio,
                           zamba2_1p2b)  # noqa: F401
