"""llava-next-34b — VLM backbone (anyres tiling frontend is a stub; inputs
are precomputed patch embeddings). [hf:llava-hf/llava-v1.6; unverified]"""
from repro.configs.base import ModelCfg, register

CFG = register(ModelCfg(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
