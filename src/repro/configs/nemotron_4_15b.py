"""nemotron-4-15b — dense, GQA kv=8, squared-ReLU, LayerNorm.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelCfg, register

CFG = register(ModelCfg(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    norm="layernorm",
    act="relu2",
    gated_mlp=False,
    rope_theta=1e4,
    source="arXiv:2402.16819",
))
