"""mamba2-780m — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelCfg, SSMCfg, register

CFG = register(ModelCfg(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    # assigned vocab 50280, padded to a multiple of 128 so the vocab dim
    # shards over the 16-way 'model' axis (standard practice; the original
    # Mamba releases pad to a multiple of 16 for the same reason).
    vocab=50304,
    ssm=SSMCfg(
        n_heads=48,        # d_inner = 2*d_model = 3072, head_dim 64
        head_dim=64,
        d_state=128,
        chunk=128,
    ),
    source="arXiv:2405.21060",
))
