"""zamba2-1.2b — hybrid: Mamba2 backbone with a shared attention+MLP block
applied every 6 layers. [arXiv:2411.15242; hf]

Adaptation note: real Zamba2 adds per-use LoRA deltas on the shared block;
we share the block verbatim (noted in DESIGN.md).
"""
from repro.configs.base import ModelCfg, SSMCfg, register

CFG = register(ModelCfg(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,           # mamba layers; shared attn block every 6
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,             # shared block MLP
    vocab=32000,
    ssm=SSMCfg(
        n_heads=64,        # d_inner = 2*d_model = 4096, head_dim 64
        head_dim=64,
        d_state=64,
        chunk=128,
    ),
    hybrid_period=6,
    source="arXiv:2411.15242",
))
