"""arctic-480b — 128-expert top-2 MoE with a parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelCfg, MoECfg, register

CFG = register(ModelCfg(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,             # dense residual branch
    vocab=32000,
    moe=MoECfg(
        n_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,
        aux_coef=0.01,
    ),
    source="hf:Snowflake/snowflake-arctic-base",
))
