"""kimi-k2-1t-a32b — trillion-param MoE: 384 experts top-8, 1 shared expert,
first layer dense. [arXiv:2501.kimi2; unverified]

Note: assigned spec prescribes GQA kv=8 with 64 heads at d_model 7168
(head_dim 112); we follow the spec (real K2 uses MLA — out of scope here).
"""
from repro.configs.base import ModelCfg, MoECfg, register

CFG = register(ModelCfg(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,            # the leading dense layer's FFN
    vocab=163840,
    moe=MoECfg(
        n_experts=384,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        first_k_dense=1,
        aux_coef=0.001,
    ),
    rope_theta=5e4,
    source="arXiv:2501.kimi2",
))
