"""gemma2-2b — local+global alternating attention, logit softcaps, GeGLU.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelCfg, register

CFG = register(ModelCfg(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    act="gelu",
    gated_mlp=True,
    attn_pattern=("sliding", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_scale=1.0 / 256.0 ** 0.5,  # query_pre_attn_scalar = head_dim
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2408.00118",
))
