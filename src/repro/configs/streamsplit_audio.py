"""The paper's own model: ResNet-18-1D audio encoder, L=8 split blocks,
d=128 embeddings, GMM C=64 (§5 Reproducibility Details)."""
from dataclasses import dataclass

from repro.configs import base as _base
from repro.models.audio_encoder import AudioEncCfg

CFG = AudioEncCfg()


@dataclass(frozen=True)
class _AudioMarker:
    """Registry marker; LM cells() skips family == 'audio_enc'."""
    name: str = CFG.name
    family: str = CFG.family
    hybrid_period: int = 0


_base._REGISTRY[CFG.name] = _AudioMarker()
