"""qwen3-1.7b — dense, GQA kv=8, qk-norm. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelCfg, register

CFG = register(ModelCfg(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    act="silu",
    gated_mlp=True,
    source="hf:Qwen/Qwen3-8B",
))
