"""musicgen-large — decoder-only over EnCodec tokens (frontend stub provides
conditioning embeddings). [arXiv:2306.05284; hf]

Adaptation note: MusicGen uses learned absolute positions; we use RoPE for
stack uniformity (recorded in DESIGN.md).
"""
from repro.configs.base import ModelCfg, register

CFG = register(ModelCfg(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    act="gelu",
    gated_mlp=False,
    norm="layernorm",
    source="arXiv:2306.05284",
))
