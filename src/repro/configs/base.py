"""Config dataclasses + arch/shape registry.

Every assigned architecture is a ``ModelCfg``; the four assigned input
shapes are ``ShapeCfg``s.  ``input_specs(model_cfg, shape_cfg, step)``
returns ShapeDtypeStruct stand-ins for every input of the lowered step
(no device allocation — dry-run only).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    gated: bool = True
    act: str = "silu"
    n_shared_experts: int = 0      # always-on shared expert(s) (DeepSeek/kimi)
    dense_residual: bool = False   # parallel dense FFN residual (arctic)
    first_k_dense: int = 0         # leading dense layers (kimi)
    aux_coef: float = 0.01
    cap_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    n_heads: int
    head_dim: int
    d_state: int
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                    # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    norm: str = "rmsnorm"
    act: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    attn_scale: Optional[float] = None
    rope_theta: float = 1e4
    window: Optional[int] = None           # sliding-window size
    attn_pattern: tuple = ("global",)      # cycled over layers
    attn_chunk: int = 2048                 # online-softmax KV chunk
    loss_chunk: int = 2048                 # CE computed in seq chunks
    tie_embeddings: bool = False
    embed_scale: bool = False              # gemma: x *= sqrt(d_model)
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid_period: int = 0                 # shared attn block every k mamba
    remat: bool = True
    dtype: str = "float32"
    param_dtype: str = "float32"
    # provenance
    source: str = ""

    @property
    def xdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def layer_windows(self):
        """Per-layer attention window sizes as an int32 array.

        'global' layers get a huge sentinel window (== unwindowed)."""
        GLOBAL = 1 << 30
        out = []
        for i in range(self.n_layers):
            kind = self.attn_pattern[i % len(self.attn_pattern)]
            out.append(self.window if kind == "sliding" else GLOBAL)
        return jnp.asarray(out, jnp.int32)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# archs for which long_500k runs (sub-quadratic / O(1)-state decode).
LONG_CONTEXT_OK = {"mamba2-780m", "zamba2-1.2b"}

_REGISTRY: dict = {}


def register(cfg: ModelCfg):
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelCfg:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs  # noqa
        import importlib
        importlib.import_module("repro.configs.all")
    return _REGISTRY[name]


def list_configs():
    import importlib
    importlib.import_module("repro.configs.all")
    return sorted(_REGISTRY)


def cells(include_long=True):
    """All (arch, shape) dry-run cells per the assignment."""
    out = []
    for name in list_configs():
        cfg = _REGISTRY[name]
        if cfg.family in ("audio_enc",):
            continue
        for sname, s in SHAPES.items():
            if sname == "long_500k" and name not in LONG_CONTEXT_OK:
                continue
            out.append((name, sname))
    return out


def smoke_config(cfg: ModelCfg) -> ModelCfg:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.hybrid_period == 0 else cfg.hybrid_period + 1),
        d_model=64, d_ff=128, vocab=256,
        attn_chunk=32, loss_chunk=64,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, min(4, cfg.n_kv_heads)), head_dim=16)
        if cfg.n_kv_heads == cfg.n_heads:
            kw["n_kv_heads"] = 4
    if cfg.window:
        kw["window"] = 16
    if cfg.moe:
        kw["moe"] = replace(cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k),
                            d_ff_expert=32)
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, n_heads=4, head_dim=8, d_state=8, chunk=16)
    if cfg.hybrid_period:
        kw["hybrid_period"] = 2
        kw["n_layers"] = 5
    return replace(cfg, **kw)


def input_specs(cfg: ModelCfg, shape: ShapeCfg, *, dtype=None):
    """ShapeDtypeStructs for the lowered step's data inputs."""
    dt = jnp.dtype(dtype or cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.family == "vlm":
            return {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), i32)}
    raise ValueError(shape.kind)
