"""``GatewayCluster`` — self-healing multi-gateway federation.

One gateway serves one accelerator's fleet; a deployment has several.
This module federates N member servers behind a single session API:

- **Routing**: every cluster session gets a stable global sid (gsid)
  and is placed on the member that owns it on a seeded consistent-hash
  ring (``cluster/hashing.py``).  Placement walks the ring's preference
  order past full members, so admission only fails when the whole
  cluster is out of headroom.
- **Live migration**: ``drain(member)`` (rolling restarts) and
  ``add_member`` / member failure (rebalance) move sessions between
  gateways via the ``SessionSnapshot`` seam — ring row, sync books,
  scheduler books, token-bucket level, and every waiting frame with its
  ORIGINAL deadline travel together, so a migrated stream is
  indistinguishable from one that never moved (the bit-parity oracle in
  ``tests/test_cluster.py`` pins this).
- **Fault tolerance, bounded loss** (``cluster/replication.py``): with
  ``replicate=True`` every accepted frame is write-ahead-journaled on a
  deterministic buddy member (the next live ring node past the owner)
  through the member's ``on_admit`` journal-ack hook, and recovery from
  a member death is *import the last checkpoint + replay the journal's
  open entries* through the ordinary ``import_session`` seam — so
  ``lost_in_flight`` shrinks from "everything since ``snapshot_every``"
  to "frames admitted but not yet journal-acked" (at most one
  ``journal_flush_every`` window).  Whatever is still unrecoverable is
  counted — never silently dropped — in ``ClusterStats.lost_in_flight``,
  the term that keeps the cluster-wide conservation identity

      submitted == served + queue_depth + in_flight
                   + shed_expired + lost_in_flight

  true at every ``stats()`` snapshot, including across repeated
  kill → recover → kill cycles.
- **Failure detection** (``cluster/health.py``): a member that RAISES
  dies at the exception seam, as before; a member that HANGS (makes no
  progress without raising) is caught by heartbeat suspicion on the
  injected timer and routed through the same recovery path.  Transient
  faults (``runtime/fault.TransientFault``) from member submit / step /
  checkpoint calls are retried with deterministic exponential backoff
  (``RetryPolicy`` — no wall-clock sleeps) instead of executing the
  member; only exhausted retries or fatal exceptions fail it over.
- **Graceful degradation**: when live membership falls below
  ``degraded_below`` × the peak membership, the cluster turns visibly
  degraded — new sessions and BULK frames are refused with the typed
  ``ClusterDegradedError`` (counted in ``rejected_degraded``), keeping
  the survivors' headroom for the streams they already hold.  The mode
  clears itself as soon as capacity returns via ``add_member``.
  ``StragglerMonitor`` feeds a slow-member signal that shrinks the
  member's hash-space share (placement bias; nothing is evicted).

**The cluster owns its members.**  Member servers must be constructed
WITHOUT their own serving thread running; the cluster drives them
through the public ``step()`` seam — one ``cluster.step()`` steps every
live member once, deterministically, which is also why every chaos test
runs on a fake clock.  All client traffic (open/submit/close) must flow
through the cluster: a frame submitted directly to a member is invisible
to the federation books and breaks the conservation identity.

The cluster keeps its OWN books at the federation boundary (counted at
``submit`` / ``on_result`` / ``on_shed``) instead of summing member
counters: a dead member's counters vanish with it, and a migrated
session's would double-count — cluster-level accounting is the only
representation that survives both.
"""
from __future__ import annotations

import threading
import time
from dataclasses import replace

import numpy as np

from repro.api.types import (AdmissionError, ClusterDegradedError,
                             ClusterDrainTimeout, ClusterStats, QoSClass,
                             ServerSessionSnapshot)
from repro.cluster.hashing import HashRing
from repro.cluster.health import HeartbeatMonitor, MemberHungError
from repro.cluster.replication import ReplicationLog
from repro.obs import FlightRecorder, MetricsRegistry, to_prometheus
from repro.runtime.fault import RetryPolicy, TransientFault
from repro.serving.queues import QueueFullError, RateLimitError
from repro.serving.server import _UNSET

__all__ = ["GatewayCluster"]

_DUMP_KEEP = 8          # newest automatic failover dumps retained


class _ClusterSession:
    """Federation-side session record: where the session lives now,
    plus the cluster's own conservation books for it (these survive
    migration and member death — member counters do not)."""

    __slots__ = ("gsid", "member", "lsid", "qos", "platform",
                 "submitted", "served", "shed", "lost")

    def __init__(self, gsid, member, lsid, qos, platform):
        self.gsid = gsid
        self.member = member       # current owner's name
        self.lsid = lsid           # sid on that member (fresh per move)
        self.qos = qos
        self.platform = platform
        self.submitted = 0
        self.served = 0
        self.shed = 0
        self.lost = 0              # counted at member death, cumulative

    @property
    def outstanding(self) -> int:
        """Frames accepted but not yet served, shed, or counted lost."""
        return self.submitted - self.served - self.shed - self.lost


class GatewayCluster:
    """Federates N ``StreamServer`` members behind one session API.

    Parameters
    ----------
    members : ``{name: StreamServer}``.  Servers must not have their
        serving thread running — the cluster steps them.
    seed / vnodes : consistent-hash ring determinism knobs
        (``cluster/hashing.py``).
    snapshot_every : take a failure-recovery checkpoint of every
        session each N cluster steps (0 disables; then a member failure
        loses its sessions entirely — still counted, never silent —
        unless ``replicate`` is on, which checkpoints at admission and
        after every move so a buddy journal always has a base to replay
        onto).
    replicate : write-ahead-journal every accepted frame on a buddy
        member (``cluster/replication.py``) and recover member deaths
        by checkpoint + journal replay.  Loss per failure is bounded by
        the unflushed journal window instead of ``snapshot_every``.
    journal_flush_every : ship pending journal entries to the buddy
        every N cluster steps (1 = each step).  The replication lag —
        and the loss bound — is at most one flush window.
    heartbeat_timeout_s : declare a member HUNG (and fail it over) when
        it completes no step for this long on the injected ``timer``
        (None disables hang detection — raising members still die at
        the exception seam).
    retry : ``runtime/fault.RetryPolicy`` for transient member faults
        at the submit/step/checkpoint seams (the default retries 3
        attempts with deterministic exponential backoff; pass ``None``
        to make every fault fatal like PR 7).
    degraded_below : enter degraded mode when ``live_members <
        degraded_below * peak_members`` — new sessions and BULK frames
        get the typed ``ClusterDegradedError`` until capacity returns
        (0 disables).
    on_result : like ``StreamServer``'s — invoked with each
        ``FrameResult`` re-addressed to the global sid; without it
        results buffer until ``drain_results()``.
    injectors : ``{name: FailureInjector}`` — chaos hook; the injector
        fires at the top of that member's turn in ``step()`` (and its
        ``hanging`` window makes the cluster skip the member's turn —
        a hang is the absence of progress, not an exception).
    straggler_factory : zero-arg callable returning a fresh
        ``StragglerMonitor`` per member (None disables detection).
    straggler_weight : ring weight applied to a flagged member
        (fraction of a healthy member's hash-space share).
    timer : step-duration source for the straggler monitors, heartbeat
        suspicion and migration-pause stats (injectable for
        deterministic tests; defaults to ``time.perf_counter``).
    """

    def __init__(self, members: dict, *, seed: int = 0, vnodes: int = 64,
                 snapshot_every: int = 0, on_result=None,
                 injectors: dict | None = None,
                 replicate: bool = False, journal_flush_every: int = 1,
                 heartbeat_timeout_s: float | None = None,
                 retry=_UNSET,
                 degraded_below: float = 0.0,
                 straggler_factory=None, straggler_weight: float = 0.25,
                 timer=time.perf_counter,
                 registry: MetricsRegistry | None = None,
                 recorder: FlightRecorder | None = None):
        if not members:
            raise ValueError("a cluster needs at least one member")
        if not 0.0 < straggler_weight <= 1.0:
            raise ValueError("straggler_weight must be in (0, 1]")
        if journal_flush_every < 1:
            raise ValueError("journal_flush_every must be >= 1")
        if not 0.0 <= degraded_below <= 1.0:
            raise ValueError("degraded_below must be in [0, 1]")
        self._members: dict = {}
        self._ring = HashRing(seed=seed, vnodes=vnodes)
        self._on_result = on_result
        self._snapshot_every = int(snapshot_every)
        self._injectors = dict(injectors or {})
        self._replicate = bool(replicate)
        self._flush_every = int(journal_flush_every)
        # the federation's OWN telemetry plane (repro.obs;
        # docs/OBSERVABILITY.md) — separate from the members': a dead
        # member takes its registry down with it, the cluster's books
        # must survive.  Names are cluster_*-prefixed.
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.recorder = recorder if recorder is not None \
            else FlightRecorder(clock=timer)
        R = self.registry
        self._log = ReplicationLog(registry=R) if replicate else None
        self._retry = (RetryPolicy() if retry is _UNSET else retry)
        self._degraded_below = float(degraded_below)
        self._straggler_factory = straggler_factory
        self._straggler_weight = float(straggler_weight)
        self._timer = timer
        self._health = (HeartbeatMonitor(
            suspect_after_s=heartbeat_timeout_s, clock=timer, registry=R)
            if heartbeat_timeout_s is not None else None)
        self._lock = threading.RLock()
        # federation books (cumulative; survive migration + death) —
        # registry counters mutated only under the cluster lock, read
        # by stats() / the exporters as views
        def _per_class(name):
            return {q.value: R.counter(name, qos=q.value)
                    for q in QoSClass}
        self._submitted = _per_class("cluster_frames_submitted")
        self._served = _per_class("cluster_frames_served")
        self._shed = _per_class("cluster_shed_expired")
        self._lost = _per_class("cluster_lost_in_flight")
        self._rejected_full = _per_class("cluster_rejected_full")
        self._rejected_rl = _per_class("cluster_rejected_rate_limited")
        self._rejected_degraded = _per_class("cluster_rejected_degraded")
        self._sessions: dict = {}          # gsid -> _ClusterSession
        self._local: dict = {}             # (member, lsid) -> gsid
        self._orig_cb: dict = {}           # name -> pre-interpose hooks
        self._snaps: dict = {}             # gsid -> last checkpoint
        self._stragglers: dict = {}        # name -> StragglerMonitor
        self._results: list = []
        self._next_gsid = 0
        self._steps = 0
        self._migrations = R.counter("cluster_migrations")
        self._migrated_frames = R.counter("cluster_migrated_frames")
        self._migrated_bytes = R.counter("cluster_migrated_bytes")
        # full pause list stays (public migration_pauses_ms API —
        # benchmarks slice cold vs warm by move order); the sketch is
        # the bounded exporter/stats view of the same samples
        self._pause_ms: list = []
        self._pause_hist = R.histogram("cluster_migration_pause_ms")
        self._drains = R.counter("cluster_drains")
        self._failures = R.counter("cluster_member_failures")
        self._failovers = R.counter("cluster_failovers")
        #                                    sessions restored on survivors
        self._retries = R.counter("cluster_retries")
        #                                    transient faults retried away
        self._replayed_frames = R.counter("cluster_replayed_frames")
        #                                    journal entries re-queued
        self._drain_stragglers = R.counter("cluster_drain_stragglers")
        #                                    sessions stuck at stop(drain)
        self._g_sessions = R.gauge("cluster_sessions_open")
        self._g_members = R.gauge("cluster_members_live")
        # flight-recorder dumps taken automatically at member failure —
        # the black box survives exactly the event it explains (bounded:
        # newest _DUMP_KEEP kept)
        self.failover_dumps: list = []
        self._peak_members = 0             # high-water live membership
        self._drained: dict = {}           # name -> server, out of rotation
        self._dead: dict = {}              # name -> server, postmortem
        self._lost_sessions: list = []     # gsids dropped at member death
        self._thread = None
        self._stopping = False
        for name, srv in sorted(members.items()):
            self._admit_member(name, srv)

    # -- membership ----------------------------------------------------------
    def _admit_member(self, name, srv) -> None:
        if name in self._members:
            raise ValueError(f"member {name!r} already in the cluster")
        if srv.stats().running:
            raise ValueError(
                f"member {name!r} has its own serving thread — the "
                "cluster owns stepping; construct members unstarted")
        # interpose on the member's delivery callbacks: the federation
        # books count at exactly the instants the member's do, under
        # the cluster lock (step() holds it; the RLock re-enters).  The
        # originals are kept so leaving the cluster (drain, death)
        # un-wraps — a drained member that rejoins via add_member must
        # not end up double-wrapped (every frame counted twice)
        prev_r, prev_s, prev_a = (srv._on_result, srv._on_shed,
                                  srv._on_admit)
        self._orig_cb[name] = (prev_r, prev_s, prev_a)
        def on_result(r, _n=name, _p=prev_r):
            self._count_result(_n, r)
            if _p is not None:
                _p(r)
        def on_shed(qf, _n=name, _p=prev_s):
            self._count_shed(_n, qf)
            if _p is not None:
                _p(qf)
        def on_admit(qf, _n=name, _p=prev_a):
            # the journal-ack seam: write-ahead-record exactly the
            # frames the member accepted, with their admission ledger
            self._journal_admit(_n, qf)
            if _p is not None:
                _p(qf)
        srv._on_result = on_result
        srv._on_shed = on_shed
        srv._on_admit = on_admit
        self._members[name] = srv
        self._peak_members = max(self._peak_members, len(self._members))
        self._ring.add(name)
        if self._health is not None:
            self._health.watch(name)
        if self._straggler_factory is not None:
            self._stragglers[name] = self._straggler_factory()

    def _release_member(self, name):
        """Un-wrap the callbacks and detach every monitor — the common
        tail of drain (graceful) and death (not)."""
        srv = self._members.pop(name)
        srv._on_result, srv._on_shed, srv._on_admit = \
            self._orig_cb.pop(name)
        self._stragglers.pop(name, None)
        if self._health is not None:
            self._health.forget(name)
        return srv

    def add_member(self, name, srv) -> int:
        """Join a member and rebalance: ONLY sessions whose ring
        ownership moved to the newcomer migrate (the consistent-hash
        property).  Returns how many moved."""
        with self._lock:
            self._admit_member(name, srv)
            moved = self._rebalance()
            self._rehome_journals()
            return moved

    def drain(self, name) -> int:
        """Rolling-restart move: stop admission to the member (it
        leaves the ring), quiesce its in-flight tick, then migrate
        every one of its sessions — books, ring row, token bucket and
        queued frames with their original deadlines — to ring-chosen
        survivors.  No stream is dropped; the member's server object is
        parked in case it returns via ``add_member``.  Returns sessions
        migrated."""
        with self._lock:
            srv = self._members.get(name)
            if srv is None:
                raise KeyError(f"member {name!r} not in the cluster")
            homed = [g for g, cs in self._sessions.items()
                     if cs.member == name]
            if homed and len(self._members) < 2:
                raise RuntimeError(
                    "cannot drain the only member while it serves "
                    "sessions — add_member() a target first")
            if self._ring.has(name):
                self._ring.remove(name)
            srv.quiesce()
            for gsid in homed:
                self._migrate(gsid)
            self._drains.inc()
            self._drained[name] = self._release_member(name)
            self._injectors.pop(name, None)
            # journals homed on the leaving member re-ship gracefully
            # (it is alive — its data moves, nothing is cleared)
            self._rehome_journals()
            return len(homed)

    # -- degraded mode -------------------------------------------------------
    def _degraded(self) -> bool:
        return (self._degraded_below > 0.0 and self._peak_members > 0
                and len(self._members)
                < self._degraded_below * self._peak_members)

    def _refuse_degraded(self, qos: QoSClass, what: str):
        self._rejected_degraded[qos.value].inc()
        self.recorder.record("degraded_refusal", qos=qos.value,
                             what=what, live=len(self._members),
                             peak=self._peak_members)
        raise ClusterDegradedError(len(self._members), self._peak_members,
                                   self._degraded_below, what)

    # -- session API (any thread) --------------------------------------------
    def open_session(self, platform="pi4",
                     qos: QoSClass = QoSClass.STANDARD, *,
                     weight: float = 1.0, rate_limit=_UNSET):
        """Admit a session cluster-wide: place it on its ring owner,
        walking the preference order past members without headroom.
        In degraded mode new sessions are refused with the typed
        ``ClusterDegradedError`` — the survivors' headroom belongs to
        the streams they already hold.  Returns ``SessionInfo`` whose
        ``sid`` is the GLOBAL session id — valid at ``submit``/
        ``close_session`` on this cluster only."""
        with self._lock:
            if self._degraded():
                self._refuse_degraded(qos, "new session")
            gsid = self._next_gsid
            self._next_gsid += 1
            kw = {} if rate_limit is _UNSET else {"rate_limit": rate_limit}
            last = None
            for name in self._ring.preference(gsid):
                srv = self._members.get(name)
                if srv is None:
                    continue
                try:
                    info = srv.open_session(platform=platform, qos=qos,
                                            weight=weight, **kw)
                except AdmissionError as e:
                    last = e
                    continue
                cs = _ClusterSession(gsid, name, info.sid, qos, platform)
                self._sessions[gsid] = cs
                self._local[(name, info.sid)] = gsid
                if self._log is not None:
                    self._log.open(
                        gsid, self._ring.buddy(gsid, exclude=(name,)))
                # an immediate admission checkpoint: recovery must never
                # find a journal with no base to replay onto (the
                # satellite contract: lost_sessions stays empty whenever
                # a buddy holds a journal)
                if self._snapshot_every or self._replicate:
                    self._snaps[gsid] = srv.checkpoint_session(info.sid)
                return replace(info, sid=gsid)
            if last is not None:
                raise last
            raise RuntimeError("no live members in the cluster")

    def submit(self, gsid, frame) -> None:
        """Route one frame to the session's current owner.  The same
        typed refusals as ``StreamServer.submit`` (``RateLimitError``,
        ``QueueFullError``) plus the degraded-mode BULK door shed
        (``ClusterDegradedError``), all counted at the federation
        boundary; transient member faults are retried per the
        ``RetryPolicy`` before anything is refused; an accepted frame
        enters the cluster books here."""
        with self._lock:
            cs = self._require(gsid)
            if cs.qos is QoSClass.BULK and self._degraded():
                self._refuse_degraded(cs.qos, "BULK frame")
            srv = self._members[cs.member]
            try:
                self._call_member(lambda: srv.submit(cs.lsid, frame))
            except RateLimitError:
                self._rejected_rl[cs.qos.value].inc()
                raise
            except QueueFullError:
                self._rejected_full[cs.qos.value].inc()
                raise
            cs.submitted += 1
            self._submitted[cs.qos.value].inc()

    def close_session(self, gsid) -> None:
        """Graceful cluster-wide close: the owner drains every accepted
        frame (serve or visible shed), then evicts the row.  With no
        serving thread on the member, the close is driven to completion
        here via the member's caller-driven ``step()`` fallback."""
        with self._lock:
            cs = self._require(gsid)
            self._members[cs.member].close_session(cs.lsid)
            del self._local[(cs.member, cs.lsid)]
            del self._sessions[gsid]
            self._snaps.pop(gsid, None)
            if self._log is not None:
                self._log.close(gsid)

    def session_member(self, gsid):
        """The member currently serving the session (observability —
        tests assert who owns what across migrations)."""
        with self._lock:
            return self._require(gsid).member

    def _require(self, gsid) -> _ClusterSession:
        cs = self._sessions.get(gsid)
        if cs is None:
            raise KeyError(f"cluster session {gsid} is not open")
        return cs

    # -- retry seam ----------------------------------------------------------
    def _call_member(self, fn):
        """Run one member call under the transient-fault retry policy
        (``runtime/fault.py``): ``TransientFault``s retry with
        deterministic backoff and are counted; anything else — or an
        exhausted policy — propagates to the caller's fatal path."""
        if self._retry is None:
            return fn()
        return self._retry.call(fn, on_retry=self._count_retry)

    def _count_retry(self, attempt, backoff_s, exc) -> None:
        with self._lock:
            self._retries.inc()
            self.recorder.record("retry", attempt=attempt,
                                 backoff_s=backoff_s,
                                 error=type(exc).__name__)

    # -- federation books (member callbacks) ---------------------------------
    def _journal_admit(self, name, qf) -> None:
        if self._log is None:
            return
        with self._lock:
            gsid = self._local.get((name, qf.sid))
            if gsid is None:
                return
            self._log.record(gsid, t=qf.frame.t, frame=qf.frame,
                             enq_s=qf.enq_s, deadline_s=qf.deadline_s,
                             weight=qf.weight)
            if qf.trace is not None:       # the journal hop, in-span
                qf.trace.add("journal", qf.enq_s, gsid=gsid)

    def _count_result(self, name, r) -> None:
        with self._lock:
            gsid = self._local.get((name, r.sid))
            if gsid is None:       # not cluster-routed (shouldn't happen)
                return
            cs = self._sessions[gsid]
            cs.served += 1
            self._served[cs.qos.value].inc()
            if self._log is not None:
                self._log.settle(gsid, r.t)
            out = replace(r, sid=gsid)
            if self._on_result is None:
                self._results.append(out)
                return
        try:
            self._on_result(out)
        except Exception:          # user code must not kill stepping
            import traceback
            traceback.print_exc()

    def _count_shed(self, name, qf) -> None:
        with self._lock:
            gsid = self._local.get((name, qf.sid))
            if gsid is None:
                return
            cs = self._sessions[gsid]
            cs.shed += 1
            self._shed[cs.qos.value].inc()
            if self._log is not None:
                self._log.settle(gsid, qf.frame.t)

    def drain_results(self) -> list:
        """All ``FrameResult``s (global sids) since the last drain —
        only populated when no ``on_result`` callback is installed."""
        with self._lock:
            out, self._results = self._results, []
        return out

    # -- the stepping loop ---------------------------------------------------
    def step(self) -> int:
        """One cluster iteration: ship pending journal entries to their
        buddies (every ``journal_flush_every`` steps), then step every
        live member once (sorted name order — deterministic), with the
        chaos hooks around each turn: the member's ``FailureInjector``
        may kill it (fatal — handled as a real death), raise a
        ``TransientFault`` (retried per the policy), or HANG it (the
        turn is skipped and no heartbeat lands); its step duration
        feeds the ``StragglerMonitor`` (a flagged member's ring share
        shrinks).  After the turns, heartbeat suspicion fails over any
        member silent past the threshold, and every ``snapshot_every``
        steps each session is checkpointed for failure recovery.
        Returns frames delivered cluster-wide."""
        served = 0
        with self._lock:
            self._steps += 1
            if self._log is not None and \
                    self._steps % self._flush_every == 0:
                self._log.flush_all()
            for name in sorted(self._members):
                srv = self._members[name]
                inj = self._injectors.get(name)
                if inj is not None and inj.hanging(self._steps):
                    continue       # stuck: no progress, no beat
                t0 = self._timer()
                def turn(_srv=srv, _inj=inj):
                    if _inj is not None:
                        _inj.maybe_fail(self._steps)
                    return _srv.step()
                try:
                    served += self._call_member(turn)
                except Exception as e:      # noqa: BLE001 — death seam
                    self._member_failed(name, e)
                    continue
                if self._health is not None:
                    self._health.beat(name)
                mon = self._stragglers.get(name)
                if mon is not None and mon.record(self._steps,
                                                  self._timer() - t0):
                    if (self._ring.has(name) and self._ring.weight(name)
                            != self._straggler_weight):
                        self._ring.set_weight(name,
                                              self._straggler_weight)
            if self._health is not None:
                for name, silent in self._health.suspects():
                    if name in self._members:
                        self._member_failed(name, MemberHungError(
                            name, silent, self._health.suspect_after_s))
            if (self._snapshot_every
                    and self._steps % self._snapshot_every == 0):
                self._checkpoint_all()
        return served

    def pump(self, max_steps: int = 100_000) -> int:
        """Step until no member holds queued, staged, or in-flight work
        — the stepped-mode drain.  Returns frames delivered."""
        served = 0
        for _ in range(max_steps):
            with self._lock:
                if not any(s.busy() for s in self._members.values()):
                    return served
            served += self.step()
        raise RuntimeError(f"cluster did not drain in {max_steps} steps")

    def start(self) -> "GatewayCluster":
        """Background stepping thread (optional — tests and benchmarks
        drive ``step()``/``pump()`` directly for determinism)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stopping = False
            self._thread = threading.Thread(target=self._loop,
                                            name="streamsplit-cluster",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float = 60.0,
             max_steps: int = 100_000):
        """Stop the stepping thread, then (``drain=True``) pump every
        member empty.  A drain that stalls — a member wedged, a stream
        that cannot finish within ``max_steps`` — no longer exits
        through an anonymous pump error: it raises the typed
        ``ClusterDrainTimeout`` naming every straggler session and its
        outstanding frame count, and the stragglers are counted into
        ``ClusterStats.drain_stragglers``."""
        self._stopping = True
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout)
            if t.is_alive():
                raise TimeoutError("cluster stepping thread did not stop")
        self._thread = None
        if drain:
            try:
                self.pump(max_steps)
            except RuntimeError as e:
                with self._lock:
                    strag = {g: cs.outstanding
                             for g, cs in sorted(self._sessions.items())
                             if cs.outstanding > 0}
                    self._drain_stragglers.inc(len(strag))
                    for g, n in strag.items():
                        self.recorder.record("drain_straggler", gsid=g,
                                             outstanding=n)
                raise ClusterDrainTimeout(strag, max_steps) from e
        return self

    def _loop(self):
        while not self._stopping:
            if self.step() == 0:
                with self._lock:
                    idle = not any(s.busy()
                                   for s in self._members.values())
                if idle:
                    time.sleep(0.001)

    # -- migration -----------------------------------------------------------
    def _owner_live(self, gsid):
        for name in self._ring.preference(gsid):
            if name in self._members:
                return name
        return None

    def _rebalance(self) -> int:
        """Move ONLY sessions whose ring ownership changed (membership
        or weight change) — the consistent-hash contract."""
        moved = 0
        for gsid, cs in list(self._sessions.items()):
            want = self._owner_live(gsid)
            if want is not None and want != cs.member:
                self._migrate(gsid)
                moved += 1
        return moved

    def _rehome_journal(self, gsid) -> None:
        """Keep the session's journal on a live member that is not its
        owner (the buddy invariant); a conflicting or missing buddy
        re-ships the journal, metered."""
        if self._log is None:
            return
        j = self._log.journal(gsid)
        if j is None:
            return
        cs = self._sessions[gsid]
        if (j.buddy is None or j.buddy == cs.member
                or j.buddy not in self._members):
            self._log.rehome(
                gsid, self._ring.buddy(gsid, exclude=(cs.member,)))

    def _rehome_journals(self) -> None:
        if self._log is not None:
            for gsid in list(self._sessions):
                self._rehome_journal(gsid)

    def _refresh_checkpoint(self, gsid) -> None:
        """Re-checkpoint a session on its (new) owner right after a
        move — the old checkpoint predates the move and a destructive
        snapshot must never double as one.  A freshly imported session
        has no frames in any in-flight plan, so this needs no quiesce."""
        cs = self._sessions[gsid]
        if self._snapshot_every or self._replicate:
            self._snaps[gsid] = self._members[cs.member] \
                .checkpoint_session(cs.lsid)
            if self._log is not None:
                self._log.checkpointed(gsid)
        else:
            self._snaps.pop(gsid, None)

    def _migrate(self, gsid) -> None:
        """Move one session to its ring-preferred live member: quiesce
        the source, export (books + row + queued frames leave with
        their ledger), import at the first member with headroom.  If NO
        member can take it, the session is restored onto the source and
        the admission error propagates — a failed migration never loses
        a stream."""
        cs = self._sessions[gsid]
        src_name, src = cs.member, self._members[cs.member]
        t0 = self._timer()
        src.quiesce()
        snap = src.export_session(cs.lsid)
        del self._local[(src_name, cs.lsid)]
        last = None
        for tname in self._ring.preference(gsid):
            tsrv = self._members.get(tname)
            if tsrv is None or tname == src_name:
                continue
            try:
                info = tsrv.import_session(snap)
            except AdmissionError as e:
                last = e
                continue
            cs.member, cs.lsid = tname, info.sid
            self._local[(tname, info.sid)] = gsid
            self._migrations.inc()
            moved = len(snap.server.queued) if snap.server else 0
            self._migrated_frames.inc(moved)
            self._migrated_bytes.inc(snap.nbytes)
            pause = (self._timer() - t0) * 1e3
            self._pause_ms.append(pause)
            self._pause_hist.observe(pause)
            self.recorder.record("migrate_out", gsid=gsid, src=src_name,
                                 dst=tname, frames=moved,
                                 pause_ms=pause)
            self._refresh_checkpoint(gsid)
            self._rehome_journal(gsid)
            return
        # nobody could take it: put it back where it came from
        info = src.import_session(snap)
        cs.lsid = info.sid
        self._local[(src_name, info.sid)] = gsid
        if last is not None:
            raise last
        raise RuntimeError(f"no migration target for session {gsid}")

    # -- failure recovery ----------------------------------------------------
    def _checkpoint_all(self) -> None:
        quiesced = set()
        for gsid, cs in list(self._sessions.items()):
            srv = self._members.get(cs.member)
            if srv is None:
                continue
            if cs.member not in quiesced:   # checkpoint needs no plan
                srv.quiesce()               # in flight (migration-safe)
                quiesced.add(cs.member)
            try:
                self._snaps[gsid] = self._call_member(
                    lambda _s=srv, _c=cs: _s.checkpoint_session(_c.lsid))
            except KeyError:
                continue                    # closing under us
            except TransientFault:
                continue   # retries exhausted: keep the previous
                #            checkpoint — the journal still bounds loss
            if self._log is not None:
                # the fresh checkpoint is the durable record of every
                # settled frame: those journal entries can go
                self._log.checkpointed(gsid)

    def _member_failed(self, name, exc) -> None:
        """A member died (raised) or hung (heartbeat suspicion) — the
        same recovery path either way.  Every session it homed resumes
        on a survivor from its last checkpoint; with replication, the
        buddy journal's open entries replay on top through the ordinary
        ``import_session`` implant, so only frames whose journal append
        never reached the buddy are counted into ``lost_in_flight``.
        Journals HOMED on the dead member lose their shipped data
        (cleared, re-homed — their sessions are exposed until the next
        checkpoint).  Sessions with neither checkpoint nor journal are
        dropped visibly (``lost_sessions``).  The whole recovery lands
        in the flight recorder, and an automatic dump is appended to
        ``failover_dumps`` at the end — the black box survives exactly
        the event it exists to explain."""
        self._failures.inc()
        self.recorder.record(
            "member_hung" if isinstance(exc, MemberHungError)
            else "member_failed",
            member=name, error=type(exc).__name__, detail=str(exc))
        self._dead[name] = self._release_member(name)
        self._injectors.pop(name, None)
        if self._ring.has(name):
            self._ring.remove(name)
        if self._log is not None:
            self._log.drop_member(name)
        for gsid, cs in list(self._sessions.items()):
            if cs.member != name:
                continue
            j = self._log.journal(gsid) if self._log is not None else None
            replay = j.replayable() if j is not None else []
            if j is not None:
                # pending appends die with the owner — it was the
                # shipping side of the transport
                j.entries = [e for e in j.entries if e.acked]
            lost_now = max(0, cs.outstanding - len(replay))
            cs.lost += lost_now
            self._lost[cs.qos.value].inc(lost_now)
            if lost_now:
                self.recorder.record("lost_in_flight", gsid=gsid,
                                     qos=cs.qos.value, frames=lost_now)
            del self._local[(name, cs.lsid)]
            snap = self._snaps.get(gsid)
            restored = False
            if snap is not None:
                sv = snap.server if snap.server is not None else \
                    ServerSessionSnapshot(submitted=0, served=0, shed=0,
                                          weight=1.0)
                queued = tuple(e.snapshot() for e in replay)
                resume = replace(
                    snap, server=replace(
                        sv, submitted=sv.submitted + len(queued),
                        queued=queued))
                for tname in self._ring.preference(gsid):
                    tsrv = self._members.get(tname)
                    if tsrv is None:
                        continue
                    offer = resume
                    if tsrv.tracer.enabled and queued:
                        # journal-replay trace continuity: the replayed
                        # frame keeps its ORIGINAL enqueue timestamp in
                        # the implant; its span begins (adopt, not
                        # maybe_begin — the real submit died with the
                        # owner) at the replay hop, sampled by the
                        # cluster-stable (gsid, t) decision
                        tq = tuple(
                            replace(qs, trace=tsrv.tracer.adopt(
                                gsid, qs.frame.t, "replay",
                                enq_s=qs.enq_s, member=tname))
                            for qs in queued)
                        offer = replace(resume, server=replace(
                            offer.server, queued=tq))
                    try:
                        info = tsrv.import_session(offer)
                    except AdmissionError:
                        continue
                    cs.member, cs.lsid = tname, info.sid
                    self._local[(tname, info.sid)] = gsid
                    self._failovers.inc()
                    self._replayed_frames.inc(len(queued))
                    self.recorder.record("failover", gsid=gsid,
                                         src=name, dst=tname,
                                         replayed=len(queued),
                                         lost=lost_now)
                    if queued:
                        self.recorder.record("journal_replay", gsid=gsid,
                                             dst=tname,
                                             frames=len(queued))
                    self._refresh_checkpoint(gsid)
                    self._rehome_journal(gsid)
                    restored = True
                    break
            if not restored:
                # the replayable frames found no home either: they are
                # lost WITH the session — counted, like everything here
                cs.lost += len(replay)
                self._lost[cs.qos.value].inc(len(replay))
                del self._sessions[gsid]
                self._snaps.pop(gsid, None)
                if self._log is not None:
                    self._log.close(gsid)
                self._lost_sessions.append(gsid)
                self.recorder.record("lost_in_flight", gsid=gsid,
                                     qos=cs.qos.value,
                                     frames=len(replay),
                                     session_lost=True)
        # the automatic black-box dump, AFTER every recovery decision
        # above was recorded — bounded like everything always-on
        self.failover_dumps.append(
            self.recorder.dump(reason=f"member_failed:{name}"))
        del self.failover_dumps[:-_DUMP_KEEP]

    @property
    def migration_pauses_ms(self) -> tuple:
        """Every migration pause so far, in move order (ms) — the
        percentile summary is in ``stats()``; benchmarks slice this to
        separate cold (first move to a fresh receiver, compile-heavy)
        from warm steady-state pauses."""
        with self._lock:
            return tuple(self._pause_ms)

    @property
    def lost_sessions(self) -> list:
        """Global sids dropped at member death with no checkpoint to
        restore from — explicit, like every other loss here."""
        with self._lock:
            return list(self._lost_sessions)

    # -- observability -------------------------------------------------------
    def stats(self) -> ClusterStats:
        """One consistent federation snapshot — taken under the cluster
        lock, which every frame transition (submit, member step with
        its callbacks, migration, death) also holds, so the
        ``ClusterStats.conserved`` identity holds at EVERY snapshot."""
        with self._lock:
            member_stats = {n: self._members[n].stats()
                            for n in sorted(self._members)}
            depth = {q.value: 0 for q in QoSClass}
            infl = {q.value: 0 for q in QoSClass}
            for st in member_stats.values():
                for c, v in st.queue_depth.items():
                    depth[c] += v
                for c, v in st.in_flight.items():
                    infl[c] += v
            # percentiles from the registry sketch — exact (bit-identical
            # to numpy.percentile) below its exact_cap, which every
            # realistic migration count sits under
            s = self._pause_hist.summary()
            pause = {"p50": s["p50"], "p95": s["p95"], "max": s["max"]}
            self._g_sessions.set(len(self._sessions))
            self._g_members.set(len(self._members))
            def _view(d):
                return {c: m.value for c, m in d.items()}
            return ClusterStats(
                members=tuple(sorted(self._members)),
                sessions_open=len(self._sessions),
                submitted=_view(self._submitted),
                served=_view(self._served),
                queue_depth=depth,
                in_flight=infl,
                shed_expired=_view(self._shed),
                lost_in_flight=_view(self._lost),
                rejected_full=_view(self._rejected_full),
                rejected_rate_limited=_view(self._rejected_rl),
                migrations=self._migrations.value,
                migrated_frames=self._migrated_frames.value,
                migrated_bytes=self._migrated_bytes.value,
                migration_pause_ms=pause,
                drains=self._drains.value,
                failures=self._failures.value,
                ring_share=self._ring.share(),
                member_stats=member_stats,
                degraded=self._degraded(),
                failovers=self._failovers.value,
                retries=self._retries.value,
                replayed_frames=self._replayed_frames.value,
                journal_bytes=(self._log.bytes_shipped
                               if self._log is not None else 0),
                rejected_degraded=_view(self._rejected_degraded),
                drain_stragglers=self._drain_stragglers.value)

    def metrics(self) -> str:
        """The federation registry (``cluster_*`` metrics) in
        Prometheus text exposition format.  Member-level metrics live
        on each member's own registry (``member.metrics()``) — a dead
        member's series disappear with it, by design; the cluster
        series are the ones that survive."""
        with self._lock:
            self._g_sessions.set(len(self._sessions))
            self._g_members.set(len(self._members))
        return to_prometheus(self.registry)

    def dump_trace(self, reason: str = "on_demand") -> dict:
        """Flight-recorder dump of the federation black box (see also
        ``failover_dumps`` for the automatic per-failure dumps)."""
        return self.recorder.dump(reason=reason)
