"""``GatewayCluster`` — multi-gateway federation over ``StreamServer``s.

One gateway serves one accelerator's fleet; a deployment has several.
This module federates N member servers behind a single session API:

- **Routing**: every cluster session gets a stable global sid (gsid)
  and is placed on the member that owns it on a seeded consistent-hash
  ring (``cluster/hashing.py``).  Placement walks the ring's preference
  order past full members, so admission only fails when the whole
  cluster is out of headroom.
- **Live migration**: ``drain(member)`` (rolling restarts) and
  ``add_member`` / member failure (rebalance) move sessions between
  gateways via the ``SessionSnapshot`` seam — ring row, sync books,
  scheduler books, token-bucket level, and every waiting frame with its
  ORIGINAL deadline travel together, so a migrated stream is
  indistinguishable from one that never moved (the bit-parity oracle in
  ``tests/test_cluster.py`` pins this).
- **Fault tolerance**: a member that dies mid-step (detected by the
  exception, injected in tests via ``runtime/fault.FailureInjector``)
  is removed from the ring; its sessions resume on survivors from the
  last periodic checkpoint (``snapshot_every``).  Frames that were
  queued or in flight on the dead member are counted — never silently
  dropped — in ``ClusterStats.lost_in_flight``, which is exactly the
  term that keeps the cluster-wide conservation identity

      submitted == served + queue_depth + in_flight
                   + shed_expired + lost_in_flight

  true at every ``stats()`` snapshot, including across failures.
  ``StragglerMonitor`` feeds a slow-member signal that shrinks the
  member's hash-space share (placement bias; nothing is evicted).

**The cluster owns its members.**  Member servers must be constructed
WITHOUT their own serving thread running; the cluster drives them
through the public ``step()`` seam — one ``cluster.step()`` steps every
live member once, deterministically, which is also why every chaos test
runs on a fake clock.  All client traffic (open/submit/close) must flow
through the cluster: a frame submitted directly to a member is invisible
to the federation books and breaks the conservation identity.

The cluster keeps its OWN books at the federation boundary (counted at
``submit`` / ``on_result`` / ``on_shed``) instead of summing member
counters: a dead member's counters vanish with it, and a migrated
session's would double-count — cluster-level accounting is the only
representation that survives both.
"""
from __future__ import annotations

import threading
import time
from dataclasses import replace

import numpy as np

from repro.api.types import AdmissionError, ClusterStats, QoSClass
from repro.cluster.hashing import HashRing
from repro.serving.queues import QueueFullError, RateLimitError
from repro.serving.server import _UNSET

__all__ = ["GatewayCluster"]


class _ClusterSession:
    """Federation-side session record: where the session lives now,
    plus the cluster's own conservation books for it (these survive
    migration and member death — member counters do not)."""

    __slots__ = ("gsid", "member", "lsid", "qos", "platform",
                 "submitted", "served", "shed", "lost")

    def __init__(self, gsid, member, lsid, qos, platform):
        self.gsid = gsid
        self.member = member       # current owner's name
        self.lsid = lsid           # sid on that member (fresh per move)
        self.qos = qos
        self.platform = platform
        self.submitted = 0
        self.served = 0
        self.shed = 0
        self.lost = 0              # counted at member death, cumulative


class GatewayCluster:
    """Federates N ``StreamServer`` members behind one session API.

    Parameters
    ----------
    members : ``{name: StreamServer}``.  Servers must not have their
        serving thread running — the cluster steps them.
    seed / vnodes : consistent-hash ring determinism knobs
        (``cluster/hashing.py``).
    snapshot_every : take a failure-recovery checkpoint of every
        session each N cluster steps (0 disables; then a member failure
        loses its sessions entirely — still counted, never silent).
    on_result : like ``StreamServer``'s — invoked with each
        ``FrameResult`` re-addressed to the global sid; without it
        results buffer until ``drain_results()``.
    injectors : ``{name: FailureInjector}`` — chaos hook; the injector
        fires at the top of that member's turn in ``step()``.
    straggler_factory : zero-arg callable returning a fresh
        ``StragglerMonitor`` per member (None disables detection).
    straggler_weight : ring weight applied to a flagged member
        (fraction of a healthy member's hash-space share).
    timer : step-duration source for the straggler monitors and
        migration-pause stats (injectable for deterministic tests;
        defaults to ``time.perf_counter``).
    """

    def __init__(self, members: dict, *, seed: int = 0, vnodes: int = 64,
                 snapshot_every: int = 0, on_result=None,
                 injectors: dict | None = None,
                 straggler_factory=None, straggler_weight: float = 0.25,
                 timer=time.perf_counter):
        if not members:
            raise ValueError("a cluster needs at least one member")
        if not 0.0 < straggler_weight <= 1.0:
            raise ValueError("straggler_weight must be in (0, 1]")
        self._members: dict = {}
        self._ring = HashRing(seed=seed, vnodes=vnodes)
        self._on_result = on_result
        self._snapshot_every = int(snapshot_every)
        self._injectors = dict(injectors or {})
        self._straggler_factory = straggler_factory
        self._straggler_weight = float(straggler_weight)
        self._timer = timer
        self._lock = threading.RLock()
        # federation books (cumulative; survive migration + death)
        self._submitted = {q.value: 0 for q in QoSClass}
        self._served = {q.value: 0 for q in QoSClass}
        self._shed = {q.value: 0 for q in QoSClass}
        self._lost = {q.value: 0 for q in QoSClass}
        self._rejected_full = {q.value: 0 for q in QoSClass}
        self._rejected_rl = {q.value: 0 for q in QoSClass}
        self._sessions: dict = {}          # gsid -> _ClusterSession
        self._local: dict = {}             # (member, lsid) -> gsid
        self._orig_cb: dict = {}           # name -> pre-interpose hooks
        self._snaps: dict = {}             # gsid -> last checkpoint
        self._stragglers: dict = {}        # name -> StragglerMonitor
        self._results: list = []
        self._next_gsid = 0
        self._steps = 0
        self._migrations = 0
        self._migrated_frames = 0
        self._migrated_bytes = 0
        self._pause_ms: list = []
        self._drains = 0
        self._failures = 0
        self._drained: dict = {}           # name -> server, out of rotation
        self._dead: dict = {}              # name -> server, postmortem
        self._lost_sessions: list = []     # gsids dropped at member death
        self._thread = None
        self._stopping = False
        for name, srv in sorted(members.items()):
            self._admit_member(name, srv)

    # -- membership ----------------------------------------------------------
    def _admit_member(self, name, srv) -> None:
        if name in self._members:
            raise ValueError(f"member {name!r} already in the cluster")
        if srv.stats().running:
            raise ValueError(
                f"member {name!r} has its own serving thread — the "
                "cluster owns stepping; construct members unstarted")
        # interpose on the member's delivery callbacks: the federation
        # books count at exactly the instants the member's do, under
        # the cluster lock (step() holds it; the RLock re-enters).  The
        # originals are kept so leaving the cluster (drain, death)
        # un-wraps — a drained member that rejoins via add_member must
        # not end up double-wrapped (every frame counted twice)
        prev_r, prev_s = srv._on_result, srv._on_shed
        self._orig_cb[name] = (prev_r, prev_s)
        def on_result(r, _n=name, _p=prev_r):
            self._count_result(_n, r)
            if _p is not None:
                _p(r)
        def on_shed(qf, _n=name, _p=prev_s):
            self._count_shed(_n, qf)
            if _p is not None:
                _p(qf)
        srv._on_result = on_result
        srv._on_shed = on_shed
        self._members[name] = srv
        self._ring.add(name)
        if self._straggler_factory is not None:
            self._stragglers[name] = self._straggler_factory()

    def add_member(self, name, srv) -> int:
        """Join a member and rebalance: ONLY sessions whose ring
        ownership moved to the newcomer migrate (the consistent-hash
        property).  Returns how many moved."""
        with self._lock:
            self._admit_member(name, srv)
            return self._rebalance()

    def drain(self, name) -> int:
        """Rolling-restart move: stop admission to the member (it
        leaves the ring), quiesce its in-flight tick, then migrate
        every one of its sessions — books, ring row, token bucket and
        queued frames with their original deadlines — to ring-chosen
        survivors.  No stream is dropped; the member's server object is
        parked in case it returns via ``add_member``.  Returns sessions
        migrated."""
        with self._lock:
            srv = self._members.get(name)
            if srv is None:
                raise KeyError(f"member {name!r} not in the cluster")
            homed = [g for g, cs in self._sessions.items()
                     if cs.member == name]
            if homed and len(self._members) < 2:
                raise RuntimeError(
                    "cannot drain the only member while it serves "
                    "sessions — add_member() a target first")
            if self._ring.has(name):
                self._ring.remove(name)
            srv.quiesce()
            for gsid in homed:
                self._migrate(gsid)
            self._drains += 1
            self._drained[name] = self._members.pop(name)
            srv._on_result, srv._on_shed = self._orig_cb.pop(name)
            self._stragglers.pop(name, None)
            return len(homed)

    # -- session API (any thread) --------------------------------------------
    def open_session(self, platform="pi4",
                     qos: QoSClass = QoSClass.STANDARD, *,
                     weight: float = 1.0, rate_limit=_UNSET):
        """Admit a session cluster-wide: place it on its ring owner,
        walking the preference order past members without headroom.
        Returns ``SessionInfo`` whose ``sid`` is the GLOBAL session id
        — valid at ``submit``/``close_session`` on this cluster only."""
        with self._lock:
            gsid = self._next_gsid
            self._next_gsid += 1
            kw = {} if rate_limit is _UNSET else {"rate_limit": rate_limit}
            last = None
            for name in self._ring.preference(gsid):
                srv = self._members.get(name)
                if srv is None:
                    continue
                try:
                    info = srv.open_session(platform=platform, qos=qos,
                                            weight=weight, **kw)
                except AdmissionError as e:
                    last = e
                    continue
                cs = _ClusterSession(gsid, name, info.sid, qos, platform)
                self._sessions[gsid] = cs
                self._local[(name, info.sid)] = gsid
                return replace(info, sid=gsid)
            if last is not None:
                raise last
            raise RuntimeError("no live members in the cluster")

    def submit(self, gsid, frame) -> None:
        """Route one frame to the session's current owner.  The same
        typed refusals as ``StreamServer.submit`` (``RateLimitError``,
        ``QueueFullError``), counted at the federation boundary; an
        accepted frame enters the cluster books here."""
        with self._lock:
            cs = self._require(gsid)
            srv = self._members[cs.member]
            try:
                srv.submit(cs.lsid, frame)
            except RateLimitError:
                self._rejected_rl[cs.qos.value] += 1
                raise
            except QueueFullError:
                self._rejected_full[cs.qos.value] += 1
                raise
            cs.submitted += 1
            self._submitted[cs.qos.value] += 1

    def close_session(self, gsid) -> None:
        """Graceful cluster-wide close: the owner drains every accepted
        frame (serve or visible shed), then evicts the row.  With no
        serving thread on the member, the close is driven to completion
        here via the member's caller-driven ``step()`` fallback."""
        with self._lock:
            cs = self._require(gsid)
            self._members[cs.member].close_session(cs.lsid)
            del self._local[(cs.member, cs.lsid)]
            del self._sessions[gsid]
            self._snaps.pop(gsid, None)

    def session_member(self, gsid):
        """The member currently serving the session (observability —
        tests assert who owns what across migrations)."""
        with self._lock:
            return self._require(gsid).member

    def _require(self, gsid) -> _ClusterSession:
        cs = self._sessions.get(gsid)
        if cs is None:
            raise KeyError(f"cluster session {gsid} is not open")
        return cs

    # -- federation books (member callbacks) ---------------------------------
    def _count_result(self, name, r) -> None:
        with self._lock:
            gsid = self._local.get((name, r.sid))
            if gsid is None:       # not cluster-routed (shouldn't happen)
                return
            cs = self._sessions[gsid]
            cs.served += 1
            self._served[cs.qos.value] += 1
            out = replace(r, sid=gsid)
            if self._on_result is None:
                self._results.append(out)
                return
        try:
            self._on_result(out)
        except Exception:          # user code must not kill stepping
            import traceback
            traceback.print_exc()

    def _count_shed(self, name, qf) -> None:
        with self._lock:
            gsid = self._local.get((name, qf.sid))
            if gsid is None:
                return
            cs = self._sessions[gsid]
            cs.shed += 1
            self._shed[cs.qos.value] += 1

    def drain_results(self) -> list:
        """All ``FrameResult``s (global sids) since the last drain —
        only populated when no ``on_result`` callback is installed."""
        with self._lock:
            out, self._results = self._results, []
        return out

    # -- the stepping loop ---------------------------------------------------
    def step(self) -> int:
        """One cluster iteration: step every live member once (sorted
        name order — deterministic), with the chaos hooks around each
        turn: the member's ``FailureInjector`` may kill it (handled as
        a real death), its step duration feeds the ``StragglerMonitor``
        (a flagged member's ring share shrinks), and every
        ``snapshot_every`` steps each session is checkpointed for
        failure recovery.  Returns frames delivered cluster-wide."""
        served = 0
        with self._lock:
            self._steps += 1
            for name in sorted(self._members):
                srv = self._members[name]
                t0 = self._timer()
                try:
                    inj = self._injectors.get(name)
                    if inj is not None:
                        inj.maybe_fail(self._steps)
                    served += srv.step()
                except Exception as e:      # noqa: BLE001 — death seam
                    self._member_failed(name, e)
                    continue
                mon = self._stragglers.get(name)
                if mon is not None and mon.record(self._steps,
                                                  self._timer() - t0):
                    if (self._ring.has(name) and self._ring.weight(name)
                            != self._straggler_weight):
                        self._ring.set_weight(name,
                                              self._straggler_weight)
            if (self._snapshot_every
                    and self._steps % self._snapshot_every == 0):
                self._checkpoint_all()
        return served

    def pump(self, max_steps: int = 100_000) -> int:
        """Step until no member holds queued, staged, or in-flight work
        — the stepped-mode drain.  Returns frames delivered."""
        served = 0
        for _ in range(max_steps):
            with self._lock:
                if not any(s.busy() for s in self._members.values()):
                    return served
            served += self.step()
        raise RuntimeError(f"cluster did not drain in {max_steps} steps")

    def start(self) -> "GatewayCluster":
        """Background stepping thread (optional — tests and benchmarks
        drive ``step()``/``pump()`` directly for determinism)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stopping = False
            self._thread = threading.Thread(target=self._loop,
                                            name="streamsplit-cluster",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float = 60.0):
        self._stopping = True
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout)
            if t.is_alive():
                raise TimeoutError("cluster stepping thread did not stop")
        self._thread = None
        if drain:
            self.pump()
        return self

    def _loop(self):
        while not self._stopping:
            if self.step() == 0:
                with self._lock:
                    idle = not any(s.busy()
                                   for s in self._members.values())
                if idle:
                    time.sleep(0.001)

    # -- migration -----------------------------------------------------------
    def _owner_live(self, gsid):
        for name in self._ring.preference(gsid):
            if name in self._members:
                return name
        return None

    def _rebalance(self) -> int:
        """Move ONLY sessions whose ring ownership changed (membership
        or weight change) — the consistent-hash contract."""
        moved = 0
        for gsid, cs in list(self._sessions.items()):
            want = self._owner_live(gsid)
            if want is not None and want != cs.member:
                self._migrate(gsid)
                moved += 1
        return moved

    def _migrate(self, gsid) -> None:
        """Move one session to its ring-preferred live member: quiesce
        the source, export (books + row + queued frames leave with
        their ledger), import at the first member with headroom.  If NO
        member can take it, the session is restored onto the source and
        the admission error propagates — a failed migration never loses
        a stream."""
        cs = self._sessions[gsid]
        src_name, src = cs.member, self._members[cs.member]
        t0 = self._timer()
        src.quiesce()
        snap = src.export_session(cs.lsid)
        del self._local[(src_name, cs.lsid)]
        last = None
        for tname in self._ring.preference(gsid):
            tsrv = self._members.get(tname)
            if tsrv is None or tname == src_name:
                continue
            try:
                info = tsrv.import_session(snap)
            except AdmissionError as e:
                last = e
                continue
            cs.member, cs.lsid = tname, info.sid
            self._local[(tname, info.sid)] = gsid
            self._migrations += 1
            self._migrated_frames += (len(snap.server.queued)
                                      if snap.server else 0)
            self._migrated_bytes += snap.nbytes
            self._pause_ms.append((self._timer() - t0) * 1e3)
            # the old checkpoint predates the move and a destructive
            # snapshot must never double as one (its queued frames
            # would double-count against lost_in_flight at a later
            # failure) — recovery re-checkpoints on the new owner
            self._snaps.pop(gsid, None)
            return
        # nobody could take it: put it back where it came from
        info = src.import_session(snap)
        cs.lsid = info.sid
        self._local[(src_name, info.sid)] = gsid
        if last is not None:
            raise last
        raise RuntimeError(f"no migration target for session {gsid}")

    # -- failure recovery ----------------------------------------------------
    def _checkpoint_all(self) -> None:
        quiesced = set()
        for gsid, cs in list(self._sessions.items()):
            srv = self._members.get(cs.member)
            if srv is None:
                continue
            if cs.member not in quiesced:   # checkpoint needs no plan
                srv.quiesce()               # in flight (migration-safe)
                quiesced.add(cs.member)
            try:
                self._snaps[gsid] = srv.checkpoint_session(cs.lsid)
            except KeyError:
                pass                        # closing under us

    def _member_failed(self, name, exc) -> None:
        """A member died mid-step.  Its queued + in-flight frames are
        gone — counted per session into ``lost_in_flight`` (the books
        are cluster-side, so the dead member's counters aren't needed)
        — and every session resumes on a survivor from its last
        checkpoint.  Sessions without a checkpoint are dropped, visibly
        (``lost_sessions``)."""
        self._failures += 1
        srv = self._members.pop(name)
        self._dead[name] = srv
        srv._on_result, srv._on_shed = self._orig_cb.pop(name)
        self._injectors.pop(name, None)
        self._stragglers.pop(name, None)
        if self._ring.has(name):
            self._ring.remove(name)
        for gsid, cs in list(self._sessions.items()):
            if cs.member != name:
                continue
            outstanding = cs.submitted - cs.served - cs.shed - cs.lost
            cs.lost += outstanding
            self._lost[cs.qos.value] += outstanding
            del self._local[(name, cs.lsid)]
            snap = self._snaps.get(gsid)
            restored = False
            if snap is not None:
                for tname in self._ring.preference(gsid):
                    tsrv = self._members.get(tname)
                    if tsrv is None:
                        continue
                    try:
                        info = tsrv.import_session(snap)
                    except AdmissionError:
                        continue
                    cs.member, cs.lsid = tname, info.sid
                    self._local[(tname, info.sid)] = gsid
                    restored = True
                    break
            if not restored:
                del self._sessions[gsid]
                self._snaps.pop(gsid, None)
                self._lost_sessions.append(gsid)

    @property
    def migration_pauses_ms(self) -> tuple:
        """Every migration pause so far, in move order (ms) — the
        percentile summary is in ``stats()``; benchmarks slice this to
        separate cold (first move to a fresh receiver, compile-heavy)
        from warm steady-state pauses."""
        with self._lock:
            return tuple(self._pause_ms)

    @property
    def lost_sessions(self) -> list:
        """Global sids dropped at member death with no checkpoint to
        restore from — explicit, like every other loss here."""
        with self._lock:
            return list(self._lost_sessions)

    # -- observability -------------------------------------------------------
    def stats(self) -> ClusterStats:
        """One consistent federation snapshot — taken under the cluster
        lock, which every frame transition (submit, member step with
        its callbacks, migration, death) also holds, so the
        ``ClusterStats.conserved`` identity holds at EVERY snapshot."""
        with self._lock:
            member_stats = {n: self._members[n].stats()
                            for n in sorted(self._members)}
            depth = {q.value: 0 for q in QoSClass}
            infl = {q.value: 0 for q in QoSClass}
            for st in member_stats.values():
                for c, v in st.queue_depth.items():
                    depth[c] += v
                for c, v in st.in_flight.items():
                    infl[c] += v
            if self._pause_ms:
                a = np.asarray(self._pause_ms, np.float64)
                pause = {"p50": float(np.percentile(a, 50)),
                         "p95": float(np.percentile(a, 95)),
                         "max": float(a.max())}
            else:
                pause = {"p50": 0.0, "p95": 0.0, "max": 0.0}
            return ClusterStats(
                members=tuple(sorted(self._members)),
                sessions_open=len(self._sessions),
                submitted=dict(self._submitted),
                served=dict(self._served),
                queue_depth=depth,
                in_flight=infl,
                shed_expired=dict(self._shed),
                lost_in_flight=dict(self._lost),
                rejected_full=dict(self._rejected_full),
                rejected_rate_limited=dict(self._rejected_rl),
                migrations=self._migrations,
                migrated_frames=self._migrated_frames,
                migrated_bytes=self._migrated_bytes,
                migration_pause_ms=pause,
                drains=self._drains,
                failures=self._failures,
                ring_share=self._ring.share(),
                member_stats=member_stats)
