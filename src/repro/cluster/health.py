"""Heartbeat failure detection — catching members that HANG.

PR 7's failure seam only fires when a member *raises*: a member whose
step loop wedges (deadlocked executor, stuck device, livelocked queue)
makes no progress, reports no error, and would hold its sessions
hostage forever.  This module closes that gap with the classic
heartbeat/suspicion pattern, deterministic on the injected clock:

- every completed member turn in ``GatewayCluster.step()`` records a
  BEAT for that member (an idle member still beats — completing a
  no-op step is progress; what a hung member cannot do is complete);
- ``suspects()`` returns every watched member whose last beat is older
  than ``suspect_after_s`` on the cluster's own timer;
- the cluster routes a suspect through the SAME ``_member_failed`` →
  checkpoint + journal-replay recovery path as a raising member, with
  a typed ``MemberHungError`` as the cause — hung and crashed members
  are indistinguishable to the sessions they held, which is the point.

No wall-clock anywhere: the monitor reads time only through the clock
it was constructed with, so chaos tests advance a fake clock and get
byte-for-byte reproducible suspicion decisions.
"""
from __future__ import annotations

__all__ = ["HeartbeatMonitor", "MemberHungError"]


class MemberHungError(RuntimeError):
    """A member stopped making progress without raising — detected by
    heartbeat suspicion, failed over like a crash (typed so postmortems
    can tell a hang from a fault)."""

    def __init__(self, name, silent_for_s: float, suspect_after_s: float):
        self.name = name
        self.silent_for_s = float(silent_for_s)
        self.suspect_after_s = float(suspect_after_s)
        super().__init__(
            f"member {name!r} hung: no heartbeat for "
            f"{silent_for_s:.3f}s (suspicion threshold "
            f"{suspect_after_s:.3f}s)")


class HeartbeatMonitor:
    """Last-beat table + suspicion threshold on an injected clock.

    Not thread-safe on its own — the owning cluster mutates it under
    its lock, like every other piece of federation state.
    """

    def __init__(self, *, suspect_after_s: float, clock, registry=None):
        if suspect_after_s <= 0:
            raise ValueError("suspect_after_s must be > 0")
        self.suspect_after_s = float(suspect_after_s)
        self._clock = clock
        self._last: dict = {}      # member -> clock at last beat
        self._beats = (registry.counter("cluster_heartbeats")
                       if registry is not None else None)

    def watch(self, name) -> None:
        """Start (or reset) monitoring — admission counts as a beat, so
        a freshly joined member gets a full suspicion window before it
        can be declared hung."""
        self._last[name] = self._clock()

    def forget(self, name) -> None:
        self._last.pop(name, None)

    def beat(self, name) -> None:
        """The member completed a step — progress, by definition."""
        if name in self._last:
            self._last[name] = self._clock()
            if self._beats is not None:
                self._beats.inc()

    def silent_for_s(self, name) -> float:
        return self._clock() - self._last[name]

    def suspects(self) -> list:
        """``[(name, silent_for_s)]`` past the threshold, name order —
        deterministic, like every other iteration in the cluster."""
        now = self._clock()
        return [(n, now - t) for n, t in sorted(self._last.items())
                if now - t > self.suspect_after_s]
