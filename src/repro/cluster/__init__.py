"""Multi-gateway federation: consistent-hash routing, live session
migration, and chaos-tested drain/rebalance (docs/FEDERATION.md).

Public surface::

    from repro.cluster import GatewayCluster, HashRing, SessionSnapshot
    from repro.cluster import FailureInjector, StragglerMonitor
"""
from repro.api.types import (ClusterStats, ServerSessionSnapshot,
                             SessionSnapshot)
from repro.cluster.cluster import GatewayCluster
from repro.cluster.hashing import HashRing
from repro.runtime.fault import (FailureInjector, StragglerEvent,
                                 StragglerMonitor)

__all__ = [
    "ClusterStats",
    "FailureInjector",
    "GatewayCluster",
    "HashRing",
    "ServerSessionSnapshot",
    "SessionSnapshot",
    "StragglerEvent",
    "StragglerMonitor",
]
