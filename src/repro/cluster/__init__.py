"""Multi-gateway federation: consistent-hash routing, live session
migration, frame journaling + buddy replication, heartbeat failure
detection, and chaos-tested drain/rebalance (docs/FEDERATION.md).

Public surface::

    from repro.cluster import GatewayCluster, HashRing, SessionSnapshot
    from repro.cluster import FailureInjector, StragglerMonitor
    from repro.cluster import FrameJournal, ReplicationLog
    from repro.cluster import HeartbeatMonitor, MemberHungError
    from repro.cluster import RetryPolicy, TransientFault
"""
from repro.api.types import (ClusterDegradedError, ClusterDrainTimeout,
                             ClusterStats, ServerSessionSnapshot,
                             SessionSnapshot)
from repro.cluster.cluster import GatewayCluster
from repro.cluster.hashing import HashRing
from repro.cluster.health import HeartbeatMonitor, MemberHungError
from repro.cluster.replication import (FrameJournal, JournalEntry,
                                       ReplicationLog)
from repro.runtime.fault import (FailureInjector, RetryPolicy,
                                 StragglerEvent, StragglerMonitor,
                                 TransientFault)

__all__ = [
    "ClusterDegradedError",
    "ClusterDrainTimeout",
    "ClusterStats",
    "FailureInjector",
    "FrameJournal",
    "GatewayCluster",
    "HashRing",
    "HeartbeatMonitor",
    "JournalEntry",
    "MemberHungError",
    "ReplicationLog",
    "RetryPolicy",
    "ServerSessionSnapshot",
    "SessionSnapshot",
    "StragglerEvent",
    "StragglerMonitor",
    "TransientFault",
]
