"""Deterministic consistent-hash ring — who owns a session.

The federation layer (``cluster/cluster.py``) places every session on a
member gateway by hashing its cluster-wide session id onto a ring of
virtual nodes.  Everything here is a pure function of ``(members,
weights, seed)`` — keyed blake2b, no wall clock, no ``random`` — so a
test (or a second cluster replica) rebuilding the ring from the same
membership reproduces every placement decision bit-for-bit.

Why consistent hashing and not round-robin: on membership change only
the keys whose arc moved change owner — ``add`` steals arcs for the new
member and touches nobody else, ``remove`` hands the departed member's
arcs to its ring successors.  The cluster exploits exactly that:
rebalance migrates *only* sessions whose ``owner`` changed.

``set_weight`` scales a member's virtual-node count — the straggler
signal (``runtime/fault.StragglerMonitor``) biases placement away from
a slow member by shrinking its share of the hash space without evicting
what it already serves.
"""
from __future__ import annotations

import bisect
import hashlib

_SPACE = 1 << 64          # hash points are 64-bit (blake2b digest_size=8)


class HashRing:
    """Weighted consistent-hash ring over opaque member names.

    ``vnodes`` virtual nodes per unit weight smooth the arc
    distribution (at 64 the max/min owned-share ratio over a few
    members stays within ~2x); ``seed`` keys the hash so distinct
    clusters disagree about placement while one cluster is perfectly
    reproducible.
    """

    def __init__(self, members=(), *, seed: int = 0, vnodes: int = 64):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.seed = int(seed)
        self.vnodes = vnodes
        self._weights: dict = {}
        self._points: list[int] = []       # sorted vnode hash points
        self._owners: list = []            # member at each point
        for m in members:
            self.add(m)

    def _hash(self, key: str) -> int:
        h = hashlib.blake2b(key.encode("utf-8"), digest_size=8,
                            key=self.seed.to_bytes(8, "big", signed=True))
        return int.from_bytes(h.digest(), "big")

    # -- membership ----------------------------------------------------------
    @property
    def members(self) -> list:
        return sorted(self._weights)

    def has(self, member) -> bool:
        return member in self._weights

    def weight(self, member) -> float:
        return self._weights[member]

    def add(self, member, weight: float = 1.0) -> None:
        if member in self._weights:
            raise ValueError(f"member {member!r} already on the ring")
        if weight <= 0:
            raise ValueError("weight must be > 0")
        self._weights[member] = float(weight)
        self._rebuild()

    def remove(self, member) -> None:
        if member not in self._weights:
            raise KeyError(f"member {member!r} not on the ring")
        del self._weights[member]
        self._rebuild()

    def set_weight(self, member, weight: float) -> None:
        """Rescale a member's share of the hash space (its vnode count)
        — the straggler-bias hook.  Only arcs that change hands move."""
        if member not in self._weights:
            raise KeyError(f"member {member!r} not on the ring")
        if weight <= 0:
            raise ValueError("weight must be > 0")
        self._weights[member] = float(weight)
        self._rebuild()

    def _rebuild(self) -> None:
        pts = []
        for m in sorted(self._weights):
            n = max(1, round(self.vnodes * self._weights[m]))
            pts.extend((self._hash(f"{m}#{i}"), m) for i in range(n))
        pts.sort()
        self._points = [p for p, _ in pts]
        self._owners = [m for _, m in pts]

    # -- placement -----------------------------------------------------------
    def owner(self, key):
        """The member owning ``key``'s arc: its hash point's clockwise
        successor vnode.  Raises ``KeyError`` on an empty ring."""
        if not self._points:
            raise KeyError("empty ring")
        i = bisect.bisect_right(self._points, self._hash(str(key)))
        return self._owners[i % len(self._owners)]

    def preference(self, key) -> list:
        """Distinct members in ring-walk order from ``key``'s point —
        the failover order: placement tries ``preference(key)[0]``
        first and walks on when a member refuses admission or is gone.
        Empty ring -> empty list."""
        if not self._points:
            return []
        i = bisect.bisect_right(self._points, self._hash(str(key)))
        n = len(self._owners)
        seen, out = set(), []
        for j in range(n):
            m = self._owners[(i + j) % n]
            if m not in seen:
                seen.add(m)
                out.append(m)
        return out

    def buddy(self, key, exclude=()):
        """The first member on ``key``'s ring walk not in ``exclude``
        — the deterministic replication-buddy choice
        (``cluster/replication.py``): with ``exclude=(owner,)`` this is
        the next LIVE node past the owner, which is also exactly where
        the owner's keys would land if it died, so the journal is
        already on the member most likely to inherit the session.
        ``None`` when no such member exists (single-member ring)."""
        for m in self.preference(key):
            if m not in exclude:
                return m
        return None

    def share(self) -> dict:
        """``member -> owned fraction of the hash space`` (sums to 1.0)
        — ``ClusterStats.ring_share``, and the observable the straggler
        bias moves."""
        if not self._points:
            return {}
        out = {m: 0.0 for m in self._weights}
        pts, owners = self._points, self._owners
        for i, p in enumerate(pts):
            prev = pts[i - 1] if i else pts[-1] - _SPACE
            out[owners[i]] += (p - prev) / _SPACE
        return out
