"""Frame journaling + buddy replication — bounded-loss failover.

PR 7's failure recovery restores a dead member's sessions from the last
*periodic* checkpoint, so everything submitted since ``snapshot_every``
is counted into ``lost_in_flight``.  This module shrinks that bound to
"frames admitted but not yet journal-acked": every frame a member
accepts is appended to a per-session write-ahead ``FrameJournal`` that
lives on a deterministic BUDDY member (the next live node past the
owner on the ``HashRing`` walk), and recovery becomes

    import the last checkpoint  +  replay the journal's open entries

through the existing ``import_session`` seam — the replayed frames
re-enter the new owner's queues with their ORIGINAL arrival times and
deadlines, exactly like a migration implant.

The journal's lifecycle mirrors a real replicated log, in-process:

- ``record`` appends a PENDING entry at submit time (the owner accepted
  the frame; the append has not reached the buddy yet);
- ``flush`` ships pending entries to the buddy — from then on they are
  ACKED (durable: they survive the owner's death).  The cluster
  flushes every ``journal_flush_every`` steps, so the replication lag —
  and with it the loss bound — is at most one flush window;
- ``settle`` marks an entry whose frame was served or visibly shed (it
  left the system through the normal books; replaying it would
  double-serve);
- ``checkpointed`` truncates entries that are both acked and settled:
  a fresh checkpoint reflects every served frame, so only the OPEN
  entries (accepted, not yet served/shed) still matter for replay;
- ``replayable`` returns exactly those open acked entries, oldest
  first — the frames a failover re-queues on the new owner.

At the owner's death, PENDING entries die with it (the append never
reached the buddy) — the cluster counts them into ``lost_in_flight``.
At the BUDDY's death the journal's data dies instead: the log is
cleared and re-homed, and the session is exposed until its next
checkpoint — honest, like a real single-replica log.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.api.types import FrameRequest, QueuedFrameSnapshot

__all__ = ["FrameJournal", "JournalEntry", "ReplicationLog",
           "entry_nbytes"]

# per-entry transport overhead estimate on top of the mel payload
# (frame metadata + timestamps); the metric is a meter, not a codec
_ENTRY_OVERHEAD_B = 64


def entry_nbytes(entry: "JournalEntry") -> int:
    """Shipped size of one entry (``ClusterStats.journal_bytes``)."""
    return int(entry.frame.mel.nbytes) + _ENTRY_OVERHEAD_B


@dataclass
class JournalEntry:
    """One write-ahead record: the frame plus the admission ledger it
    needs to re-enter a queue unchanged (original arrival time and
    deadline — replay must not grant a fresh deadline budget)."""

    t: int
    frame: FrameRequest
    enq_s: float
    deadline_s: float
    weight: float = 1.0
    acked: bool = False        # shipped to the buddy (survives the owner)
    settled: bool = False      # served or shed — never replayed

    def snapshot(self) -> QueuedFrameSnapshot:
        """The implant form ``import_session`` consumes."""
        return QueuedFrameSnapshot(frame=self.frame, enq_s=self.enq_s,
                                   deadline_s=self.deadline_s,
                                   weight=self.weight)


class FrameJournal:
    """Per-session write-ahead journal, homed on a buddy member."""

    def __init__(self, gsid, buddy):
        self.gsid = gsid
        self.buddy = buddy         # member name holding the data (or None)
        self.entries: list[JournalEntry] = []

    def append(self, entry: JournalEntry) -> None:
        self.entries.append(entry)

    def flush(self) -> int:
        """Ack every pending entry (the ship to the buddy); returns the
        bytes that crossed the transport.  A journal without a buddy
        has nowhere to ship — entries stay pending (and are therefore
        lost with the owner, counted)."""
        if self.buddy is None:
            return 0
        shipped = 0
        for e in self.entries:
            if not e.acked:
                e.acked = True
                shipped += entry_nbytes(e)
        return shipped

    def settle(self, t) -> bool:
        """Mark the oldest open entry for frame ``t`` served/shed."""
        for e in self.entries:
            if not e.settled and e.t == t:
                e.settled = True
                return True
        return False

    def truncate_settled(self) -> int:
        """Drop entries that are acked AND settled — called right after
        a checkpoint, which is the durable record of those frames."""
        before = len(self.entries)
        self.entries = [e for e in self.entries
                        if not (e.acked and e.settled)]
        return before - len(self.entries)

    def replayable(self) -> list[JournalEntry]:
        """Open acked entries, append order (== enqueue order) — what a
        failover re-queues on the new owner."""
        return [e for e in self.entries if e.acked and not e.settled]

    def pending(self) -> list[JournalEntry]:
        """Entries not yet shipped — the loss bound at owner death."""
        return [e for e in self.entries if not e.acked]

    @property
    def nbytes(self) -> int:
        """Current journal payload size (what a re-home re-ships)."""
        return sum(entry_nbytes(e) for e in self.entries)


class ReplicationLog:
    """All sessions' journals plus the transport accounting.

    Owned by ``GatewayCluster`` and mutated only under the cluster
    lock; every byte that crosses the (in-process) owner→buddy seam is
    metered into ``bytes_shipped`` → ``ClusterStats.journal_bytes``
    (a ``cluster_journal_bytes`` counter when a ``MetricsRegistry`` is
    attached, so the exporters see it too).
    """

    def __init__(self, registry=None):
        self._journals: dict = {}      # gsid -> FrameJournal
        if registry is not None:
            self._bytes = registry.counter("cluster_journal_bytes")
        else:
            from repro.obs import Counter
            self._bytes = Counter("cluster_journal_bytes", ())
        self.resets = 0                # journals cleared by buddy death

    @property
    def bytes_shipped(self) -> int:
        return self._bytes.value

    def open(self, gsid, buddy) -> FrameJournal:
        j = FrameJournal(gsid, buddy)
        self._journals[gsid] = j
        return j

    def close(self, gsid) -> None:
        self._journals.pop(gsid, None)

    def journal(self, gsid) -> FrameJournal | None:
        return self._journals.get(gsid)

    def record(self, gsid, *, t, frame, enq_s, deadline_s,
               weight=1.0) -> None:
        j = self._journals.get(gsid)
        if j is not None:
            j.append(JournalEntry(t=t, frame=frame, enq_s=enq_s,
                                  deadline_s=deadline_s, weight=weight))

    def flush_all(self) -> int:
        shipped = sum(j.flush() for j in self._journals.values())
        self._bytes.inc(shipped)
        return shipped

    def settle(self, gsid, t) -> None:
        j = self._journals.get(gsid)
        if j is not None:
            j.settle(t)

    def checkpointed(self, gsid) -> None:
        j = self._journals.get(gsid)
        if j is not None:
            j.truncate_settled()

    def rehome(self, gsid, buddy) -> None:
        """Move the journal to a new buddy — the old one still holds
        the data (it is alive: a drain, or the owner moved onto the
        buddy), so the entries survive and re-ship, metered."""
        j = self._journals.get(gsid)
        if j is None or j.buddy == buddy:
            return
        j.buddy = buddy
        if buddy is not None:
            self._bytes.inc(sum(entry_nbytes(e) for e in j.entries
                                if e.acked))

    def drop_member(self, name) -> list:
        """The member died: journals HOMED on it lose their ACKED data
        (those entries lived there) — pending entries survive, they
        never left the owner's side of the transport.  The journal is
        left buddy-less until the cluster re-homes it.  Returns the
        affected gsids; their sessions are exposed (checkpoint-only
        recovery for the cleared span) until their next checkpoint."""
        hit = []
        for gsid, j in self._journals.items():
            if j.buddy == name:
                j.entries = [e for e in j.entries if not e.acked]
                j.buddy = None
                self.resets += 1
                hit.append(gsid)
        return hit

    def pending_total(self) -> int:
        return sum(len(j.pending()) for j in self._journals.values())
