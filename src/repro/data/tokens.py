"""Synthetic token pipeline for LM training/smoke: seeded, shardable,
deterministic per (step, shard)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


class TokenStream:
    """Markov-chain token generator — nontrivially learnable structure."""

    def __init__(self, vocab, *, seed=0, order_states=64):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.n_states = order_states
        self.trans = rng.dirichlet(0.3 * np.ones(order_states),
                                   size=order_states)
        self.emit = rng.dirichlet(0.1 * np.ones(vocab), size=order_states)
        self.seed = seed

    def batch(self, batch, seq, *, step=0):
        rng = np.random.default_rng((self.seed, step))
        out = np.zeros((batch, seq + 1), np.int32)
        state = rng.integers(0, self.n_states, batch)
        for t in range(seq + 1):
            for b in range(batch):
                out[b, t] = rng.choice(self.vocab, p=self.emit[state[b]])
                state[b] = rng.choice(self.n_states, p=self.trans[state[b]])
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}


def random_batch(key, vocab, batch, seq):
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab, jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
