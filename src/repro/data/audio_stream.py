"""Synthetic continuous-audio stream generator + mel frontend.

Offline stand-in for AudioSet / EcoStream-Wild with the *structural*
properties the paper relies on (DESIGN.md §5):

- temporally coherent sources (sounds don't teleport — Affinity);
- regime mix 60.2 % background / 24.5 % speech / 15.3 % transients
  (EcoStream-Wild class distribution, §6.1.1);
- class-conditional spectral signatures so linear probes are learnable.

Waveforms are sums of class-specific harmonic stacks + filtered noise;
``mel_frontend`` gives the 128-bin log-mel features (25 ms / 10 ms hop)
that the paper computes with PyKissFFT.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

SR = 16_000
N_MELS = 128
WIN = 400     # 25 ms
HOP = 160     # 10 ms


@dataclass(frozen=True)
class StreamCfg:
    n_classes: int = 15
    p_background: float = 0.602
    p_speech: float = 0.245
    p_transient: float = 0.153
    seg_seconds: tuple = (2.0, 8.0)   # source persistence
    seed: int = 0


def _mel_filterbank(n_fft=512, n_mels=N_MELS, sr=SR):
    # HTK-style mel filterbank
    def hz_to_mel(f):
        return 2595.0 * np.log10(1.0 + f / 700.0)

    def mel_to_hz(m):
        return 700.0 * (10.0 ** (m / 2595.0) - 1.0)

    fmax = sr / 2
    mels = np.linspace(hz_to_mel(0), hz_to_mel(fmax), n_mels + 2)
    freqs = mel_to_hz(mels)
    bins = np.floor((n_fft + 1) * freqs / sr).astype(int)
    fb = np.zeros((n_mels, n_fft // 2 + 1))
    for i in range(n_mels):
        lo, c, hi = bins[i], bins[i + 1], bins[i + 2]
        if c > lo:
            fb[i, lo:c] = (np.arange(lo, c) - lo) / (c - lo)
        if hi > c:
            fb[i, c:hi] = (hi - np.arange(c, hi)) / (hi - c)
    return fb


_FB = None


def mel_frontend(wave):
    """wave: (T,) float -> (frames, N_MELS) log-mel."""
    global _FB
    if _FB is None:
        _FB = _mel_filterbank()
    n = (len(wave) - WIN) // HOP + 1
    idx = np.arange(WIN)[None] + HOP * np.arange(n)[:, None]
    frames = wave[idx] * np.hanning(WIN)[None]
    spec = np.abs(np.fft.rfft(frames, n=512, axis=-1)) ** 2
    mel = spec @ _FB.T
    return np.log1p(mel).astype(np.float32)


class AudioStream:
    """Infinite stream of 1-s samples (paper's Sample unit) with labels.

    Classes: 0..4 background (hums/noise), 5..9 speech-like (formant
    sweeps), 10..14 transients (clicks/chirps)."""

    def __init__(self, cfg: StreamCfg = StreamCfg()):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._new_segment()
        # per-class harmonic signatures
        r = np.random.default_rng(1234)
        self.f0 = r.uniform(60, 2000, cfg.n_classes)
        self.harm = r.uniform(0.2, 1.0, (cfg.n_classes, 6))

    def _class_group(self):
        r = self.rng.random()
        c = self.cfg
        if r < c.p_transient:
            return "transient"
        if r < c.p_transient + c.p_speech:
            return "speech"
        return "background"

    def _new_segment(self):
        self.group = getattr(self, "_forced_group", None) or self._class_group()
        base = {"background": 0, "speech": 5, "transient": 10}[self.group]
        self.label = base + int(self.rng.integers(0, 5))
        lo, hi = self.cfg.seg_seconds
        self.seg_left = float(self.rng.uniform(lo, hi))
        self.phase = self.rng.uniform(0, 2 * np.pi)

    def next_sample(self):
        """-> (wave (16000,), label, group) for one second."""
        t = np.arange(SR) / SR
        c = self.label
        f0 = self.f0[c]
        wave = np.zeros(SR)
        if self.group == "background":
            for h, a in enumerate(self.harm[c]):
                wave += a * 0.2 * np.sin(2 * np.pi * f0 * (h + 1) * t + self.phase)
            wave += 0.05 * self.rng.standard_normal(SR)
        elif self.group == "speech":
            sweep = f0 * (1 + 0.3 * np.sin(2 * np.pi * 3.0 * t))
            ph = 2 * np.pi * np.cumsum(sweep) / SR
            for h, a in enumerate(self.harm[c]):
                wave += a * 0.25 * np.sin((h + 1) * ph)
            wave *= (0.4 + 0.6 * np.abs(np.sin(2 * np.pi * 4 * t)))  # syllables
        else:  # transient
            n_events = self.rng.integers(1, 4)
            for _ in range(n_events):
                at = self.rng.integers(0, SR - 800)
                dur = self.rng.integers(200, 800)
                chirp = np.sin(2 * np.pi * f0 * np.linspace(0, 3, dur) ** 2)
                wave[at:at + dur] += chirp * np.hanning(dur) * 1.5
            wave += 0.05 * self.rng.standard_normal(SR)
        self.phase += 2 * np.pi * f0
        self.seg_left -= 1.0
        label, group = self.label, self.group
        if self.seg_left <= 0:
            self._new_segment()
        return wave.astype(np.float32), label, group

    def next_mel(self):
        wave, label, group = self.next_sample()
        return mel_frontend(wave), label, group

    def batch(self, n, *, mel=True):
        xs, ys, gs = [], [], []
        for _ in range(n):
            if mel:
                x, y, g = self.next_mel()
            else:
                x, y, g = self.next_sample()
            xs.append(x)
            ys.append(y)
            gs.append(g)
        return np.stack(xs), np.array(ys), gs


def augment_pair(rng, mel):
    """The paper's lightweight augmentations: Gaussian noise + freq mask."""
    def one(m):
        m = m + 0.05 * rng.standard_normal(m.shape).astype(np.float32)
        f0 = rng.integers(0, m.shape[1] - 16)
        m = m.copy()
        m[:, f0:f0 + rng.integers(4, 16)] = 0.0
        return m
    return one(mel), one(mel)
