"""Three-term roofline model for TPU v5e (target hardware).

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` on the partitioned module reports per-chip
flops/bytes; collective bytes come from launch/hlo_analysis.py.
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) measures how much of the
compiled compute is "useful" (catches remat/redundancy waste).
"""
from __future__ import annotations

from dataclasses import dataclass

# v5e per chip
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # B/s
ICI_BW = 50e9             # B/s per link (assignment constant)


@dataclass
class Roofline:
    flops: float               # per-chip HLO flops
    hbm_bytes: float           # per-chip bytes accessed
    coll_bytes: float          # per-chip collective bytes
    model_flops: float         # global useful flops (6ND)
    n_chips: int

    @property
    def compute_s(self):
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self):
        """Optimistic (perfect-overlap) step time = max of the terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self):
        """MODEL_FLOPS / (global HLO flops)."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_upper_bound(self):
        """Model-flop utilization implied by the roofline step time."""
        denom = self.step_s * PEAK_FLOPS * self.n_chips
        return self.model_flops / denom if denom else 0.0

    def as_dict(self):
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "model_flops": self.model_flops,
            "n_chips": self.n_chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "useful_flop_fraction": self.useful_flop_fraction,
            "mfu_upper_bound": self.mfu_upper_bound,
        }


def count_params(shapes_tree):
    import jax
    return sum(int(x.size) for x in jax.tree.leaves(shapes_tree))


def active_params(cfg, params_shapes):
    """Active params per token: MoE expert weights count at top_k/E.

    Expert weights are identified by their experts dim (== cfg.moe.n_experts
    in dims 1-2 of the layer-stacked (L, E, ...) tensors)."""
    import jax
    leaves = jax.tree.leaves(params_shapes)
    total = sum(int(x.size) for x in leaves)
    if cfg.moe is None:
        return total
    E = cfg.moe.n_experts
    expert_sz = sum(int(x.size) for x in leaves
                    if len(x.shape) >= 3 and E in x.shape[:2])
    return (total - expert_sz) + expert_sz * cfg.moe.top_k / E


def model_flops(cfg, params_shapes, shape_cfg):
    """6·N(_active)·D for a train step; 2·N_active per token for decode."""
    n_act = active_params(cfg, params_shapes)
    tokens = shape_cfg.global_batch * shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * n_act * tokens
    if shape_cfg.kind == "prefill":
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape_cfg.global_batch
