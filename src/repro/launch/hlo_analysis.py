"""Trip-count-aware post-SPMD HLO cost model.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop (lax.scan)
bodies ONCE — useless for layer-scanned models (verified: a 2-layer and an
8-layer qwen stack report identical FLOPs).  This module parses the
partitioned HLO text (``compiled.as_text()``, per-device shapes) and:

1. builds the computation call graph (fusion ``calls=``, while
   ``condition=/body=``, ``to_apply=``, conditional branches),
2. extracts while trip counts from ``backend_config known_trip_count``
   (fallback: the largest constant in the loop condition),
3. propagates *multiplicities* from ENTRY so an op inside a layer scan
   inside a microbatch scan counts layers x microbatches times,
4. accounts per device:
     - FLOPs: dot ops (2·result·K, K from the operand symbol table +
       ``lhs_contracting_dims``) and convolutions,
     - HBM bytes: operand+result bytes of top-level (non-fusion-body)
       instructions — post-fusion buffer traffic,
     - collective bytes: result-shape bytes of all-reduce / all-gather /
       reduce-scatter / all-to-all / collective-permute (+ async -start
       forms; -done skipped).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*{\s*$")
_HDR_PARAM = re.compile(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|[a-z0-9]+\[[\d,]*\])")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z0-9]+"
    r"\[[\d,]*\](?:{[^}]*})?))\s*([\w\-]+)\((.*)$")
_CALL_ATTR = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations={([^}]*)}")
_TRIP = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims={([\d,]*)}")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")

# opcodes whose buffers are aliases/control — no HBM traffic of their own
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
}

# opcodes that MUST touch HBM on the target TPU (matmuls, reductions,
# data movement, collectives, fused groups).  Everything else at the HLO
# top level is elementwise/shape glue that the TPU compiler fuses into its
# consumers — the CPU backend leaves it unfused, and counting it would
# overstate HBM traffic by ~2 orders of magnitude (EXPERIMENTS.md §Method).
_HBM_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "sort",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice", "copy",
    "concatenate", "pad", "slice", "transpose", "select-and-scatter",
    "rng", "rng-bit-generator", "cholesky", "triangular-solve", "fft",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        total += _shape_elems(m.group(2)) * DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    rest: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)   # name -> type str


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(name=m.group(2),
                                  is_entry=bool(m.group(1)))
                for pm in _HDR_PARAM.finditer(m.group(3)):
                    cur.symtab[pm.group(1)] = pm.group(2)
                comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.symtab[ins.name] = ins.result_type
            cur.instrs.append(ins)
    return comps


def _while_parts(ins: Instr):
    mcond = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
    mbody = re.search(r"body=%?([\w\.\-]+)", ins.rest)
    mtrip = _TRIP.search(ins.rest)
    return (mcond.group(1) if mcond else None,
            mbody.group(1) if mbody else None,
            int(mtrip.group(1)) if mtrip else None)


def _cond_trip_fallback(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m2 = re.match(r"(\d+)\)", ins.rest)
            if m2:
                best = max(best, int(m2.group(1)))
    return best


def compute_multiplicities(comps: dict):
    """-> ({comp: multiplicity}, {comp: fusion_body_flag})."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    callers = defaultdict(list)  # callee -> [(caller, factor, via_fusion)]
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "while":
                cond, body, trip = _while_parts(ins)
                if trip is None and cond in comps:
                    trip = _cond_trip_fallback(comps[cond])
                trip = trip or 1
                if body in comps:
                    callers[body].append((c.name, float(trip), False))
                if cond in comps:
                    callers[cond].append((c.name, float(trip + 1), False))
            elif ins.opcode == "conditional":
                mb = _BRANCHES.search(ins.rest)
                if mb:
                    for b in mb.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in comps:
                            callers[b].append((c.name, 1.0, False))
            else:
                via_fusion = ins.opcode == "fusion"
                for callee in _CALL_ATTR.findall(ins.rest):
                    if callee in comps:
                        callers[callee].append((c.name, 1.0, via_fusion))

    mult = defaultdict(float)
    mult[entry.name] = 1.0
    for _ in range(len(comps) + 2):
        changed = False
        for callee, lst in callers.items():
            m = sum(mult.get(cal, 0.0) * f for cal, f, _ in lst)
            if m > 0 and abs(mult.get(callee, 0.0) - m) > 1e-9:
                mult[callee] = m
                changed = True
        if not changed:
            break

    fusion_body = {}
    for name in comps:
        lst = callers.get(name, [])
        fusion_body[name] = bool(lst) and all(via for _, _, via in lst)
    fusion_body[entry.name] = False
    return mult, fusion_body


def _operands(ins: Instr, comp: Computation, *, limit=None):
    """Resolve operand types via the computation symbol table."""
    # cut attrs off: operands live before the first "), " ... attrs follow.
    text = ins.rest
    out = []
    for m in _OPERAND_NAME.finditer(text):
        t = comp.symtab.get(m.group(1))
        if t is not None:
            out.append(t)
            if limit and len(out) >= limit:
                break
    return out


def _dot_flops(ins: Instr, comp: Computation) -> float:
    m = _SHAPE_RE.search(ins.result_type)
    if not m or m.group(1) not in DTYPE_BYTES:
        return 0.0
    res_elems = _shape_elems(m.group(2))
    ops = _operands(ins, comp, limit=1)
    mc = _LHS_CONTRACT.search(ins.rest)
    if not ops or not mc:
        return 0.0
    lhs = _SHAPE_RE.search(ops[0])
    if not lhs:
        return 0.0
    dims = [int(d) for d in lhs.group(2).split(",") if d]
    k = 1
    for i in (int(x) for x in mc.group(1).split(",") if x):
        if i < len(dims):
            k *= dims[i]
    return 2.0 * res_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    m = _SHAPE_RE.search(ins.result_type)
    if not m or m.group(1) not in DTYPE_BYTES:
        return 0.0
    res_elems = _shape_elems(m.group(2))
    ops = _operands(ins, comp, limit=2)
    if len(ops) < 2:
        return 0.0
    kern = _SHAPE_RE.search(ops[1])
    if not kern:
        return 0.0
    kdims = [int(d) for d in kern.group(2).split(",") if d]
    if not kdims:
        return 0.0
    out_ch = kdims[-1]
    return 2.0 * res_elems * (math.prod(kdims) / max(out_ch, 1))


def _operand_bytes_list(ins: Instr, comp: Computation):
    operand_text = ins.rest.split("), ")[0]
    out = []
    for m in _OPERAND_NAME.finditer(operand_text):
        t = comp.symtab.get(m.group(1))
        if t is not None:
            out.append(shape_bytes(t))
    return out


# loop-carry copies above this size are buffer-aliasing artifacts of the
# CPU backend (TPU donates/aliases scan carries); skip them.
_CARRY_COPY_CUTOFF = 256 * 2 ** 20


def _instr_traffic_bytes(ins: Instr, comp: Computation) -> int:
    if ins.opcode in _NO_TRAFFIC or ins.opcode not in _HBM_OPS:
        return 0
    ops = _operand_bytes_list(ins, comp)
    res = shape_bytes(ins.result_type)
    if ins.opcode == "fusion":
        if "dynamic-update-slice" in ins.name:
            # aliased in-place update: traffic = read+write of the update
            # window, not the whole carried buffer
            if len(ops) > 1:
                return 2 * (sum(ops) - max(ops))
            return 0
        if "copy" in ins.name and res > _CARRY_COPY_CUTOFF:
            return 0
        return res + sum(ops)
    if ins.opcode == "copy" and res > _CARRY_COPY_CUTOFF:
        return 0
    if ins.opcode in ("dynamic-update-slice",):
        # in-place: read+write only the updated window (operand 1), not the
        # aliased buffer — the KV-cache decode path would otherwise count
        # the whole cache per layer.
        upd = ops[1] if len(ops) > 1 else 0
        return 2 * upd
    if ins.opcode == "scatter":
        upd = ops[2] if len(ops) > 2 else (ops[-1] if ops else 0)
        idx = ops[1] if len(ops) > 1 else 0
        return 3 * upd + idx
    if ins.opcode == "dynamic-slice":
        return 2 * res
    return res + sum(ops)


def analyze(hlo: str):
    """Full per-device analysis -> dict."""
    comps = parse_computations(hlo)
    mult, fusion_body = compute_multiplicities(comps)
    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    counts = defaultdict(int)
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ins in c.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, c)
            elif ins.opcode == "convolution":
                flops += m * _conv_flops(ins, c)
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVES and not ins.opcode.endswith("-done"):
                coll[base] += m * shape_bytes(ins.result_type)
                counts[base] += 1
            if not fusion_body.get(name, False):
                hbm += m * _instr_traffic_bytes(ins, c)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": sum(coll.values()),
        "per_kind_bytes": dict(coll),
        "per_kind_counts": dict(counts),
        "n_computations": len(comps),
    }


def collective_bytes(hlo_text: str):
    r = analyze(hlo_text)
    return (r["collective_bytes"], r["per_kind_bytes"],
            r["per_kind_counts"])


def summarize(hlo_text: str):
    r = analyze(hlo_text)
    return {
        "collective_bytes": r["collective_bytes"],
        "per_kind_bytes": r["per_kind_bytes"],
        "per_kind_counts": r["per_kind_counts"],
        "hlo_flops": r["flops"],
        "hlo_hbm_bytes": r["hbm_bytes"],
    }
