"""Distributed training launcher.

On real hardware:  python -m repro.launch.train --arch qwen3-1.7b \
    --shape train_4k [--multi-pod] --steps 1000
On this CPU container it runs reduced configs end-to-end (use --smoke) —
the full configs are exercised compile-only via launch/dryrun.py.

Includes the production XLA flag set for collective/compute overlap
(latency-hiding scheduler, async collectives) — applied on TPU backends.
"""
from __future__ import annotations

import argparse
import os
from dataclasses import replace

import jax
import jax.numpy as jnp

TPU_PERF_FLAGS = " ".join([
    # overlap compute with collectives (latency hiding scheduler)
    "--xla_tpu_enable_latency_hiding_scheduler=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_tpu_spmd_threshold_for_allgather_cse=10000",
])


def maybe_set_tpu_flags():
    if any(d.platform == "tpu" for d in jax.devices()):
        os.environ["LIBTPU_INIT_ARGS"] = (
            os.environ.get("LIBTPU_INIT_ARGS", "") + " " + TPU_PERF_FLAGS)


def main():
    from repro.configs.base import SHAPES, get_config, smoke_config
    from repro.data.tokens import random_batch
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.runtime.trainer import TrainCfg, Trainer

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--hybrid", action="store_true",
                    help="enable the StreamSplit hybrid aux loss")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    args = ap.parse_args()

    maybe_set_tpu_flags()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        batch, seq = args.batch, args.seq
    else:
        shape = SHAPES[args.shape]
        batch, seq = shape.global_batch, shape.seq_len

    tcfg = TrainCfg(optimizer=args.optimizer, lr=args.lr,
                    total_steps=args.steps, warmup=max(args.steps // 20, 5),
                    microbatches=args.microbatches, hybrid=args.hybrid,
                    hybrid_pool=max(seq // 16, 8))

    def data_fn(step):
        return random_batch(jax.random.PRNGKey(step), cfg.vocab, batch, seq)

    n_dev = len(jax.devices())
    if n_dev > 1 and not args.smoke:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = shd.rules_for(mesh, cfg, batch=batch, kind="train")
        ctx = shd.axis_rules(rules)
    else:
        import contextlib
        ctx = contextlib.nullcontext()

    with ctx:
        trainer = Trainer(cfg, tcfg, data_fn, ckpt_dir=args.ckpt_dir)
        hist = trainer.run(args.steps, log_every=10)
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
