"""Production meshes + the ``jax.distributed`` multi-host on-ramp.

Functions, not module-level constants — importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 device while the dry-run sees 512)."""
from __future__ import annotations

import os

import jax

from repro.compat import make_mesh as _mk

# process-level latch: jax.distributed.initialize may run at most once
_distributed = {"initialized": False}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (subprocesses set
    --xla_force_host_platform_device_count accordingly)."""
    return _mk(shape, axes)


def make_sessions_mesh(n_shards=None, *, axis=None):
    """1-D fleet-serving mesh over the session axis.

    ``ShardedFleetBackend`` shards its (N, W, d) session rings over this
    axis; defaults to every visible device (1 on a plain test process,
    ``--xla_force_host_platform_device_count`` many in the forced-host
    multi-shard tests and benchmarks)."""
    from repro.distributed.sharding import SESSIONS_AXIS
    n = len(jax.devices()) if n_shards is None else n_shards
    return _mk((n,), (axis or SESSIONS_AXIS,))


def maybe_init_distributed(*, env=None, initialize=None) -> bool:
    """The multi-host on-ramp: initialize ``jax.distributed`` from the
    launcher environment, or no-op in a plain single-process run.

    Environment contract (presence of the coordinator turns this on)::

        REPRO_COORDINATOR    host:port of process 0's coordinator service
        REPRO_NUM_PROCESSES  total process count           (default 1)
        REPRO_PROCESS_ID     this process's index           (default 0)

    Call it before the first jax device query (first thing in a launcher
    ``main``): after ``jax.distributed.initialize``, ``jax.devices()``
    returns the GLOBAL device list, so ``make_sessions_mesh()`` with no
    argument spans the whole job and the sharded fleet/dispatch planes
    scale out with zero further configuration.  Returns True when the
    process joined (or had already joined) a distributed job, False for
    the single-process no-op.  Idempotent per process.

    ``env``/``initialize`` are injection seams for tests — real callers
    pass neither (``os.environ`` / ``jax.distributed.initialize``).
    """
    env = os.environ if env is None else env
    coordinator = env.get("REPRO_COORDINATOR")
    if not coordinator:
        return False
    if _distributed["initialized"]:
        return True
    n_proc = int(env.get("REPRO_NUM_PROCESSES", "1"))
    proc_id = int(env.get("REPRO_PROCESS_ID", "0"))
    if not 0 <= proc_id < n_proc:
        raise ValueError(
            f"REPRO_PROCESS_ID={proc_id} out of range for "
            f"REPRO_NUM_PROCESSES={n_proc}")
    init = jax.distributed.initialize if initialize is None else initialize
    init(coordinator_address=coordinator, num_processes=n_proc,
         process_id=proc_id)
    _distributed["initialized"] = True
    return True
