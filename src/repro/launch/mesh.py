"""Production meshes.

Functions, not module-level constants — importing this module never
touches jax device state (device count is locked at first jax init, and
smoke tests must see 1 device while the dry-run sees 512)."""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _mk


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (subprocesses set
    --xla_force_host_platform_device_count accordingly)."""
    return _mk(shape, axes)


def make_sessions_mesh(n_shards=None, *, axis=None):
    """1-D fleet-serving mesh over the session axis.

    ``ShardedFleetBackend`` shards its (N, W, d) session rings over this
    axis; defaults to every visible device (1 on a plain test process,
    ``--xla_force_host_platform_device_count`` many in the forced-host
    multi-shard tests and benchmarks)."""
    from repro.distributed.sharding import SESSIONS_AXIS
    n = len(jax.devices()) if n_shards is None else n_shards
    return _mk((n,), (axis or SESSIONS_AXIS,))
