# The multi-pod dry-run needs 512 placeholder devices; jax locks the device
# count at first init, so this MUST precede every other import.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove it fits (memory_analysis), and extract the
roofline terms (cost_analysis + collective-bytes from the partitioned HLO).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all                # every cell, 16x16
  python -m repro.launch.dryrun --all --multi-pod    # every cell, 2x16x16
"""
import argparse
import json
import time
import traceback
from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, cells, get_config, input_specs
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis, roofline
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import get_optimizer
from repro.runtime.trainer import TrainCfg, make_train_step

# per-arch large-scale policy: optimizer / FSDP / microbatching.
# microbatch counts were hillclimbed (EXPERIMENTS.md §Perf): FSDP weight
# regathers scale linearly with microbatch count, so fewer+larger
# microbatches win as long as the MoE all-to-all buffers stay in HBM
# (kimi: mb 8 -> 2 lifted the MFU bound 2.5% -> 5.8%).
POLICY = {
    "kimi-k2-1t-a32b": dict(optimizer="adafactor", fsdp=True, microbatches=2),
    "arctic-480b": dict(optimizer="adafactor", fsdp=True, microbatches=2),
    "llava-next-34b": dict(optimizer="adamw", fsdp=True, microbatches=2),
    "nemotron-4-15b": dict(optimizer="adamw", fsdp=True, microbatches=2),
}
DEFAULT_POLICY = dict(optimizer="adamw", fsdp=False, microbatches=1)


def policy_for(arch):
    return {**DEFAULT_POLICY, **POLICY.get(arch, {})}


def _opt_axes(optname, params_axes):
    is_ax = lambda x: isinstance(x, tuple)
    if optname == "adamw":
        return {"m": params_axes, "v": params_axes, "step": ()}
    if optname == "sgd":
        return (params_axes,)
    if optname == "adafactor":
        def leaf(a):
            if len(a) >= 2:
                return {"vr": a[:-1], "vc": a[:-2] + a[-1:]}
            return {"v": a}
        return {"stats": jax.tree.map(leaf, params_axes, is_leaf=is_ax),
                "step": ()}
    raise ValueError(optname)


def _shardings(axes_tree, rules, mesh):
    is_ax = lambda x: isinstance(x, tuple)
    return jax.tree.map(
        lambda a: NamedSharding(mesh, rules.spec(a, kind="param")),
        axes_tree, is_leaf=is_ax)


def _act_shardings(axes_tree, rules, mesh):
    is_ax = lambda x: isinstance(x, tuple)
    return jax.tree.map(
        lambda a: NamedSharding(mesh, rules.spec(a, kind="act")),
        axes_tree, is_leaf=is_ax)


def eval_params(cfg, key):
    box = {}

    def f(k):
        p, a = lm.init_lm(cfg, k)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, box["axes"]


def build_and_compile(arch, shape_name, mesh, *, dtype="bfloat16",
                      overrides=None, want_hlo=False):
    """Lower + compile one cell. Returns the result record."""
    cfg = get_config(arch)
    cfg = replace(cfg, dtype=dtype, param_dtype=dtype)
    if overrides:
        cfg = replace(cfg, **{k: v for k, v in overrides.items()
                              if hasattr(cfg, k)})
    shape = SHAPES[shape_name]
    pol = policy_for(arch)
    rules = shd.rules_for(mesh, cfg, batch=shape.global_batch,
                          kind=shape.kind, fsdp=pol["fsdp"])
    key = jax.random.PRNGKey(0)
    t0 = time.time()

    with shd.axis_rules(rules), mesh:
        params_shapes, params_axes = eval_params(cfg, key)
        p_shard = _shardings(params_axes, rules, mesh)
        params_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            params_shapes, p_shard)
        data = input_specs(cfg, shape, dtype=dtype)
        data_axes = {
            "tokens": ("batch", "seq"), "labels": ("batch", "seq"),
            "embeds": ("batch", "seq", "embed"),
        }
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=NamedSharding(
                    mesh, rules.spec(data_axes[k][: len(v.shape)],
                                     kind="act")))
            for k, v in data.items()}

        if shape.kind == "train":
            tcfg = TrainCfg(optimizer=pol["optimizer"],
                            microbatches=pol["microbatches"],
                            lr=1e-4, total_steps=10_000, warmup=100)
            opt_init, _ = get_optimizer(pol["optimizer"])
            opt_shapes = jax.eval_shape(opt_init, params_shapes)
            opt_axes = _opt_axes(pol["optimizer"], params_axes)
            o_shard = _shardings(opt_axes, rules, mesh)
            opt_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                opt_shapes, o_shard)
            step_fn = make_train_step(cfg, tcfg)
            rep = NamedSharding(mesh, P())
            step_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
            key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=rep)
            lowered = jax.jit(
                step_fn,
                out_shardings=(p_shard, o_shard, None),
            ).lower(params_sds, opt_sds, batch_sds, step_sds, key_sds)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return lm.prefill(cfg, params, **batch)
            lowered = jax.jit(prefill_fn).lower(params_sds, batch_sds)
        else:  # decode
            state_shapes = jax.eval_shape(
                partial(lm.init_decode_state, cfg, shape.global_batch,
                        shape.seq_len, dtype=dtype))
            state_axes = lm.decode_state_specs(cfg, shape.global_batch,
                                               shape.seq_len)
            s_shard = _act_shardings(state_axes, rules, mesh)
            state_sds = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                state_shapes, s_shard)

            def decode_fn(params, state, tokens):
                return lm.decode_step(cfg, params, state, tokens)
            lowered = jax.jit(
                decode_fn, out_shardings=(None, s_shard),
            ).lower(params_sds, state_sds, batch_sds["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # --- analyses --------------------------------------------------------
    n_chips = mesh.size
    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_rec[k] = int(v)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # NOTE: XLA's cost_analysis counts while(scan) bodies ONCE — recorded
    # for reference only.  The roofline uses the trip-count-aware HLO
    # parser (launch/hlo_analysis.py).
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = hlo_analysis.summarize(hlo)
    flops = coll["hlo_flops"]
    hbm_bytes = coll["hlo_hbm_bytes"]
    mflops = roofline.model_flops(cfg, params_shapes, shape)
    rl = roofline.Roofline(
        flops=flops, hbm_bytes=hbm_bytes,
        coll_bytes=float(coll["collective_bytes"]),
        model_flops=mflops, n_chips=n_chips)
    n_params = roofline.count_params(params_shapes)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "policy": pol,
        "n_params": n_params,
        "n_params_active": roofline.active_params(cfg, params_shapes),
        "param_bytes_per_chip": int(
            sum(x.size * x.dtype.itemsize for x in
                jax.tree.leaves(params_shapes)) / n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "cost": {"flops": flops, "bytes_accessed": hbm_bytes,
                 "xla_flops_looponce": xla_flops,
                 "xla_bytes_looponce": xla_bytes},
        "collectives": {k: v for k, v in coll.items()
                        if not k.startswith("hlo_")},
        "roofline": rl.as_dict(),
    }
    if want_hlo:
        rec["_hlo"] = hlo
    return rec


def run_cell(arch, shape_name, *, multi_pod, out_dir, want_hlo=False,
             overrides=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
    print(f"=== {tag} ===", flush=True)
    try:
        rec = build_and_compile(arch, shape_name, mesh, want_hlo=want_hlo,
                                overrides=overrides)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        print(f"  FAILED: {rec['error']}", flush=True)
    else:
        r = rec["roofline"]
        print(f"  params {rec['n_params']/1e9:.2f}B  "
              f"compile {rec['compile_s']:.1f}s  "
              f"compute {r['compute_s']*1e3:.2f}ms  "
              f"memory {r['memory_s']*1e3:.2f}ms  "
              f"collective {r['collective_s']*1e3:.2f}ms  "
              f"bottleneck={r['bottleneck']}  "
              f"MFU<= {r['mfu_upper_bound']*100:.1f}%", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        hlo = rec.pop("_hlo", None)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        if hlo is not None:
            with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo", action="store_true")
    args = ap.parse_args()

    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for arch, shape_name in todo:
            results.append(run_cell(arch, shape_name, multi_pod=mp,
                                    out_dir=args.out, want_hlo=args.hlo))
    n_fail = sum("error" in r for r in results)
    print(f"\n{len(results) - n_fail}/{len(results)} cells compiled OK")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
