"""Uncertainty-routed cascade serving — the paper's offloading policy as a
datacenter pattern (DESIGN.md §2): requests whose pooled-embedding GMM
entropy is low are answered by the small ("edge-class") model; high-
entropy (hard) requests escalate to the large ("server-class") model.

  python -m repro.launch.serve --demo     # runs the CPU-scale demo
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.core import gmm as gmm_mod
from repro.models import lm


@dataclass
class CascadeStats:
    served_small: int = 0
    served_large: int = 0
    route_ms: float = 0.0   # shared: embed forward + GMM update + routing
    small_ms: float = 0.0   # easy-tier answer materialization only
    large_ms: float = 0.0   # escalated sub-batch forward
    small_batches: int = 0
    large_batches: int = 0

    @property
    def escalation_rate(self):
        n = self.served_small + self.served_large
        return self.served_large / n if n else 0.0


def _bucket(n):
    """Next power of two — pads tier sub-batches to a handful of shapes so
    each tier compiles O(log B) executables instead of one per size."""
    b = 1
    while b < n:
        b <<= 1
    return b


class CascadeServer:
    """Two-tier server. ``threshold`` is normalized entropy in [0, 1]
    (paper: offload when U_t > 0.7 regardless of platform, §6.5.2)."""

    def __init__(self, small_cfg, small_params, large_cfg, large_params,
                 *, threshold="auto", auto_quantile=0.75, gmm_components=64,
                 seed=0):
        assert small_cfg.vocab == large_cfg.vocab, \
            "cascade tiers must share a vocab (one logits buffer)"
        self.small_cfg, self.small_params = small_cfg, small_params
        self.large_cfg, self.large_params = large_cfg, large_params
        self.threshold = threshold          # float, or "auto" (calibrated
        self.auto_quantile = auto_quantile  # to a quantile of the first
                                            # batch's entropies)
        key = jax.random.PRNGKey(seed)
        self.gmm = gmm_mod.init_gmm(key, gmm_components, small_cfg.d_model)
        self.stats = CascadeStats()

        def embed_and_small_logits(params, tokens):
            # ONE small forward serves double duty: pooled embedding for
            # the GMM uncertainty AND last-token logits, so easy requests
            # are already answered by the time routing happens.
            h, _ = lm.forward(small_cfg, params, tokens=tokens)
            z = h.mean(axis=1)
            z = z / jnp.maximum(
                jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)
            logits = lm.logits_from_hidden(small_cfg, params,
                                           h[:, -1:, :])[:, -1]
            return z, logits

        self._embed = jax.jit(embed_and_small_logits)

        def large_step(p, t):
            h, _ = lm.forward(large_cfg, p, tokens=t)
            return lm.logits_from_hidden(large_cfg, p, h[:, -1:, :])[:, -1]

        self._large_step = jax.jit(large_step)

    def _serve_large(self, tokens, idx, out):
        """Run the large tier ONCE on its padded sub-batch and scatter."""
        t0 = time.perf_counter()
        pad = _bucket(len(idx))
        sub = np.asarray(tokens)[idx]
        if pad > len(idx):  # repeat-pad: every shape bucket stays compiled
            sub = np.concatenate(
                [sub, np.broadcast_to(sub[:1], (pad - len(idx),)
                                      + sub.shape[1:])])
        logits = np.asarray(
            self._large_step(self.large_params, jnp.asarray(sub)))[:len(idx)]
        out[idx] = logits
        self.stats.served_large += len(idx)
        self.stats.large_ms += (time.perf_counter() - t0) * 1e3
        self.stats.large_batches += 1

    def handle(self, tokens, *, update_gmm=True):
        """tokens: (B, S).  Routes the batch; returns (logits, routed_to).

        Easy requests are answered by the small logits computed alongside
        the uncertainty embedding (zero extra forwards); hard requests are
        grouped into ONE padded large-tier sub-batch — never one forward
        per request.
        """
        t0 = time.perf_counter()
        z, small_logits = self._embed(self.small_params, tokens)
        u = gmm_mod.normalized_entropy(self.gmm, z)
        if update_gmm:
            self.gmm = gmm_mod.em_update(self.gmm, z)
        if self.threshold == "auto":
            self.threshold = float(jnp.quantile(u, self.auto_quantile))
        hard = np.asarray(u > self.threshold)   # host sync: routing is done
        self.stats.route_ms += (time.perf_counter() - t0) * 1e3
        out = np.zeros((len(hard), self.small_cfg.vocab), np.float32)
        easy_idx = np.where(~hard)[0]
        hard_idx = np.where(hard)[0]
        if easy_idx.size:
            t1 = time.perf_counter()
            out[easy_idx] = np.asarray(small_logits)[easy_idx]
            self.stats.served_small += easy_idx.size
            self.stats.small_ms += (time.perf_counter() - t1) * 1e3
            self.stats.small_batches += 1
        if hard_idx.size:
            self._serve_large(tokens, hard_idx, out)
        return out, hard


def demo(n_batches=8, batch=8, seq=64):
    small = smoke_config(get_config("qwen1.5-0.5b"))
    large = replace(smoke_config(get_config("qwen3-1.7b")),
                    vocab=small.vocab, d_model=small.d_model,
                    n_layers=4)
    key = jax.random.PRNGKey(0)
    sp, _ = lm.init_lm(small, key)
    lp, _ = lm.init_lm(large, key)
    srv = CascadeServer(small, sp, large, lp, threshold="auto")
    for i in range(n_batches):
        toks = jax.random.randint(jax.random.PRNGKey(i), (batch, seq), 0,
                                  small.vocab)
        srv.handle(toks)
    s = srv.stats
    print(f"served: small={s.served_small} large={s.served_large} "
          f"escalation={s.escalation_rate:.2f}")
    return s


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    args = ap.parse_args()
    demo()
