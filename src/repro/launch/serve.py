"""Uncertainty-routed cascade serving — the paper's offloading policy as a
datacenter pattern (DESIGN.md §2): requests whose pooled-embedding GMM
entropy is low are answered by the small ("edge-class") model; high-
entropy (hard) requests escalate to the large ("server-class") model.

  python -m repro.launch.serve --demo     # runs the CPU-scale demo
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.core import gmm as gmm_mod
from repro.models import lm


@dataclass
class CascadeStats:
    served_small: int = 0
    served_large: int = 0
    small_ms: float = 0.0
    large_ms: float = 0.0

    @property
    def escalation_rate(self):
        n = self.served_small + self.served_large
        return self.served_large / n if n else 0.0


class CascadeServer:
    """Two-tier server. ``threshold`` is normalized entropy in [0, 1]
    (paper: offload when U_t > 0.7 regardless of platform, §6.5.2)."""

    def __init__(self, small_cfg, small_params, large_cfg, large_params,
                 *, threshold="auto", auto_quantile=0.75, gmm_components=64,
                 seed=0):
        self.small_cfg, self.small_params = small_cfg, small_params
        self.large_cfg, self.large_params = large_cfg, large_params
        self.threshold = threshold          # float, or "auto" (calibrated
        self.auto_quantile = auto_quantile  # to a quantile of the first
                                            # batch's entropies)
        key = jax.random.PRNGKey(seed)
        self.gmm = gmm_mod.init_gmm(key, gmm_components, small_cfg.d_model)
        self.stats = CascadeStats()

        def embed_and_uncertainty(params, tokens):
            h, _ = lm.forward(small_cfg, params, tokens=tokens)
            z = h.mean(axis=1)
            z = z / jnp.maximum(jnp.linalg.norm(z, -1, keepdims=True), 1e-6)
            return z

        self._embed = jax.jit(embed_and_uncertainty)
        self._small_step = jax.jit(
            lambda p, t: lm.forward(small_cfg, p, tokens=t))
        self._large_step = jax.jit(
            lambda p, t: lm.forward(large_cfg, p, tokens=t))

    def handle(self, tokens, *, update_gmm=True):
        """tokens: (B, S). Routes each request; returns (logits, routed_to)."""
        z = self._embed(self.small_params, tokens)
        u = gmm_mod.normalized_entropy(self.gmm, z)
        if update_gmm:
            self.gmm = gmm_mod.em_update(self.gmm, z)
        if self.threshold == "auto":
            self.threshold = float(jnp.quantile(u, self.auto_quantile))
        hard = np.asarray(u > self.threshold)
        out = []
        for i, is_hard in enumerate(hard):
            t0 = time.perf_counter()
            if is_hard:
                h, _ = self._large_step(self.large_params, tokens[i:i + 1])
                logits = lm.logits_from_hidden(self.large_cfg,
                                               self.large_params, h)
                self.stats.served_large += 1
                self.stats.large_ms += (time.perf_counter() - t0) * 1e3
            else:
                h, _ = self._small_step(self.small_params, tokens[i:i + 1])
                logits = lm.logits_from_hidden(self.small_cfg,
                                               self.small_params, h)
                self.stats.served_small += 1
                self.stats.small_ms += (time.perf_counter() - t0) * 1e3
            out.append(np.asarray(logits[0, -1]))
        return np.stack(out), hard


def demo(n_batches=8, batch=8, seq=64):
    small = smoke_config(get_config("qwen1.5-0.5b"))
    large = replace(smoke_config(get_config("qwen3-1.7b")),
                    vocab=small.vocab, d_model=small.d_model,
                    n_layers=4)
    key = jax.random.PRNGKey(0)
    sp, _ = lm.init_lm(small, key)
    lp, _ = lm.init_lm(large, key)
    srv = CascadeServer(small, sp, large, lp, threshold="auto")
    for i in range(n_batches):
        toks = jax.random.randint(jax.random.PRNGKey(i), (batch, seq), 0,
                                  small.vocab)
        srv.handle(toks)
    s = srv.stats
    print(f"served: small={s.served_small} large={s.served_large} "
          f"escalation={s.escalation_rate:.2f}")
    return s


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    args = ap.parse_args()
    demo()
