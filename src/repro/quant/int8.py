"""Asymmetric INT8 post-training quantization (paper §5): the wire format
of the split link.  Per-tensor granularity, calibration-free (min/max of
the tensor being shipped), <0.5 ms overhead class.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # () f32
    zero: jax.Array     # () f32  (asymmetric zero point, float for exactness)

    @property
    def wire_bytes(self):
        return self.q.size + 8  # payload + scale/zero header


def quantize(x, *, bits=8):
    """Asymmetric affine quantization to int8 (per tensor)."""
    x = x.astype(jnp.float32)
    lo = jnp.min(x)
    hi = jnp.max(x)
    qmax = (1 << (bits - 1)) - 1   # 127
    qmin = -(1 << (bits - 1))      # -128
    scale = jnp.maximum((hi - lo) / (qmax - qmin), 1e-12)
    zero = qmin - lo / scale
    q = jnp.clip(jnp.round(x / scale + zero), qmin, qmax).astype(jnp.int8)
    return QTensor(q=q, scale=scale, zero=zero)


def dequantize(t: QTensor, dtype=jnp.float32):
    return ((t.q.astype(jnp.float32) - t.zero) * t.scale).astype(dtype)


def fake_quant(x):
    """quantize∘dequantize — in-graph wire simulation (differentiable via STE)."""
    y = dequantize(quantize(x), x.dtype)
    return x + jax.lax.stop_gradient(y - x)


def quant_error(x):
    return jnp.max(jnp.abs(x - dequantize(quantize(x), x.dtype)))
