"""The fleet data plane behind one ``FleetBackend`` seam.

The gateway (``api/gateway.py``) serves millions of streams through a
single abstraction that owns session rows, ingest, and refinement:

- ``HostFleetBackend`` — the original single-host path: ``FleetBuffer``
  rings in host numpy, one ``(N, W, d)`` snapshot copied to the device
  per refinement round, ``FleetRefiner`` in one jit.
- ``ShardedFleetBackend`` — the scaling path (parallel split learning:
  EPSL arXiv:2403.15815, AdaSplit arXiv:2112.01637): session rings live
  **on device** as ``jax.Array``s sharded over a ``sessions`` mesh axis,
  inserts are donated in-place ``.at[]`` scatters (no per-round snapshot
  copy — the refine step reads the rings where they already are), and
  ``refine`` runs under ``shard_map``: per-shard hybrid losses with the
  cross-shard active-session normalizer ``psum``'d (the estimator family
  of ``swd_loss(axis_name=...)``), gradients ``pmean``'d via
  ``distributed.grad_sync``, and the optional distributional memory
  updated with ``gmm.em_update(axis_name=...)``'s psum'd sufficient
  statistics.  One refine step trains on the whole fleet across the mesh.

Contracts (pinned in ``tests/test_fleet_backend.py``):
- a 1-shard ``ShardedFleetBackend`` refine is **bit-identical** to
  ``HostFleetBackend`` (losses, parts, per-session losses, updated head);
- a multi-shard refine matches the unsharded estimator to fp32 tolerance
  (the only cross-shard reassociations are the pmean/psum reductions);
- both report host<->device traffic (``snapshot_h2d_bytes`` /
  ``ingest_h2d_bytes``) so ``benchmarks/fleet_serve.py`` can show the
  snapshot copy is gone.

The gateway's overlapped tick (docs/PERF.md) stages every frame as one
device array and hands the submission-ordered dispatch embeddings to
``insert_batch`` as a ``jax.Array``: on the sharded backend the payload
flows dispatch → rings entirely on device (``ingest_h2d_bytes`` stays 0;
the zero-copy volume is measured in ``ingest_d2d_bytes``).

Both backends are **thread-safe by contract**: every state transition
(admit/evict/insert/refine/snapshot) holds one re-entrant lock, because
the streaming runtime (``serving/server.py``) ingests from a background
serving thread while clients open/close sessions from their own.  The
sharded backend additionally places admissions **least-loaded** across
the session mesh (ROADMAP: per-shard load balancing) — see
``ShardedFleetBackend.admit``.
"""
from __future__ import annotations

import abc
import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import gmm
from repro.core.fleet_buffer import (T_SENTINEL, FleetBuffer, FleetFullError,
                                     as_host, pad_pow2)
from repro.core.fleet_refiner import FleetRefiner, make_fleet_loss
from repro.core.hybrid import HybridCfg
from repro.distributed.grad_sync import pmean_grads
from repro.distributed.sharding import SESSIONS_AXIS, sessions_sharding

# Device rings are int32 (jax default int width without x64): the sentinel
# is the int32 minimum, still far below any reachable window index -(W+1).
T_SENTINEL_DEV = int(np.iinfo(np.int32).min)


class FleetBackend(abc.ABC):
    """Everything the gateway needs from the fleet data plane.

    ``capacity``/``window``/``dim`` describe the (N, W, d) session rings;
    ``shards`` is 1 on the host backend and the ``sessions`` mesh-axis
    size on the sharded one.  ``snapshot_h2d_bytes`` accumulates fleet
    snapshot bytes copied host->device for refinement (the cost the
    device-resident backend eliminates); ``ingest_h2d_bytes`` accumulates
    frame payload bytes moved host->device at ingest, and
    ``ingest_d2d_bytes`` the payload that arrived as ``jax.Array``s and
    never crossed the host boundary (the gateway's staged dispatch path).
    """

    capacity: int
    window: int
    dim: int
    shards: int = 1
    kind: str = "abstract"
    # True when insert_batch can consume jax.Arrays without a host
    # round-trip — the gateway hands over device embeddings directly
    device_ingest: bool = False
    snapshot_h2d_bytes: int = 0
    ingest_h2d_bytes: int = 0
    ingest_d2d_bytes: int = 0

    def __init__(self):
        # Ingest is thread-safe by contract: the streaming runtime
        # (``serving/server.py``) drives admit/insert/refine from its
        # serving thread while clients open/close sessions from their
        # own — every state transition in a concrete backend holds this
        # re-entrant lock.
        self._lock = threading.RLock()

    # -- session lifecycle ---------------------------------------------------
    @property
    @abc.abstractmethod
    def n_active(self) -> int: ...

    @abc.abstractmethod
    def admit(self) -> int: ...

    @abc.abstractmethod
    def evict(self, sid) -> None: ...

    # -- ingest --------------------------------------------------------------
    @abc.abstractmethod
    def insert(self, sid, t, z, label=-1) -> None: ...

    @abc.abstractmethod
    def insert_batch(self, sids, ts, zs, labels=None) -> None: ...

    @abc.abstractmethod
    def fill_fraction(self, sid) -> float: ...

    # -- row migration (cluster federation; docs/FEDERATION.md) --------------
    @abc.abstractmethod
    def export_row(self, sid):
        """Copy one session's ring row out of the fleet:
        ``(z (W, d) f32, t (W,) i64, label (W,) i64, newest int)`` in the
        HOST representation (``fleet_buffer.T_SENTINEL`` marks empty
        slots) regardless of backend — so a row exported from any
        backend implants into any other."""

    @abc.abstractmethod
    def import_row(self, sid, z, t, label, newest) -> None:
        """Implant an exported row into an admitted session slot (the
        inverse of ``export_row``; host-representation inputs)."""

    # -- refinement ----------------------------------------------------------
    @property
    def can_refine(self) -> bool:
        return getattr(self, "refiner", None) is not None

    @abc.abstractmethod
    def refine(self, key):
        """One fleet-wide hybrid-loss step.
        -> (mean active loss, mean active parts, per-session losses (N,))."""

    # -- observability -------------------------------------------------------
    @abc.abstractmethod
    def snapshot(self):
        """Host-side (z (N, W, d), mask (N, W), labels (N, W))."""

    def shards_of(self, sids) -> np.ndarray:
        """Which session shard each fleet row lives on (contiguous
        blocks) — THE placement contract; override in lockstep with the
        mesh layout."""
        return np.asarray(sids, np.int64) * self.shards // self.capacity

    def shard_of(self, sid) -> int:
        return int(self.shards_of(np.array([sid]))[0])


class HostFleetBackend(FleetBackend):
    """The original single-host data plane behind the backend seam:
    numpy ``FleetBuffer`` rings + ``FleetRefiner``; every refine round
    copies one full fleet snapshot to the device (counted in
    ``snapshot_h2d_bytes``)."""

    kind = "host"

    def __init__(self, *, capacity=32, window=100, dim=128, head_init=None,
                 head_apply=None, cfg: HybridCfg = HybridCfg(), lr=1e-2,
                 seed=0, n_components=0, memory_decay=0.05):
        super().__init__()
        if n_components and head_init is None:
            raise ValueError("fleet memory (n_components) updates ride the "
                             "refine round: pass head_init/head_apply too")
        self.capacity, self.window, self.dim = capacity, window, dim
        self.shards = 1
        self.buffer = FleetBuffer(capacity=capacity, window=window, dim=dim)
        self.refiner = None
        if head_init is not None:
            self.refiner = FleetRefiner(head_init, head_apply, cfg=cfg,
                                        lr=lr, seed=seed)
        self.memory = None
        if n_components:
            self.memory = gmm.init_gmm(jax.random.PRNGKey(seed + 1),
                                       n_components, dim)
            # reseed stays off for fleet memory: reseeding picks rows of
            # the local batch, which would de-replicate the state across
            # shards on the sharded twin — keep both backends identical
            self._em = jax.jit(partial(gmm.em_update, decay=memory_decay,
                                       reseed_frac=0.0))
        self.snapshot_h2d_bytes = 0
        self.ingest_h2d_bytes = 0

    # -- delegation to the host buffer --------------------------------------
    @property
    def n_active(self):
        return self.buffer.n_active

    @property
    def active(self):
        return self.buffer.active

    def admit(self):
        with self._lock:
            return self.buffer.admit()

    def evict(self, sid):
        with self._lock:
            self.buffer.evict(sid)

    def insert(self, sid, t, z, label=-1):
        with self._lock:
            self.buffer.insert(sid, t, z, label=label)

    def insert_batch(self, sids, ts, zs, labels=None):
        with self._lock:
            self.buffer.insert_batch(sids, ts, zs, labels)

    def fill_fraction(self, sid):
        with self._lock:
            return self.buffer.fill_fraction(sid)

    def export_row(self, sid):
        with self._lock:
            return self.buffer.export_row(sid)

    def import_row(self, sid, z, t, label, newest):
        with self._lock:
            self.buffer.import_row(sid, z, t, label, newest)

    def snapshot(self):
        with self._lock:
            return self.buffer.snapshot()

    def refine(self, key):
        if self.refiner is None:
            raise RuntimeError("backend built without a head: no refiner")
        with self._lock:
            z, mask, labels = self.buffer.snapshot()
            self.snapshot_h2d_bytes += (z.nbytes + mask.nbytes
                                        + labels.nbytes
                                        + self.buffer.active.nbytes)
            out = self.refiner.refine_arrays(key, z, mask, labels,
                                             self.buffer.active)
            if self.memory is not None:
                self.memory = self._em(self.memory, z.reshape(-1, self.dim),
                                       weights=mask.reshape(-1))
            return out


def _snapshot_rows(z, t, label, newest, active, *, window):
    """Temporal-order snapshot of a block of session rows, on device.

    Row-local (no cross-session term), so the same function serves the
    global jit snapshot and the per-shard view inside ``shard_map``.
    Same math as ``FleetBuffer.snapshot`` — the parity tests compare the
    two bitwise."""
    w_idx = jnp.arange(window, dtype=newest.dtype)
    order = (newest - window + 1)[:, None] + w_idx[None, :]   # (n, W)
    slots = order % window
    valid = jnp.take_along_axis(t, slots, axis=1) == order
    valid &= (newest >= 0)[:, None] & (active > 0)[:, None]
    zs = jnp.where(valid[:, :, None],
                   jnp.take_along_axis(z, slots[:, :, None], axis=1), 0.0)
    labels = jnp.where(valid, jnp.take_along_axis(label, slots, axis=1), -1)
    return zs, valid.astype(jnp.float32), labels


class ShardedFleetBackend(FleetBackend):
    """Device-resident fleet data plane sharded over a ``sessions`` axis.

    State lives as donated ``jax.Array``s (``z``/``t``/``label``/
    ``newest``/``active``) with dim 0 partitioned over the mesh; ingest is
    a jitted in-place scatter (batch padded to powers of two so the
    compile cache stays O(log capacity)); refine runs one
    ``shard_map``'d step per round — snapshot, hybrid loss, cross-shard
    pmean of loss/parts/grads, optional psum'd distributional-memory
    update — and only scalars + the (N,) per-session losses ever leave
    the device.

    Admission is **least-loaded**: each shard owns a contiguous block of
    rows (``shards_of``), and ``admit`` places the new session on the
    shard with the fewest active sessions (ties break to the lowest
    shard index; within a shard rows hand out lowest-first, exactly the
    host free-list order).  A fleet that fills and drains therefore
    keeps its refine work balanced across the mesh instead of stacking
    every live session on shard 0 (ROADMAP: per-shard load balancing of
    admissions; pinned in ``tests/test_fleet_backend.py``).
    """

    kind = "sharded"
    device_ingest = True

    def __init__(self, *, capacity=32, window=100, dim=128, head_init=None,
                 head_apply=None, cfg: HybridCfg = HybridCfg(), lr=1e-2,
                 seed=0, n_components=0, memory_decay=0.05, mesh=None,
                 axis=SESSIONS_AXIS):
        from repro.compat import shard_map
        super().__init__()
        if n_components and head_init is None:
            raise ValueError("fleet memory (n_components) updates ride the "
                             "refine round: pass head_init/head_apply too")
        if mesh is None:
            from repro.launch.mesh import make_sessions_mesh
            mesh = make_sessions_mesh(axis=axis)
        self.mesh, self.axis = mesh, axis
        self.shards = mesh.shape[axis]
        if capacity % self.shards:
            raise ValueError(
                f"capacity={capacity} must divide evenly over "
                f"{self.shards} session shards")
        self.capacity, self.window, self.dim = capacity, window, dim
        self._sharding = sessions_sharding(mesh, axis)
        put = lambda x: jax.device_put(x, self._sharding)
        self.z = put(jnp.zeros((capacity, window, dim), jnp.float32))
        self.t = put(jnp.full((capacity, window), T_SENTINEL_DEV, jnp.int32))
        self.label = put(jnp.full((capacity, window), -1, jnp.int32))
        self.newest = put(jnp.full((capacity,), -1, jnp.int32))
        self.active_dev = put(jnp.zeros((capacity,), jnp.float32))
        # host-side admission bookkeeping: one free-list PER SHARD (each
        # a lowest-row-first stack like FleetBuffer's) + per-shard active
        # counts, so admit can place least-loaded across the mesh
        self._active = np.zeros((capacity,), bool)
        self._dirty = np.zeros((capacity,), bool)
        rows = capacity // self.shards
        self._free_by_shard = [
            list(range((s + 1) * rows - 1, s * rows - 1, -1))
            for s in range(self.shards)]
        self._shard_active = [0] * self.shards
        self.snapshot_h2d_bytes = 0
        self.ingest_h2d_bytes = 0
        self.ingest_d2d_bytes = 0

        # -- compiled state transitions (donated: in-place on device) -------
        def _ins(z, t, label, newest, sids, slots, ts, zs, labels,
                 ts_newest):
            # ts_newest == ts except when insert_batch folded duplicate
            # (sid, slot) writes: the ring keeps the LAST write's frame,
            # newest still advances to the max timestamp seen
            return (z.at[sids, slots].set(zs),
                    t.at[sids, slots].set(ts),
                    label.at[sids, slots].set(labels),
                    newest.at[sids].max(ts_newest))

        def _ins_placed(z, t, label, newest, sid_zl, slots, ts, zs, labels,
                        sid_nw, nw_ts):
            # blocked shard-local scatter (insert_batch_placed): every
            # operand is an equal per-shard block, so under shard_map each
            # device scatters only its own rows.  Rows carrying the DROP
            # sentinel (local sid == rows-per-shard: pads and superseded
            # duplicate writes) fall out of range and mode="drop" makes
            # them no-ops; ``newest`` maxes over ALL real rows, which is
            # order-independent, so duplicates need no fold there.
            return (z.at[sid_zl, slots].set(zs, mode="drop"),
                    t.at[sid_zl, slots].set(ts, mode="drop"),
                    label.at[sid_zl, slots].set(labels, mode="drop"),
                    newest.at[sid_nw].max(nw_ts, mode="drop"))

        def _wipe_admit(z, t, label, newest, active, sid):
            return (z.at[sid].set(0.0),
                    t.at[sid].set(T_SENTINEL_DEV),
                    label.at[sid].set(-1),
                    newest.at[sid].set(-1),
                    active.at[sid].set(1.0))

        def _implant(z, t, label, newest, sid, zr, tr, lr, nw):
            # whole-row set: the migration import seam (export_row's
            # inverse) — one executable regardless of which row
            return (z.at[sid].set(zr),
                    t.at[sid].set(tr),
                    label.at[sid].set(lr),
                    newest.at[sid].set(nw))

        # out_shardings pinned: XLA's scatter sharding propagation would
        # otherwise return replicated rings, silently resharding (and
        # recompiling) the next refine step
        shd = self._sharding
        self._insert_fn = jax.jit(_ins, donate_argnums=(0, 1, 2, 3),
                                  out_shardings=(shd,) * 4)
        pa = P(axis)
        self._insert_placed_fn = jax.jit(
            shard_map(_ins_placed, mesh=mesh, in_specs=(pa,) * 11,
                      out_specs=(pa,) * 4, check_vma=False),
            donate_argnums=(0, 1, 2, 3))
        self._wipe_fn = jax.jit(_wipe_admit, donate_argnums=(0, 1, 2, 3, 4),
                                out_shardings=(shd,) * 5)
        self._implant_fn = jax.jit(_implant, donate_argnums=(0, 1, 2, 3),
                                   out_shardings=(shd,) * 4)
        self._set_active_fn = jax.jit(
            lambda active, sid, v: active.at[sid].set(v),
            donate_argnums=(0,), out_shardings=shd)
        self._snapshot_fn = jax.jit(
            partial(_snapshot_rows, window=window))

        # -- the shard_map'd refine round -----------------------------------
        self.refiner = None
        self.memory = None
        if head_init is not None:
            self.refiner = FleetRefiner(head_init, head_apply, cfg=cfg,
                                        lr=lr, seed=seed)
            # commit head/opt/memory to the mesh-replicated sharding NOW:
            # otherwise the first apply_grads would flip their committed
            # sharding and force one silent refine-step recompile
            replicated = jax.sharding.NamedSharding(mesh, P())
            st = self.refiner.state
            st.params = jax.device_put(st.params, replicated)
            st.opt_state = jax.device_put(st.opt_state, replicated)
            fleet_loss = make_fleet_loss(head_apply, cfg, axis_name=axis,
                                         axis_size=self.shards)
            if n_components:
                self.memory = jax.device_put(
                    gmm.init_gmm(jax.random.PRNGKey(seed + 1),
                                 n_components, dim), replicated)

            def _local(params, key, z, t, label, newest, active):
                zs, mask, labels = _snapshot_rows(z, t, label, newest,
                                                  active, window=window)
                (loss, (losses, parts)), grads = jax.value_and_grad(
                    fleet_loss, has_aux=True)(params, key, zs, mask,
                                              labels, active)
                loss = jax.lax.pmean(loss, axis)
                parts = {k: jax.lax.pmean(v, axis) for k, v in parts.items()}
                grads = pmean_grads(grads, axis)
                return loss, parts, losses, grads, (zs, mask)

            if n_components:
                def local_step(params, mem, key, z, t, label, newest,
                               active):
                    loss, parts, losses, grads, (zs, mask) = _local(
                        params, key, z, t, label, newest, active)
                    mem = gmm.em_update(mem, zs.reshape(-1, dim),
                                        weights=mask.reshape(-1),
                                        decay=memory_decay, axis_name=axis,
                                        reseed_frac=0.0)
                    return loss, parts, losses, grads, mem

                in_specs = (P(), P(), P()) + (P(axis),) * 5
                out_specs = (P(), P(), P(axis), P(), P())
            else:
                def local_step(params, key, z, t, label, newest, active):
                    loss, parts, losses, grads, _ = _local(
                        params, key, z, t, label, newest, active)
                    return loss, parts, losses, grads

                in_specs = (P(), P()) + (P(axis),) * 5
                out_specs = (P(), P(), P(axis), P())

            self._refine_step = jax.jit(shard_map(
                local_step, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_vma=False))

    # -- session lifecycle ---------------------------------------------------
    @property
    def n_active(self):
        return int(self._active.sum())

    @property
    def active(self):
        return self._active

    def admit(self):
        """Least-loaded placement: the new session lands on the shard
        with the fewest active sessions (ties -> lowest shard index)."""
        with self._lock:
            ranked = [(self._shard_active[s], s)
                      for s in range(self.shards) if self._free_by_shard[s]]
            if not ranked:
                raise FleetFullError(
                    f"all {self.capacity} session rows in use")
            _, shard = min(ranked)
            sid = self._free_by_shard[shard].pop()
            self._shard_active[shard] += 1
            if self._dirty[sid]:   # deferred O(W·d) wipe, on device
                (self.z, self.t, self.label, self.newest,
                 self.active_dev) = self._wipe_fn(
                    self.z, self.t, self.label, self.newest, self.active_dev,
                    jnp.int32(sid))
                self._dirty[sid] = False
            else:
                self.active_dev = self._set_active_fn(
                    self.active_dev, jnp.int32(sid), jnp.float32(1.0))
            self._active[sid] = True
            return sid

    def evict(self, sid):
        with self._lock:
            if not self._active[sid]:
                raise KeyError(f"session {sid} is not active")
            self._active[sid] = False
            self._dirty[sid] = True
            shard = self.shard_of(sid)
            self._free_by_shard[shard].append(sid)
            self._shard_active[shard] -= 1
            self.active_dev = self._set_active_fn(
                self.active_dev, jnp.int32(sid), jnp.float32(0.0))

    # -- ingest --------------------------------------------------------------
    def insert(self, sid, t, z, label=-1):
        z = z[None] if isinstance(z, jax.Array) else np.asarray(z)[None]
        self.insert_batch(np.array([sid]), np.array([t]), z,
                          np.array([label]))

    def insert_batch(self, sids, ts, zs, labels=None):
        """Donated in-place scatter into the device rings.

        ``zs`` may be a ``jax.Array`` (stays on device, 0 ingest-h2d
        bytes) or a host array (one h2d transfer, counted).  The batch is
        repeat-padded to the next power of two so each batch size bucket
        compiles once (pad rows duplicate entry 0's indices with
        identical values — a well-defined scatter).  Caller-supplied
        duplicate (sid, slot) pairs are folded to numpy's last-wins
        semantics before the scatter, keeping the host-backend parity."""
        with self._lock:
            self._insert_batch_locked(sids, ts, zs, labels)

    def insert_batch_placed(self, sids, ts, zs, labels, rows):
        """Shard-local scatter of a tick batch already blocked per shard.

        The sharded dispatch plane (``StreamSplitGateway`` with
        ``shard_dispatch``) lays each tick's embeddings out as one global
        ``(R, d)`` device array over the sessions axis in equal per-shard
        blocks; ``rows[i]`` names frame ``i``'s global row in that layout,
        and every frame's row must sit inside the block owned by its
        session's shard (checked), so the scatter — a ``shard_map`` over
        the same axis — never moves a payload byte across shards.  Rows
        not named by ``rows`` (pads) and duplicate (sid, slot) writes
        superseded by a later frame scatter with an out-of-range DROP
        sentinel under ``mode="drop"``; ``newest`` still maxes over every
        real row, matching ``insert_batch``'s last-wins + max-ts fold.
        """
        with self._lock:
            sids = as_host(sids, np.int64)
            ts = as_host(ts, np.int64)
            rows = as_host(rows, np.int64)
            if not self._active[sids].all():
                raise KeyError("insert_batch into inactive session")
            n = len(sids)
            if n == 0:
                return
            if not isinstance(zs, jax.Array):
                raise TypeError("insert_batch_placed takes the staged "
                                "device array; host payloads go through "
                                "insert_batch")
            R = int(zs.shape[0])
            if R % self.shards:
                raise ValueError(f"blocked batch of {R} rows does not "
                                 f"split over {self.shards} shards")
            block = R // self.shards
            rows_ps = self.capacity // self.shards
            if int(ts.max()) > np.iinfo(np.int32).max:
                raise ValueError("frame index exceeds the device ring's "
                                 "int32 range; re-key session time or use "
                                 "HostFleetBackend")
            shard = self.shards_of(sids)
            if not np.array_equal(rows // block, shard):
                raise ValueError("frame placed in a row block that is not "
                                 "its session's shard")
            if labels is None:
                labels = np.full(n, -1, np.int64)
            labels32 = as_host(labels, np.int64).astype(np.int32)
            loc = (sids - shard * rows_ps).astype(np.int32)
            slots = np.asarray(ts % self.window, np.int32)
            drop = np.int32(rows_ps)     # out of local range -> no-op
            sid_zl = np.full(R, drop, np.int32)
            slot_b = np.zeros(R, np.int32)
            ts_b = np.zeros(R, np.int32)
            lab_b = np.zeros(R, np.int32)
            sid_nw = np.full(R, drop, np.int32)
            nw_b = np.zeros(R, np.int32)
            keep = np.ones(n, bool)
            keys = sids * self.window + slots
            if len(np.unique(keys)) < n:
                last = {}
                for i, k in enumerate(keys.tolist()):
                    last[k] = i
                keep[:] = False
                keep[np.fromiter(last.values(), np.int64)] = True
            kr = rows[keep]
            sid_zl[kr] = loc[keep]
            slot_b[kr] = slots[keep]
            ts_b[kr] = ts[keep].astype(np.int32)
            lab_b[kr] = labels32[keep]
            sid_nw[rows] = loc
            nw_b[rows] = ts.astype(np.int32)
            self.ingest_d2d_bytes += n * self.dim * 4
            self.z, self.t, self.label, self.newest = self._insert_placed_fn(
                self.z, self.t, self.label, self.newest, sid_zl, slot_b,
                ts_b, zs, lab_b, sid_nw, nw_b)

    def _insert_batch_locked(self, sids, ts, zs, labels):
        sids = as_host(sids, np.int64)
        ts = as_host(ts, np.int64)
        if not self._active[sids].all():
            raise KeyError("insert_batch into inactive session")
        n = len(sids)
        if n == 0:                       # host-buffer contract: a no-op
            return
        if int(ts.max()) > np.iinfo(np.int32).max:
            # the device rings keep int32 frame indices (jax default int
            # width); silently wrapping would drop the session from every
            # refine round while the host backend kept serving it
            raise ValueError("frame index exceeds the device ring's int32 "
                             "range; re-key session time or use "
                             "HostFleetBackend")
        if labels is None:
            labels = np.full(n, -1, np.int64)
        sids32 = np.asarray(sids, np.int32)
        slots32 = np.asarray(ts % self.window, np.int32)
        ts32 = np.asarray(ts, np.int32)
        ts_newest = ts32
        labels32 = as_host(labels, np.int64).astype(np.int32)
        if not isinstance(zs, jax.Array):
            zs = as_host(zs, np.float32)
            self.ingest_h2d_bytes += zs.nbytes
        else:   # staged dispatch path: payload never touches the host
            self.ingest_d2d_bytes += zs.nbytes
        keys = sids32.astype(np.int64) * self.window + slots32
        if len(np.unique(keys)) < n:
            # duplicate (sid, slot) writes in one batch: jnp scatter with
            # repeated indices is undefined, numpy fancy assignment keeps
            # the last — fold to last-wins here (max timestamp per ring
            # slot still reaches ``newest``) so both backends agree
            last, tmax = {}, {}
            for i, k in enumerate(keys.tolist()):
                last[k] = i
                tmax[k] = max(tmax.get(k, ts32[i]), ts32[i])
            keep = np.sort(np.fromiter(last.values(), np.int64))
            sids32, slots32, ts32, labels32 = (
                a[keep] for a in (sids32, slots32, ts32, labels32))
            ts_newest = np.array([tmax[k] for k in keys[keep]], np.int32)
            zs = zs[keep] if isinstance(zs, jax.Array) \
                else np.ascontiguousarray(zs[keep])
            n = len(keep)
        pad = pad_pow2(n) - n
        if pad:
            rep = lambda a: np.concatenate(
                [a, np.broadcast_to(a[:1], (pad,) + a.shape[1:])])
            sids32, slots32, ts32, labels32, ts_newest = map(
                rep, (sids32, slots32, ts32, labels32, ts_newest))
            zs = jnp.concatenate(
                [zs, jnp.broadcast_to(zs[:1], (pad,) + zs.shape[1:])]) \
                if isinstance(zs, jax.Array) else rep(zs)
        self.z, self.t, self.label, self.newest = self._insert_fn(
            self.z, self.t, self.label, self.newest, sids32, slots32,
            ts32, jnp.asarray(zs, jnp.float32), labels32, ts_newest)

    def export_row(self, sid):
        """Device row -> host representation (one D2H per array): int64
        timestamps with the host ``T_SENTINEL`` marking empty slots, so
        the snapshot implants into either backend kind."""
        with self._lock:
            if not self._active[sid]:
                raise KeyError(f"session {sid} is not active")
            z = np.asarray(self.z[sid])
            t32 = np.asarray(self.t[sid])
            t = t32.astype(np.int64)
            t[t32 == T_SENTINEL_DEV] = T_SENTINEL
            label = np.asarray(self.label[sid]).astype(np.int64)
            return z, t, label, int(self.newest[sid])

    def import_row(self, sid, z, t, label, newest):
        with self._lock:
            if not self._active[sid]:
                raise KeyError(f"session {sid} is not active")
            z = as_host(z, np.float32)
            if z.shape != (self.window, self.dim):
                raise ValueError(
                    f"row shape {z.shape} != ({self.window}, {self.dim}) "
                    "— migrating between fleets with different window/dim "
                    "is not supported")
            t = as_host(t, np.int64)
            live = t != T_SENTINEL
            if live.any() and int(t[live].max()) > np.iinfo(np.int32).max:
                raise ValueError("frame index exceeds the device ring's "
                                 "int32 range; re-key session time or use "
                                 "HostFleetBackend")
            t32 = np.where(live, t, T_SENTINEL_DEV).astype(np.int32)
            (self.z, self.t, self.label, self.newest) = self._implant_fn(
                self.z, self.t, self.label, self.newest, jnp.int32(sid),
                jnp.asarray(z), jnp.asarray(t32),
                jnp.asarray(as_host(label, np.int64).astype(np.int32)),
                jnp.int32(newest))
            self.ingest_h2d_bytes += z.nbytes + t32.nbytes

    def fill_fraction(self, sid):
        with self._lock:
            if not self._active[sid]:
                return 0.0
            newest = int(self.newest[sid])
            if newest < 0:
                return 0.0
            order = np.arange(newest - self.window + 1, newest + 1)
            t_row = np.asarray(self.t[sid])
            return float((t_row[order % self.window] == order).mean())

    # -- refinement ----------------------------------------------------------
    def refine(self, key):
        """One fleet-wide step across the session mesh — no fleet
        snapshot ever crosses the host boundary (``snapshot_h2d_bytes``
        stays 0; only scalars and the (N,) per-session losses come back).
        """
        if self.refiner is None:
            raise RuntimeError("backend built without a head: no refiner")
        with self._lock:
            args = (self.refiner.state.params,)
            if self.memory is not None:
                args += (self.memory,)
            out = self._refine_step(*args, key, self.z, self.t, self.label,
                                    self.newest, self.active_dev)
            if self.memory is not None:
                loss, parts, losses, grads, self.memory = out
            else:
                loss, parts, losses, grads = out
            self.refiner.apply_grads(grads)
            return (float(loss), {k: float(v) for k, v in parts.items()},
                    np.asarray(losses))

    # -- observability -------------------------------------------------------
    def snapshot(self):
        """Host copy of the fleet view (observability / compat — NOT the
        refine path, which reads the device rings in place)."""
        with self._lock:
            z, mask, labels = self._snapshot_fn(self.z, self.t, self.label,
                                                self.newest, self.active_dev)
        return (np.asarray(z), np.asarray(mask),
                np.asarray(labels, np.int64))


def make_backend(kind="host", **kw) -> FleetBackend:
    """Backend factory: ``host`` (numpy rings, single device) or
    ``sharded`` (device-resident rings over a ``sessions`` mesh)."""
    if kind == "host":
        kw.pop("mesh", None)
        kw.pop("axis", None)
        return HostFleetBackend(**kw)
    if kind == "sharded":
        return ShardedFleetBackend(**kw)
    raise ValueError(f"unknown fleet backend kind: {kind!r}")
