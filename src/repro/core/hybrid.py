"""The Hybrid Loss (paper Eq. 13):

    L_server = L_task + λ₁·L_SW(p_θ, U) + λ₂·L_Lap(G)

L_task is InfoNCE over the buffer in the self-supervised setting, or CE
when sparse labels are available.  Also exposes the ablation variants of
Table 5 (MSE-only, KL, task+SW, task+Lap).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.infonce import batch_infonce
from repro.core.laplacian import laplacian_loss
from repro.core.swd import swd_loss


@dataclass(frozen=True)
class HybridCfg:
    lam_sw: float = 0.1      # λ₁ (paper grid search)
    lam_lap: float = 0.01    # λ₂
    n_dirs: int = 50         # SWD projections M
    knn: int = 5             # temporal graph neighbours
    tau: float = 0.1


def task_loss(z, *, labels=None, logits=None, z_pos=None, tau=0.1):
    if logits is not None and labels is not None:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
    if z_pos is not None:
        return batch_infonce(z, z_pos, tau=tau)
    return jnp.float32(0.0)


def hybrid_loss(key, z_seq, cfg: HybridCfg = HybridCfg(), *, mask=None,
                labels=None, logits=None, z_pos=None, axis_name=None,
                variant="hybrid"):
    """z_seq: (T, d) or (B, T, d) temporally ordered embeddings.

    variant ∈ {hybrid, task_sw, task_lap, mse, kl} (Table 5 ablation)."""
    z_flat = z_seq.reshape(-1, z_seq.shape[-1])
    t = task_loss(z_flat if z_pos is None else z_flat, labels=labels,
                  logits=logits, z_pos=z_pos, tau=cfg.tau)
    parts = {"task": t}
    if variant in ("hybrid", "task_sw"):
        parts["sw"] = swd_loss(key, z_flat, n_dirs=cfg.n_dirs,
                               axis_name=axis_name)
    if variant in ("hybrid", "task_lap"):
        parts["lap"] = laplacian_loss(z_seq, k=cfg.knn, mask=mask)
    if variant == "mse":
        # naive consistency: pull adjacent frames together with plain MSE
        d = z_seq[..., 1:, :] - z_seq[..., :-1, :]
        parts["mse"] = jnp.mean(jnp.square(d))
    if variant == "kl":
        # KL of the batch feature distribution to N(0, I) (moment-matched)
        mu = jnp.mean(z_flat, 0)
        var = jnp.var(z_flat, 0) + 1e-6
        parts["kl"] = 0.5 * jnp.mean(mu ** 2 + var - jnp.log(var) - 1.0)

    loss = parts["task"]
    if "sw" in parts:
        loss = loss + cfg.lam_sw * parts["sw"]
    if "lap" in parts:
        loss = loss + cfg.lam_lap * parts["lap"]
    if "mse" in parts:
        loss = loss + parts["mse"]
    if "kl" in parts:
        loss = loss + parts["kl"]
    return loss, parts
