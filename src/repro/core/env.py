"""Edge–cloud discrete-event simulator for the Control Plane MDP
(paper §4.2, Appendix B).

This is the *calibrated* environment: platform/network constants are fitted
to the paper's own anchors (Table 2 energy, Fig. 6 bandwidth, Fig. 7
latency) so the *policies* — PPO, rule-based, static, edge-only,
server-only — are evaluated under the paper's cost model.  The learning
algorithms, losses and split engine are the real implementations; only the
ARM/4G silicon is simulated (DESIGN.md §2).

State   s_t = [U_t (GMM entropy, normalized), R_cpu/100, B_net (norm)]
Action  a_t = split layer k ∈ {0..L} (k<L offloads INT8 activations)
Reward  r_t = α·A_task − β·Lat/T_max − η·E/E_budget          (Eq. 12)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.models.audio_encoder import AudioEncCfg, block_flops, boundary_bytes


# ---------------------------------------------------------------------------
# Platforms (calibrated to Table 2 / §6.5)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Platform:
    name: str
    flops_per_sec: float          # effective sustained f32 FLOP/s
    joules_per_flop: float        # edge compute energy
    joules_per_byte_tx: float     # uplink radio energy
    frontend_ms: float            # STFT/mel frontend latency per sample
    frontend_mj: float            # frontend energy per sample
    overhead_ms: float            # GMM update + RL inference (<2 ms, §6.2.2)


# Calibration anchors (Table 2, per 1-s sample):
#   edge-only  = 67.4 mJ  = frontend 12.4 + 55 mJ of local train compute
#   server-only= 187.2 mJ = frontend 12.4 + 174.8 mJ for 32 KB raw PCM
#     -> joules_per_byte_tx = 174.8e-3 / 32e3 = 5.46 uJ/B (4G-class radio)
#   local training = 3x fwd FLOPs (fwd+bwd) on the 0.103 GFLOP encoder
#     -> joules_per_flop = 55e-3 / 0.31e9 = 1.77e-10 J/FLOP
TRAIN_FLOP_MULT = 3.0
PI4 = Platform("pi4", flops_per_sec=6.0e9, joules_per_flop=1.77e-10,
               joules_per_byte_tx=5.46e-6, frontend_ms=3.2,
               frontend_mj=12.4, overhead_ms=2.0)

# Apple M2 (GPU/MPS path, §5): ~16x Pi throughput, higher absolute draw
# per op class than its process node suggests (unified-memory system power).
M2 = Platform("m2", flops_per_sec=1.0e11, joules_per_flop=2.2e-10,
              joules_per_byte_tx=5.46e-6, frontend_ms=0.4,
              frontend_mj=4.0, overhead_ms=0.5)

SERVER_FLOPS = 2.0e12          # per-stream share of the RTX3090 server
SERVER_BASE_MS = 8.0           # queueing + kernel launch floor
RAW_PCM_BYTES = 32_000         # 1 s @ 16 kHz, 16-bit mono (k=0 payload)
EMBED_BYTES = 128              # int8 d=128 embedding (k=L lazy-sync uplink)

PLATFORMS = {"pi4": PI4, "m2": M2}


# ---------------------------------------------------------------------------
# Network profiles (6 profiles over 4G/5G traces, §5)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NetProfile:
    name: str
    bw_mbps: tuple       # (lo, hi) random-walk band
    rtt_ms: tuple
    loss: float          # packet loss prob (adds retransmit latency)
    volatility: float    # random-walk step scale


NET_PROFILES = {
    "stable":    NetProfile("stable", (6.0, 10.0), (30, 50), 0.00, 0.05),
    "wifi":      NetProfile("wifi", (30.0, 50.0), (10, 25), 0.00, 0.05),
    "variable":  NetProfile("variable", (3.0, 25.0), (30, 120), 0.01, 0.25),
    "congested": NetProfile("congested", (1.0, 3.0), (120, 200), 0.03, 0.15),
    "dropout":   NetProfile("dropout", (0.5, 20.0), (40, 150), 0.05, 0.45),
    "5g":        NetProfile("5g", (20.0, 50.0), (15, 40), 0.005, 0.10),
}


@dataclass(frozen=True)
class EnvCfg:
    platform: str = "pi4"
    net: str = "stable"
    enc: AudioEncCfg = AudioEncCfg()
    t_max_ms: float = 150.0       # latency budget T_max (per sample)
    e_budget_mj: float = 100.0    # per-frame energy budget
    alpha: float = 10.0           # reward weights (paper §5)
    beta: float = 5.0
    eta: float = 3.0
    horizon: int = 200            # decision steps per episode
    frames_per_step: int = 10     # T_step (≈100 ms)
    quant_bytes: int = 1          # INT8 wire format
    quant_acc_penalty: float = 0.003   # <0.3 % (paper §5)
    kappa: float = 1.3            # local-processing utility loss ∝ U_t
    # manifold-alignment factor: with near-zero offloading the edge model
    # collapses (C1) — quality q ramps from q_min to 1 as the offloaded
    # fraction approaches o_ref (Theorem 3.2: the server can stitch gaps
    # only if *some* frames arrive).
    q_min: float = 0.05
    o_ref: float = 0.10
    seed: int = 0
    # uncertainty regime mix (EcoStream-Wild §6.1.1)
    p_background: float = 0.602
    p_speech: float = 0.245
    p_transient: float = 0.153
    # cpu background-load markov chain
    cpu_load_p: float = 0.08      # P(enter loaded)
    cpu_unload_p: float = 0.25    # P(leave loaded)


class EdgeCloudEnv:
    """Gym-style env.  obs = [U, cpu, bw_norm] ∈ [0,1]³; action k ∈ 0..L."""

    BW_NORM = 50.0  # Mbps normalization

    def __init__(self, cfg: EnvCfg = EnvCfg()):
        self.cfg = cfg
        self.plat = PLATFORMS[cfg.platform]
        self.net = NET_PROFILES[cfg.net]
        enc = cfg.enc
        self.L = enc.n_blocks
        self.flops = np.array(block_flops(enc), np.float64)
        # wire payloads: k=0 raw PCM; 0<k<L INT8 activations (+fp32 option);
        # k=L the lazy-synced int8 embedding only.
        b_int8 = np.array(boundary_bytes(enc, dtype_bytes=1), np.float64)
        self.wire_int8 = np.concatenate(
            [[RAW_PCM_BYTES], b_int8[1:-1], [EMBED_BYTES]])
        b_fp32 = np.array(boundary_bytes(enc, dtype_bytes=4), np.float64)
        self.wire_fp32 = np.concatenate(
            [[RAW_PCM_BYTES], b_fp32[1:-1], [4 * EMBED_BYTES]])
        self.rng = np.random.default_rng(cfg.seed)
        self.reset()

    # -- stochastic processes ------------------------------------------------
    def _bw_step(self):
        lo, hi = self.net.bw_mbps
        drift = self.rng.normal(0, self.net.volatility) * (hi - lo)
        self.bw = float(np.clip(self.bw + drift, lo * 0.5, hi * 1.2))

    def _cpu_step(self):
        if self.cpu_loaded:
            if self.rng.random() < self.cfg.cpu_unload_p:
                self.cpu_loaded = False
        elif self.rng.random() < self.cfg.cpu_load_p:
            self.cpu_loaded = True
        base = 28.0 if not self.cpu_loaded else 82.0
        self.cpu = float(np.clip(base + self.rng.normal(0, 6.0), 5.0, 100.0))

    def _uncertainty_step(self):
        """Regime-switching U_t matching the 60/25/15 class mix: background
        hum (low H), speech (mid), transient events (high)."""
        c = self.cfg
        r = self.rng.random()
        if r < c.p_transient:
            u = self.rng.uniform(0.75, 1.0)
        elif r < c.p_transient + c.p_speech:
            u = self.rng.uniform(0.4, 0.75)
        else:
            u = self.rng.uniform(0.02, 0.3)
        # temporal smoothing — sound sources don't teleport
        self.u = 0.6 * self.u + 0.4 * u

    def reset(self, seed=None):
        if seed is not None:
            self.rng = np.random.default_rng(seed)
        lo, hi = self.net.bw_mbps
        self.bw = float(self.rng.uniform(lo, hi))
        self.cpu_loaded = False
        self.cpu = 25.0
        self.u = 0.2
        self.offload_ema = 0.25   # warm start (cold-start local policy, §4.1.2)
        self.t = 0
        self.metrics = {k: 0.0 for k in
                        ("lat_ms", "tx_bytes", "energy_mj", "utility",
                         "drops", "frames", "edge_ms", "net_ms", "server_ms")}
        return self._obs()

    def _obs(self):
        return np.array([self.u, self.cpu / 100.0,
                         min(self.bw / self.BW_NORM, 1.0)], np.float32)

    # -- cost model ----------------------------------------------------------
    def step_costs(self, k, *, quantize=True):
        """Per-sample costs for split index k under the CURRENT state.

        Local segments are *trained* (fwd+bwd = TRAIN_FLOP_MULT x fwd)."""
        c, p = self.cfg, self.plat
        cpu_slow = 1.0 + 2.2 * max(self.cpu - 30.0, 0.0) / 70.0
        edge_flops = TRAIN_FLOP_MULT * float(self.flops[:k].sum())
        edge_ms = p.frontend_ms + p.overhead_ms + \
            1e3 * edge_flops / p.flops_per_sec * cpu_slow
        wire = float((self.wire_int8 if quantize else self.wire_fp32)[k])
        if k < self.L:
            rtt = self.rng.uniform(*self.net.rtt_ms)
            retrans = 1.0 / max(1.0 - self.net.loss * 8.0, 0.25)
            net_ms = (wire * 8.0 / (self.bw * 1e6)) * 1e3 * retrans + rtt / 2.0
            srv_ms = SERVER_BASE_MS + TRAIN_FLOP_MULT * \
                1e3 * float(self.flops[k:].sum()) / SERVER_FLOPS
        else:
            net_ms, srv_ms = 0.0, 0.0   # embedding sync is async (lazy)
        energy_mj = p.frontend_mj + 1e3 * (
            edge_flops * p.joules_per_flop + wire * p.joules_per_byte_tx)
        return edge_ms, net_ms, srv_ms, wire, energy_mj

    def utility(self, k, dropped, *, quantize=True):
        """Learning-signal utility ∈ [0,1] of this sample's placement."""
        if dropped:
            return 0.0
        if k >= self.L:
            # fully local: hard (high-U) frames hurt; and without *any*
            # offloading the manifold degrades (dimensional collapse, C1)
            q = self.cfg.q_min + (1 - self.cfg.q_min) * min(
                1.0, self.offload_ema / self.cfg.o_ref)
            return q * max(0.0, 1.0 - self.cfg.kappa * self.u)
        pen = self.cfg.quant_acc_penalty if (quantize and k > 0) else 0.0
        return 1.0 - pen

    def step(self, k, *, quantize=True):
        k = int(np.clip(k, 0, self.L))
        edge_ms, net_ms, srv_ms, wire, energy_mj = self.step_costs(
            k, quantize=quantize)
        lat = edge_ms + net_ms + srv_ms
        dropped = lat > self.cfg.t_max_ms
        util = self.utility(k, dropped, quantize=quantize)
        self.offload_ema = 0.98 * self.offload_ema + 0.02 * float(k < self.L)

        m = self.metrics
        m["lat_ms"] += lat
        m["edge_ms"] += edge_ms
        m["net_ms"] += net_ms
        m["server_ms"] += srv_ms
        m["tx_bytes"] += wire
        m["energy_mj"] += energy_mj
        m["utility"] += util
        m["drops"] += float(dropped)
        m["frames"] += 1

        r = (self.cfg.alpha * util
             - self.cfg.beta * min(lat / self.cfg.t_max_ms, 2.0)
             - self.cfg.eta * min(energy_mj / self.cfg.e_budget_mj, 2.0))

        self._bw_step()
        self._cpu_step()
        self._uncertainty_step()
        self.t += 1
        done = self.t >= self.cfg.horizon
        return self._obs(), float(r), done, {
            "lat_ms": lat, "energy_mj": energy_mj, "tx_bytes": wire,
            "dropped": dropped, "utility": util}

    # -- summary -------------------------------------------------------------
    def summary(self):
        m = self.metrics
        n = max(m["frames"], 1.0)
        return {
            "lat_ms": m["lat_ms"] / n,
            "edge_ms": m["edge_ms"] / n,
            "net_ms": m["net_ms"] / n,
            "server_ms": m["server_ms"] / n,
            "kb_per_batch": m["tx_bytes"] / n * 8.0 / 1024.0,  # batch = 8
            "energy_mj": m["energy_mj"] / n,
            "utility": m["utility"] / n,
            "drop_rate": m["drops"] / n,
        }


# accuracy anchors (Fig. 8, AudioSet): utility -> linear-probe accuracy
ACC_EDGE_ONLY = 58.6
ACC_SERVER = 73.6


def utility_to_accuracy(util):
    """Map mean learning-signal utility to the paper's accuracy scale."""
    return ACC_EDGE_ONLY + (ACC_SERVER - ACC_EDGE_ONLY) * util


def battery_hours(energy_mj_per_frame, *, wh=37.0, fps=37.4):
    """10,000 mAh pack (≈37 Wh); fps calibrated to Table 2 (see DESIGN)."""
    watts = energy_mj_per_frame * 1e-3 * fps
    return wh / max(watts, 1e-9)
