"""Fleet refinement: one hybrid-loss step over N sessions in a single jit.

Extracted from the original ``core/fleet.py`` (which now re-exports this
module).  The loss builder is shared with the device-resident sharded
backend (``core/fleet_backend.py``): ``make_fleet_loss(axis_name=...)``
produces the *same* per-session math with the cross-shard aggregation
expressed through the collective hooks the repo already had —
``jax.lax.psum`` of the active-session normalizer (the estimator family
of ``swd_loss(axis_name=...)`` / ``gmm.em_update(axis_name=...)``) so
one refine step trains on the whole fleet across a ``sessions`` mesh
axis.  With ``axis_name=None`` the function is bit-for-bit the original
single-host loss.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridCfg
from repro.core.laplacian import laplacian_loss
from repro.core.swd import (bitonic_diff_sort, diff_sort, random_directions,
                            sphere_prior_samples)


def make_fleet_loss(head_apply, cfg: HybridCfg, *, axis_name=None,
                    axis_size=1):
    """-> fleet_loss(params, key, z, mask, labels, active).

    Per-session losses reuse the exact ``ServerRefiner`` math (masked CE
    task term when sparse labels exist, SWD + Laplacian regularizers over
    the gap-masked snapshot) vmapped over the session axis.  The SWD
    directions/prior are drawn ONCE per step and shared by every session
    (common random numbers).  Session losses are averaged over *active*
    rows only.

    With ``axis_name`` the session axis is sharded: the active-row
    normalizer is ``psum``'d so every shard weights its local sessions by
    the *global* active count, and the returned loss/parts are pre-scaled
    by ``axis_size`` so that a ``pmean`` over the axis (gradients included
    — see ``distributed.grad_sync.pmean_grads``) reconstructs exactly the
    global sum.  At ``axis_size == 1`` every collective is an identity and
    the scaling is skipped, so a 1-shard mesh is bit-identical to the
    unsharded loss (pinned in ``tests/test_fleet_backend.py``).
    """

    def session_loss(params, z, mask, labels, dirs, prior_q):
        # per-session math identical to ServerRefiner's loss_fn (the
        # N=1 parity test pins this); the SWD slice quantile targets
        # arrive precomputed
        logits = head_apply(params, z)
        have_labels = labels >= 0
        lab = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ce = -jnp.take_along_axis(logp, lab[:, None], 1)[:, 0]
        w = mask * have_labels.astype(jnp.float32)
        task = jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)
        px = bitonic_diff_sort(z.astype(jnp.float32) @ dirs.T)
        sw = jnp.mean(jnp.square(px - prior_q))
        lap = laplacian_loss(z, k=cfg.knn, mask=mask)
        loss = task + cfg.lam_sw * sw + cfg.lam_lap * lap
        return loss, {"task": task, "sw": sw, "lap": lap}

    def fleet_loss(params, key, z, mask, labels, active):
        # Common random numbers across the fleet: ONE directions/prior
        # draw (exactly ServerRefiner's draw from the same key, so N=1
        # stays bit-identical) shared by every session — and, sharded,
        # by every shard: the key is replicated, so each shard draws the
        # same dirs/prior and per-session terms match the unsharded run.
        kd, kp = jax.random.split(key)
        dirs = random_directions(kd, cfg.n_dirs, z.shape[-1])
        prior = sphere_prior_samples(kp, z.shape[1], z.shape[-1])
        prior_q = diff_sort(prior @ dirs.T, axis=0)       # (W, M)
        losses, parts = jax.vmap(
            session_loss, in_axes=(None, 0, 0, 0, None, None))(
                params, z, mask, labels, dirs, prior_q)
        a_total = jnp.sum(active)
        if axis_name is not None:
            a_total = jax.lax.psum(a_total, axis_name)
        w = active / jnp.maximum(a_total, 1.0)
        parts = {k: jnp.sum(v * w) for k, v in parts.items()}
        loss = jnp.sum(losses * w)
        if axis_name is not None and axis_size > 1:
            # pre-scale so pmean(loss) == psum(local weighted sums):
            # the cross-shard mean-over-active-sessions estimator
            scale = jnp.float32(axis_size)
            loss = loss * scale
            parts = {k: v * scale for k, v in parts.items()}
        return loss, (losses, parts)

    return fleet_loss


@dataclass
class FleetRefinerState:
    params: dict
    opt_state: tuple
    step: int = 0


class FleetRefiner:
    """One hybrid-loss refinement step for the whole fleet in a single jit.

    See ``make_fleet_loss`` for the loss; one SGD step updates the shared
    head.  A ``FleetRefiner`` step over N=1 is numerically the
    ``ServerRefiner`` step (tested to fp32 tolerance in
    ``tests/test_fleet.py``).
    """

    def __init__(self, head_init, head_apply, *, cfg: HybridCfg = HybridCfg(),
                 lr=1e-2, seed=0):
        from repro.optim.sgd import sgd_init, sgd_update
        self.cfg = cfg
        self.head_apply = head_apply
        params = head_init(jax.random.PRNGKey(seed))
        self._sgd_update = sgd_update
        self.state = FleetRefinerState(params, sgd_init(params), 0)
        self.lr = lr
        self._grad = jax.jit(jax.value_and_grad(
            make_fleet_loss(head_apply, cfg), has_aux=True))

    def refine(self, key, fleet):
        """One fleet-wide step with ``key`` seeding the single
        fleet-shared SWD draw — pass ServerRefiner's key to reproduce its
        N=1 step exactly (the parity test does).

        -> (mean active loss, mean active parts, per-session losses (N,)).
        """
        z, mask, labels = fleet.snapshot()
        return self.refine_arrays(key, z, mask, labels, fleet.active)

    def refine_arrays(self, key, z, mask, labels, active):
        """Device-side step on a prepared snapshot (benchmark hot path)."""
        (loss, (losses, parts)), grads = self._grad(
            self.state.params, key, jnp.asarray(z), jnp.asarray(mask),
            jnp.asarray(labels), jnp.asarray(active, jnp.float32))
        self.apply_grads(grads)
        return (float(loss), {k: float(v) for k, v in parts.items()},
                np.asarray(losses))

    def apply_grads(self, grads):
        """Shared optimizer step — the sharded backend reuses this on its
        pmean'd gradients so both backends run the identical update math
        (the 1-shard bitwise-parity contract)."""
        params, opt_state = self._sgd_update(
            self.state.params, grads, self.state.opt_state, lr=self.lr,
            momentum=0.9)
        self.state = FleetRefinerState(params, opt_state, self.state.step + 1)
