"""Split execution engine.

1. ``SplitEngine`` — the paper's mechanism on the paper's model: run blocks
   [0, k) as the *edge stage*, INT8-quantize the boundary activation (the
   wire payload), run blocks [k, L) + head as the *server stage*.  One
   compiled executable per k, switched atomically at step boundaries
   (§4.2.2 "Atomic Transitions": recompiling/ switching between steps —
   never mid-block).

2. ``split_pipeline_podwise`` — the TPU-native adaptation: a 2-stage SPMD
   pipeline over the 'pod' mesh axis (shard_map + collective_permute),
   with the inter-stage activation optionally INT8 on the wire.  Stage
   boundary k = L/2 (SPMD requires equal stages; DESIGN.md §2 records this
   constraint).  This is the multi-pod dry-run's "paper technique" cell.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.models import audio_encoder as enc
from repro.quant.int8 import dequantize, fake_quant, quantize


class SplitEngine:
    """Compiled-per-k split executor for the audio encoder.

    Per-k executables are built lazily on first use: a session that only
    ever runs one k compiles 2 callables, not ``2·(L+1)`` — this is what
    keeps ``StreamSplitGateway`` startup O(1) in L.  Atomic-transition
    semantics are unchanged: each k still gets its own executable, and
    switching k selects a whole different compiled program at a step
    boundary, never mid-block.
    """

    def __init__(self, cfg: enc.AudioEncCfg, *, quantize_wire=True):
        self.cfg = cfg
        self.quantize_wire = quantize_wire
        self._edge = {}
        self._server = {}
        # The INT8 wire round-trip runs as its OWN jitted executable,
        # never fused into the edge/server stages: fusing it changes the
        # rounding of the affine chain, and the per-frame vs k-bucketed
        # bit-parity contract (tests/test_gateway.py) depends on both
        # paths quantizing with the same compiled program.  ``run``
        # quantizes per tensor (one scale/zero for its whole batch);
        # ``run_batch`` per sample — identical at B=1, which is exactly
        # the parity boundary.
        self._qdq_tensor = jax.jit(lambda a: dequantize(quantize(a)))
        self._qdq_sample = jax.jit(jax.vmap(lambda a: dequantize(quantize(a))))

    def _edge_exec(self, k):
        if k not in self._edge:
            self._edge[k] = jax.jit(partial(self._edge_fn, k))
        return self._edge[k]

    def _server_exec(self, k):
        if k not in self._server:
            self._server[k] = jax.jit(partial(self._server_fn, k))
        return self._server[k]

    def _edge_fn(self, k, params, mel):
        if k == 0:
            # k=0 is raw-input offload: the wire carries the model input and
            # the server runs the stem — matches boundary_bytes(cfg)[0].
            return mel
        x = enc.apply_stem(self.cfg, params, mel)
        x = enc.apply_blocks(self.cfg, params, x, 0, k)
        if k == self.cfg.n_blocks:
            return enc.apply_head(self.cfg, params, x)
        return x

    def _server_fn(self, k, params, x):
        if k == 0:
            x = enc.apply_stem(self.cfg, params, x)
        x = enc.apply_blocks(self.cfg, params, x, k, self.cfg.n_blocks)
        return enc.apply_head(self.cfg, params, x)

    def run(self, params, mel, k):
        """-> (embedding z, wire_bytes)."""
        L = self.cfg.n_blocks
        k = int(k)
        if k >= L:
            return self._edge_exec(L)(params, mel), 0
        act = self._edge_exec(k)(params, mel)
        if self.quantize_wire:
            wire_bytes = act.size + 8     # int8 payload + scale/zero header
            act = self._qdq_tensor(act)   # "received" on the server
        else:
            wire_bytes = act.size * 4
        z = self._server_exec(k)(params, act)
        return z, wire_bytes

    def run_batch(self, params, mel, k):
        """Run B frames that share one split index as ONE dispatch per stage.

        -> (z (B, d), wire_bytes per frame).  The serving hot path of
        ``api/gateway.py``: every session bucketed at the same k rides a
        single padded edge dispatch, a per-sample (vmapped) INT8 wire
        round-trip in its own executable, and a single server dispatch.
        Keeping the wire stage un-fused is what keeps the batch
        bit-identical to B separate ``run`` calls (see ``__init__``; the
        gateway parity test pins this).  Per-frame wire bytes equal
        ``run``'s on a single-frame batch: payload + 8-byte scale/zero
        header.
        """
        L = self.cfg.n_blocks
        k = int(k)
        if k >= L:
            return self._edge_exec(L)(params, mel), 0
        act = self._edge_exec(k)(params, mel)
        per_frame = act.size // act.shape[0]
        if self.quantize_wire:
            act = self._qdq_sample(act)
            wire_bytes = per_frame + 8    # int8 payload + scale/zero header
        else:
            wire_bytes = per_frame * 4
        z = self._server_exec(k)(params, act)
        return z, wire_bytes

    def run_batch_async(self, params, mel, k):
        """``run_batch`` without ever materializing on the host: accepts a
        device-resident mel batch, returns the **unmaterialized** device
        embedding — no block, no device→host copy.  The caller owns the
        tick's single sync point (``StreamSplitGateway.tick``), so B
        buckets overlap on the device instead of paying one round-trip
        each.

        The wire stage runs the fused Pallas ``wire_roundtrip`` kernel
        (``kernels/int8_quant.py``) — still its OWN executable, never
        fused into the edge/server stages, and pinned bitwise against the
        vmapped ``quantize∘dequantize`` reference that ``run_batch``
        executes — so embeddings stay bit-identical to both the PR-3 sync
        path and B separate ``run`` calls.
        """
        L = self.cfg.n_blocks
        k = int(k)
        if k >= L:
            return self._edge_exec(L)(params, mel), 0
        # k=0 offloads the raw input: _edge_fn(0) is the identity, so the
        # dispatch skips its executable entirely (bitwise no-op, one less
        # host->device program launch on the hot path)
        act = mel if k == 0 else self._edge_exec(k)(params, mel)
        per_frame = act.size // act.shape[0]
        if self.quantize_wire:
            act = kernel_ops.wire_roundtrip(act)
            wire_bytes = per_frame + 8    # int8 payload + scale/zero header
        else:
            wire_bytes = per_frame * 4
        z = self._server_exec(k)(params, act)
        return z, wire_bytes

    def full(self, params, mel):
        return self._edge_exec(self.cfg.n_blocks)(params, mel)


# ---------------------------------------------------------------------------
# Pod-axis 2-stage SPMD pipeline (the TPU adaptation of the split link)
# ---------------------------------------------------------------------------

def split_pipeline_podwise(mesh, stage_fn, params_stacked, x_microbatches,
                           *, quantize_wire=True, batch_axes=("data",)):
    """2-stage pipeline across the 'pod' axis.

    stage_fn(stage_params, h) -> h' applies half the layer stack; params
    are stacked (2, ...) and sharded so pod 0 holds stage 0 and pod 1
    stage 1.  Microbatches stream through: pod 0 computes stage 0 on
    microbatch t while pod 1 computes stage 1 on microbatch t-1; the
    boundary activation crosses the pod link via collective_permute,
    INT8-quantized (fake-quant in-graph; wire bytes = size/4).

    x_microbatches: (M, mb, ...) -> returns (M, mb, ...) stage-1 outputs.
    """
    P = jax.sharding.PartitionSpec
    M = x_microbatches.shape[0]
    n_pods = mesh.shape["pod"]
    assert n_pods == 2, "2-stage pipeline"

    def local_fn(xs, stage_params):
        # xs: (M, mb_local, ...) identical copy on both pods (batch sharded
        # over data axes only); stage_params: this pod's stage (leading dim 1)
        sp = jax.tree.map(lambda t: t[0], stage_params)
        pod = jax.lax.axis_index("pod")

        def step(carry, x_t):
            h_prev = carry
            # stage input: pod0 <- fresh microbatch, pod1 <- permuted act
            h_in = jnp.where(pod == 0, x_t, h_prev)
            h_out = stage_fn(sp, h_in)
            if quantize_wire:
                h_out = fake_quant(h_out)
            h_next = jax.lax.ppermute(h_out, "pod", [(0, 1)])
            # pod1's h_out is the finished microbatch
            return h_next, h_out

        pad = jnp.zeros_like(xs[0])
        xs_pad = jnp.concatenate([xs, pad[None]], 0)   # one drain step
        _, outs = jax.lax.scan(step, jnp.zeros_like(xs[0]), xs_pad)
        # finished outputs live on pod 1 at steps 1..M; broadcast to pod 0
        finished = outs[1:]
        finished = jnp.where(pod == 1, finished, jnp.zeros_like(finished))
        finished = jax.lax.psum(finished, "pod")
        return finished

    ndim = x_microbatches.ndim
    x_spec = P(None, batch_axes, *([None] * (ndim - 2)))
    in_specs = (x_spec, P("pod"))
    out_specs = x_spec
    from repro.compat import shard_map
    return shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(
        x_microbatches, params_stacked)
