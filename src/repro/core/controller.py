"""Deployed Control Plane: maps observed state -> split index, with the
paper's *atomic transition* semantics (decisions apply only to the next
T_step block; in-flight frames are never redone or dropped).

Policies:
  rl          PPO params from core/ppo.py (uncertainty-aware)
  rule        heuristic: offload iff BW > X AND CPU < Y  (Table 1/4)
  static      fixed k (Table 4's k=3)
  edge        k = L (Edge-Only baseline)
  server      k = 0 (Server-Only baseline)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.ppo import greedy_action


@dataclass
class RulePolicy:
    """Offload (shallow k) iff bandwidth high AND cpu free; else local.

    Reactive: re-estimates bandwidth with an EMA over probes, which is why
    its adaptation time is ~3.5x the RL agent's (Table 4)."""
    L: int
    bw_threshold: float = 0.12     # of BW_NORM (≈6 Mbps)
    cpu_threshold: float = 0.6
    offload_k: int = 2
    ema: float = 0.0
    ema_rate: float = 0.08         # slow probe-based estimate

    def __call__(self, obs):
        u, cpu, bw = obs
        self.ema = (1 - self.ema_rate) * self.ema + self.ema_rate * bw
        if self.ema > self.bw_threshold and cpu < self.cpu_threshold:
            return self.offload_k
        return self.L


class Controller:
    def __init__(self, kind, L, *, rl_params=None, static_k=3, t_step=10):
        self.kind = kind
        self.L = L
        self.rl_params = rl_params
        self.static_k = static_k
        self.t_step = t_step
        self.rule = RulePolicy(L)
        self.current_k = static_k if kind == "static" else L
        self.frame = 0
        self.transitions = 0

    def decide(self, obs):
        """Called once per decision interval (T_step frames). Returns the k
        to apply to the NEXT block — the atomic boundary."""
        if self.kind == "rl":
            k = greedy_action(self.rl_params, np.asarray(obs, np.float32))
        elif self.kind == "rule":
            k = self.rule(obs)
        elif self.kind == "static":
            k = self.static_k
        elif self.kind == "edge":
            k = self.L
        elif self.kind == "server":
            k = 0
        else:
            raise ValueError(self.kind)
        if k != self.current_k:
            self.transitions += 1
        self.current_k = int(k)
        return self.current_k


def run_episode(env, controller: Controller, *, quantize=True, seed=None):
    """Roll a policy through an env episode; returns env.summary()."""
    obs = env.reset(seed=seed)
    done = False
    while not done:
        k = controller.decide(obs)
        obs, _, done, _ = env.step(k, quantize=quantize)
    return env.summary()
