"""Diversity metric: Sliced-Wasserstein distance to the uniform
hypersphere prior (paper §3.1, Eq. 3).

Projects embeddings onto M random directions; the per-slice 1-D
Wasserstein-2 distance has the closed form  ∫|F_p^{-1} - F_q^{-1}|² dτ,
computed by sorting.  The uniform-on-S^{d-1} prior's slice quantiles are
drawn empirically (standard practice; exact inverse-CDF has no closed
form for general d).

Minimizing L_SW drives H(p_θ(z)) up — the anti-collapse "repulsive force"
that substitutes for large negative batches (Theorem 3.1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def random_directions(key, n_dirs, dim):
    w = jax.random.normal(key, (n_dirs, dim), jnp.float32)
    return w / jnp.linalg.norm(w, axis=-1, keepdims=True)


def sphere_prior_samples(key, n, dim):
    z = jax.random.normal(key, (n, dim), jnp.float32)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-9)


def diff_sort(x, axis=0):
    """Differentiable sort: argsort (constant indices) + gather.  Same
    subgradient as jnp.sort; works around this jaxlib's broken sort-JVP."""
    idx = jnp.argsort(jax.lax.stop_gradient(x), axis=axis)
    return jnp.take_along_axis(x, idx, axis=axis)


def sliced_w2(x, y, dirs):
    """Empirical SW₂² between point sets x (N,d), y (N,d) over `dirs` (M,d)."""
    px = diff_sort(x.astype(jnp.float32) @ dirs.T, axis=0)   # (N, M)
    py = diff_sort(y.astype(jnp.float32) @ dirs.T, axis=0)
    return jnp.mean(jnp.square(px - py))


def swd_to_uniform(key, z, *, n_dirs=50):
    """L_SW(p_θ, U(S^{d-1})) for a batch of embeddings z: (N, d)."""
    kd, kp = jax.random.split(key)
    dirs = random_directions(kd, n_dirs, z.shape[-1])
    prior = sphere_prior_samples(kp, z.shape[0], z.shape[-1])
    return sliced_w2(z, prior, dirs)


def swd_loss(key, z, *, n_dirs=50, axis_name=None):
    """Differentiable-through-sort SWD loss.

    With ``axis_name`` this is the *sharded* estimator: each data shard
    computes its local SWD against an equal-size prior draw and the results
    are pmean'd — an unbiased estimate of the global SWD for iid shards
    (DESIGN.md §2)."""
    val = swd_to_uniform(key, z, n_dirs=n_dirs)
    if axis_name is not None:
        val = jax.lax.pmean(val, axis_name)
    return val


def wasserstein1_1d(x, y):
    """Exact 1-D W₁ between equal-size samples (for tests/validation)."""
    return jnp.mean(jnp.abs(jnp.sort(x) - jnp.sort(y)))


def mmd_rbf(x, y, *, sigma=1.0):
    """Gaussian-kernel MMD² — the weaker baseline metric the paper compares
    against in §3.3 (r = 0.82 vs SWD's r = −0.96)."""
    def k(a, b):
        d2 = jnp.sum(jnp.square(a[:, None] - b[None]), -1)
        return jnp.exp(-d2 / (2 * sigma * sigma))
    return jnp.mean(k(x, x)) + jnp.mean(k(y, y)) - 2 * jnp.mean(k(x, y))
