"""Diversity metric: Sliced-Wasserstein distance to the uniform
hypersphere prior (paper §3.1, Eq. 3).

Projects embeddings onto M random directions; the per-slice 1-D
Wasserstein-2 distance has the closed form  ∫|F_p^{-1} - F_q^{-1}|² dτ,
computed by sorting.  The uniform-on-S^{d-1} prior's slice quantiles are
drawn empirically (standard practice; exact inverse-CDF has no closed
form for general d).

Minimizing L_SW drives H(p_θ(z)) up — the anti-collapse "repulsive force"
that substitutes for large negative batches (Theorem 3.1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def random_directions(key, n_dirs, dim):
    w = jax.random.normal(key, (n_dirs, dim), jnp.float32)
    return w / jnp.linalg.norm(w, axis=-1, keepdims=True)


def sphere_prior_samples(key, n, dim):
    z = jax.random.normal(key, (n, dim), jnp.float32)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-9)


def diff_sort(x, axis=0):
    """Differentiable sort: argsort (constant indices) + gather.  Same
    subgradient as jnp.sort; works around this jaxlib's broken sort-JVP."""
    idx = jnp.argsort(jax.lax.stop_gradient(x), axis=axis)
    return jnp.take_along_axis(x, idx, axis=axis)


_SORT_PAD = 3.0e38


def _bitonic_sort_with_perm(v):
    """Stable ascending sort of each column of v (N, M), N a power of two.

    A bitonic network whose partner exchange a[i ^ j] is a reshape+flip
    block swap (a row *gather* here makes XLA compile time explode
    combinatorially), carrying the permutation as a payload with an
    index tie-break so it stays a true permutation on equal values.
    The payload-free twin for Pallas lives in
    kernels/swd_kernel.py::_bitonic_sort_cols — keep exchange-step
    changes in sync.
    -> (sorted (N, M), perm (N, M)) with sorted[r, c] = v[perm[r, c], c].
    """
    N, M = v.shape
    assert (N & (N - 1)) == 0, "power of two"
    row = jax.lax.broadcasted_iota(jnp.int32, (N, 1), 0)
    idx = jax.lax.broadcasted_iota(jnp.int32, (N, M), 0)
    k = 2
    while k <= N:
        j = k // 2
        while j >= 1:
            swap = lambda a: jnp.flip(
                a.reshape(N // (2 * j), 2, j, M), 1).reshape(N, M)
            vp, ip = swap(v), swap(idx)
            keep_min = ((row & j) == 0) == ((row & k) == 0)
            less = (v < vp) | ((v == vp) & (idx < ip))   # stable total order
            take_self = keep_min == less
            v = jnp.where(take_self, v, vp)
            idx = jnp.where(take_self, idx, ip)
            j //= 2
        k *= 2
    return v, idx


@jax.custom_vjp
def bitonic_diff_sort(x):
    """``diff_sort(x, axis=0)`` for hot paths: identical values and
    (sub)gradient, but the forward runs a bitonic network instead of an
    XLA variadic sort (~5x faster per column batch on CPU) and the VJP is
    a single scatter through the recorded permutation.

    Inputs must be finite and below ~3e38: non-power-of-two heights pad
    with a +3.0e38 sentinel that must sort strictly last (NaN/inf would
    silently displace real rows — diff_sort handles those, this doesn't).
    """
    return _bitonic_sort_fwd(x)[0]


def _bitonic_sort_fwd(x):
    n, m = x.shape
    n_pow2 = 1 << max((n - 1).bit_length(), 0)
    v = x.astype(jnp.float32)
    if n_pow2 != n:   # +BIG pad rows sort to the bottom, then slice off
        v = jnp.concatenate(
            [v, jnp.full((n_pow2 - n, m), _SORT_PAD, jnp.float32)], 0)
    srt, perm = _bitonic_sort_with_perm(v)
    return srt[:n], (perm[:n], n)


def _bitonic_sort_bwd(res, g):
    perm, n = res
    cols = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1)
    return (jnp.zeros((n, g.shape[1]), g.dtype).at[perm, cols].set(g),)


bitonic_diff_sort.defvjp(_bitonic_sort_fwd, _bitonic_sort_bwd)


def sliced_w2(x, y, dirs):
    """Empirical SW₂² between point sets x (N,d), y (N,d) over `dirs` (M,d)."""
    px = diff_sort(x.astype(jnp.float32) @ dirs.T, axis=0)   # (N, M)
    py = diff_sort(y.astype(jnp.float32) @ dirs.T, axis=0)
    return jnp.mean(jnp.square(px - py))


def swd_to_uniform(key, z, *, n_dirs=50):
    """L_SW(p_θ, U(S^{d-1})) for a batch of embeddings z: (N, d)."""
    kd, kp = jax.random.split(key)
    dirs = random_directions(kd, n_dirs, z.shape[-1])
    prior = sphere_prior_samples(kp, z.shape[0], z.shape[-1])
    return sliced_w2(z, prior, dirs)


def swd_loss(key, z, *, n_dirs=50, axis_name=None):
    """Differentiable-through-sort SWD loss.

    With ``axis_name`` this is the *sharded* estimator: each data shard
    computes its local SWD against an equal-size prior draw and the results
    are pmean'd — an unbiased estimate of the global SWD for iid shards
    (DESIGN.md §2)."""
    val = swd_to_uniform(key, z, n_dirs=n_dirs)
    if axis_name is not None:
        val = jax.lax.pmean(val, axis_name)
    return val


def wasserstein1_1d(x, y):
    """Exact 1-D W₁ between equal-size samples (for tests/validation)."""
    return jnp.mean(jnp.abs(jnp.sort(x) - jnp.sort(y)))


def mmd_rbf(x, y, *, sigma=1.0):
    """Gaussian-kernel MMD² — the weaker baseline metric the paper compares
    against in §3.3 (r = 0.82 vs SWD's r = −0.96)."""
    def k(a, b):
        d2 = jnp.sum(jnp.square(a[:, None] - b[None]), -1)
        return jnp.exp(-d2 / (2 * sigma * sigma))
    return jnp.mean(k(x, x)) + jnp.mean(k(y, y)) - 2 * jnp.mean(k(x, y))
