"""Server Refiner (paper §4.3): temporal buffer with gap tolerance +
hybrid-loss refinement over the buffered manifold.

The buffer is a ring keyed by absolute frame index (window W=100 ≈ 1 s of
context).  Frames dropped by the splitter / network leave gaps; the
snapshot exposes a validity mask that the Laplacian term uses to "stitch"
across outages (Fig. 5) instead of hallucinating interpolations.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridCfg, hybrid_loss


class TemporalBuffer:
    def __init__(self, window=100, dim=128):
        self.window = window
        self.dim = dim
        self.z = np.zeros((window, dim), np.float32)
        # sentinel far below any reachable negative window index
        self.t = np.full((window,), -(1 << 60), np.int64)
        self.label = np.full((window,), -1, np.int64)
        self.newest = -1

    def insert(self, t, z, label=-1):
        slot = t % self.window
        self.z[slot] = np.asarray(z, np.float32)
        self.t[slot] = t
        self.label[slot] = label
        self.newest = max(self.newest, t)

    def snapshot(self):
        """-> (z (W, d), mask (W,), labels (W,)) in temporal order, where
        mask=0 marks gaps (never filled or expired)."""
        if self.newest < 0:
            return (np.zeros((self.window, self.dim), np.float32),
                    np.zeros((self.window,), np.float32),
                    np.full((self.window,), -1, np.int64))
        lo = self.newest - self.window + 1
        order = np.arange(lo, self.newest + 1)
        slots = order % self.window
        valid = (self.t[slots] == order)
        z = np.where(valid[:, None], self.z[slots], 0.0).astype(np.float32)
        labels = np.where(valid, self.label[slots], -1)
        return z, valid.astype(np.float32), labels

    @property
    def fill_fraction(self):
        _, m, _ = self.snapshot()
        return float(m.mean())


@dataclass
class RefinerState:
    params: dict
    opt_state: tuple
    step: int = 0


class ServerRefiner:
    """Optimizes L_server = L_task + λ₁ L_SW + λ₂ L_Lap over buffer
    snapshots.  ``head_apply(params, z) -> logits`` is the task head; when
    labels are absent, L_task falls back to buffer InfoNCE (paper §4.3.2).
    """

    def __init__(self, head_init, head_apply, *, cfg: HybridCfg = HybridCfg(),
                 lr=1e-2, seed=0):
        from repro.optim.sgd import sgd_init, sgd_update
        self.cfg = cfg
        self.head_apply = head_apply
        key = jax.random.PRNGKey(seed)
        params = head_init(key)
        self._sgd_update = sgd_update
        self.state = RefinerState(params, sgd_init(params), 0)
        self.lr = lr

        def loss_fn(params, key, z, mask, labels):
            logits = head_apply(params, z)
            have_labels = labels >= 0
            lab = jnp.maximum(labels, 0)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ce = -jnp.take_along_axis(logp, lab[:, None], 1)[:, 0]
            w = mask * have_labels.astype(jnp.float32)
            task = jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)
            reg, parts = hybrid_loss(key, z, cfg, mask=mask, variant="hybrid")
            # hybrid_loss's task term is 0 here (no pairs); add CE on top
            # (and report the CE, not hybrid_loss's zero placeholder)
            return task + reg, {**parts, "task": task}

        self._grad = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

    def refine(self, key, buffer: TemporalBuffer):
        z, mask, labels = buffer.snapshot()
        (loss, parts), grads = self._grad(
            self.state.params, key, jnp.asarray(z), jnp.asarray(mask),
            jnp.asarray(labels))
        params, opt_state = self._sgd_update(
            self.state.params, grads, self.state.opt_state, lr=self.lr,
            momentum=0.9)
        self.state = RefinerState(params, opt_state, self.state.step + 1)
        return float(loss), {k: float(v) for k, v in parts.items()}
