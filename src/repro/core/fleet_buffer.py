"""Host-side fleet session rings: N temporal buffers in dense arrays.

Extracted from the original ``core/fleet.py`` (which now re-exports this
module) when the fleet data plane grew a backend seam — ``FleetBuffer``
is the *host* storage implementation behind ``HostFleetBackend``
(``core/fleet_backend.py``); the device-resident sharded twin keeps the
same ``(N, W, d)`` layout as ``jax.Array``s on a ``sessions`` mesh axis.

Row semantics are identical to ``TemporalBuffer`` (same ``-(1 << 60)``
timestamp sentinel, same ring expiry, same gap-mask snapshot).
"""
from __future__ import annotations

import jax
import numpy as np

# Timestamp sentinel: far below any reachable negative window index, so an
# empty slot can never alias a real frame index (see test_fleet.py).
T_SENTINEL = -(1 << 60)


class FleetFullError(RuntimeError):
    """Raised by ``FleetBuffer.admit`` when every session row is in use."""


def as_host(x, dtype):
    """``np.asarray`` that treats ``jax.Array`` inputs as first-class:
    one device->host transfer, and no second conversion copy when the
    dtype already matches (the ingest hot path feeds float32 embeddings
    straight from the split engine)."""
    if isinstance(x, jax.Array):
        x = np.asarray(jax.device_get(x))
    else:
        x = np.asarray(x)
    return x if x.dtype == dtype else x.astype(dtype)


def pad_pow2(n):
    """Next power of two (1 for n <= 1) — pow2-padded batches keep the
    compile cache at O(log capacity) shapes per call site (gateway
    k-buckets, sharded fleet ingest)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class FleetBuffer:
    """N temporal ring buffers packed into dense arrays.

    Each *row* is one client session with ``TemporalBuffer`` semantics:
    frames keyed by absolute index ``t`` land in slot ``t % window``,
    older frames expire by overwrite, and ``snapshot`` returns the last
    ``window`` frames in temporal order with a validity (gap) mask.
    Admission hands out the lowest free row in O(1); eviction resets the
    row and returns it to the free-list in O(1).
    """

    def __init__(self, capacity=32, window=100, dim=128):
        self.capacity = capacity
        self.window = window
        self.dim = dim
        self.z = np.zeros((capacity, window, dim), np.float32)
        self.t = np.full((capacity, window), T_SENTINEL, np.int64)
        self.label = np.full((capacity, window), -1, np.int64)
        self.newest = np.full((capacity,), -1, np.int64)
        self.active = np.zeros((capacity,), bool)
        self._dirty = np.zeros((capacity,), bool)      # lazy wipe-on-admit
        self._free = list(range(capacity - 1, -1, -1))  # stack: pop -> row 0

    # -- session lifecycle (O(1)) -------------------------------------------
    @property
    def n_active(self):
        return int(self.active.sum())

    def admit(self):
        """-> session row id (sid).  Raises FleetFullError when full.

        O(1) except when re-admitting onto a row left dirty by ``evict``,
        which pays the deferred O(W·d) wipe here — a future tenant never
        sees the previous tenant's frames (tested against a clean-row
        oracle in ``tests/test_fleet.py``)."""
        if not self._free:
            raise FleetFullError(f"all {self.capacity} session rows in use")
        sid = self._free.pop()
        if self._dirty[sid]:
            self.z[sid] = 0.0
            self.t[sid] = T_SENTINEL
            self.label[sid] = -1
            self.newest[sid] = -1
            self._dirty[sid] = False
        self.active[sid] = True
        return sid

    def evict(self, sid):
        """Release a session row.  O(1) in *bytes* as well as bookkeeping:
        the row is only marked dirty — ``snapshot`` already masks inactive
        rows out of every consumer, and the wipe is deferred to the next
        ``admit`` of this row (lazy wipe-on-admit)."""
        if not self.active[sid]:
            raise KeyError(f"session {sid} is not active")
        self.active[sid] = False
        self._dirty[sid] = True
        self._free.append(sid)

    # -- ingest --------------------------------------------------------------
    def insert(self, sid, t, z, label=-1):
        if not self.active[sid]:
            raise KeyError(f"session {sid} is not active")
        slot = t % self.window
        self.z[sid, slot] = as_host(z, np.float32)
        self.t[sid, slot] = t
        self.label[sid, slot] = label
        self.newest[sid] = max(self.newest[sid], t)

    def insert_batch(self, sids, ts, zs, labels=None):
        """Vectorized ingest of one frame per (distinct) session.

        Accepts ``jax.Array`` inputs without an extra conversion copy
        (one device->host transfer, reused in place when the dtype
        already matches)."""
        sids = as_host(sids, np.int64)
        ts = as_host(ts, np.int64)
        if not self.active[sids].all():
            raise KeyError("insert_batch into inactive session")
        slots = ts % self.window
        self.z[sids, slots] = as_host(zs, np.float32)
        self.t[sids, slots] = ts
        if labels is None:
            self.label[sids, slots] = -1
        else:
            self.label[sids, slots] = as_host(labels, np.int64)
        np.maximum.at(self.newest, sids, ts)

    # -- row migration (cluster federation) ----------------------------------
    def export_row(self, sid):
        """Copy one session row out of the dense rings:
        ``(z (W, d), t (W,), label (W,), newest)`` — everything the fleet
        knows about the session, self-contained (the migration payload of
        ``cluster/snapshot.py``).  Arrays are copies: the snapshot stays
        frozen while the row keeps serving."""
        if not self.active[sid]:
            raise KeyError(f"session {sid} is not active")
        return (self.z[sid].copy(), self.t[sid].copy(),
                self.label[sid].copy(), int(self.newest[sid]))

    def import_row(self, sid, z, t, label, newest):
        """Implant an exported row into an (already admitted) session
        slot — the inverse of ``export_row``, bit-exact: a snapshot
        round-trip reproduces the row's refine contribution and
        ``fill_fraction`` identically."""
        if not self.active[sid]:
            raise KeyError(f"session {sid} is not active")
        if z.shape != (self.window, self.dim):
            raise ValueError(
                f"row shape {z.shape} != ({self.window}, {self.dim}) — "
                "migrating between fleets with different window/dim is "
                "not supported")
        self.z[sid] = as_host(z, np.float32)
        self.t[sid] = as_host(t, np.int64)
        self.label[sid] = as_host(label, np.int64)
        self.newest[sid] = int(newest)

    # -- snapshot ------------------------------------------------------------
    def snapshot(self):
        """-> (z (N, W, d), mask (N, W), labels (N, W)) in temporal order.

        mask=0 marks gaps, expired frames, empty sessions, and every slot
        of inactive rows — exactly the weights the vmapped loss consumes.
        """
        N, W = self.capacity, self.window
        lo = self.newest - W + 1                       # (N,)
        order = lo[:, None] + np.arange(W)[None, :]     # (N, W)
        slots = order % W
        rows = np.arange(N)[:, None]
        valid = (self.t[rows, slots] == order)
        valid &= (self.newest >= 0)[:, None] & self.active[:, None]
        z = np.where(valid[:, :, None], self.z[rows, slots], 0.0)
        labels = np.where(valid, self.label[rows, slots], -1)
        return z.astype(np.float32), valid.astype(np.float32), labels

    def fill_fraction(self, sid):
        """Fraction of this session's window that holds live frames —
        O(W) from the timestamp ring, no fleet-wide snapshot."""
        if not self.active[sid] or self.newest[sid] < 0:
            return 0.0
        order = np.arange(self.newest[sid] - self.window + 1,
                          self.newest[sid] + 1)
        return float((self.t[sid, order % self.window] == order).mean())
