"""Contrastive objectives: streaming InfoNCE with GMM virtual negatives
(paper Eq. 10) and the standard large-batch InfoNCE used by the server
(L_task) and the Server-Only / FedCL baselines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gmm as gmm_mod


def cosine(a, b):
    a = a / jnp.maximum(jnp.linalg.norm(a, axis=-1, keepdims=True), 1e-9)
    b = b / jnp.maximum(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-9)
    return jnp.sum(a * b, axis=-1)


def streaming_infonce(z, z_pos, z_neg, *, tau=0.1):
    """Eq. 10.  z, z_pos: (B, d); z_neg: (B, N_syn, d) virtual negatives.

    -log  exp(s⁺/τ) / (exp(s⁺/τ) + Σ_j exp(s⁻_j/τ))
    """
    zn = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-9)
    pos = cosine(z, z_pos) / tau                              # (B,)
    negs = jnp.einsum("bd,bnd->bn", zn.astype(jnp.float32),
                      z_neg.astype(jnp.float32)) / tau        # (B, N)
    logits = jnp.concatenate([pos[:, None], negs], axis=1)
    return jnp.mean(jax.nn.logsumexp(logits, axis=1) - pos)


def infonce_with_virtual_negatives(key, gmm_state, z, z_pos, *,
                                   n_syn=256, tau=0.1, boundary_tau=0.1,
                                   use_batch_negatives=True):
    """The edge objective: sample boundary-aware virtual negatives from the
    GMM, compute Eq. 10, and *discard* the negatives (no memory bank).

    ``use_batch_negatives`` additionally appends the (N-1) other in-batch
    embeddings to the denominator.  This is a zero-memory-cost robustness
    fix beyond the paper: Eq. 9's ``c != c*`` exclusion means frames lumped
    into the SAME component never repel each other, so a collapsed
    embedding cannot escape through virtual negatives alone (EXPERIMENTS.md
    §Fig8 documents the ablation).  The resident batch supplies exactly the
    within-component repulsion that closes this hole."""
    z_neg = gmm_mod.sample_virtual_negatives(
        key, gmm_state, jax.lax.stop_gradient(z), n_syn, tau=boundary_tau)
    z_neg = jax.lax.stop_gradient(z_neg)
    if use_batch_negatives:
        B = z.shape[0]
        zn = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True),
                             1e-9)
        # gradients DO flow through the in-batch negatives: one-sided
        # (stop-grad) repulsion from a shared negative cloud has a net
        # drift toward its antipode — symmetric repulsion is what keeps
        # the loss collapse-free (see tests/test_infonce.py).
        others = jnp.broadcast_to(zn[None], (B, B, z.shape[-1]))
        # mask self-pairs by replacing own row with the antipode of z_pos
        # (an always-easy negative, contributes ~0 to the denominator)
        eye = jnp.eye(B, dtype=bool)[..., None]
        filler = -z_pos[:, None, :]
        others = jnp.where(eye, jax.lax.stop_gradient(filler), others)
        z_neg = jnp.concatenate([z_neg, others], axis=1)
    return streaming_infonce(z, z_pos, z_neg, tau=tau)


def batch_infonce(z1, z2, *, tau=0.1):
    """Standard NT-Xent over a batch (SimCLR-style, both directions).

    z1, z2: (B, d) two views. Requires B > 1 — this is exactly the
    large-batch dependency (C1) that StreamSplit removes on the edge."""
    B = z1.shape[0]
    z1 = z1 / jnp.maximum(jnp.linalg.norm(z1, axis=-1, keepdims=True), 1e-9)
    z2 = z2 / jnp.maximum(jnp.linalg.norm(z2, axis=-1, keepdims=True), 1e-9)
    logits = (z1.astype(jnp.float32) @ z2.astype(jnp.float32).T) / tau
    labels = jnp.arange(B)
    l12 = -jnp.mean(jax.nn.log_softmax(logits, axis=1)[labels, labels])
    l21 = -jnp.mean(jax.nn.log_softmax(logits, axis=0)[labels, labels])
    return 0.5 * (l12 + l21)
