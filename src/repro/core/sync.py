"""Lazy Synchronization protocol (paper §4.3.3).

Downlink: GMM parameters (<35 KB) every T_sync=100 frames.  Encoder
weights are only pushed when the device reports a charging state or a
high-bandwidth link.  The tracker accounts bytes and energy so the
evaluation includes sync overhead (the paper's +0.4 mJ/frame).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class SyncCfg:
    t_sync_frames: int = 100
    t_weights_min_frames: int = 2000      # throttle weight pushes
    gmm_bytes: int = 33 * 1024
    encoder_bytes: int = 11_000_000 * 2   # ~11M params fp16
    wifi_mbps_threshold: float = 25.0
    joules_per_byte_down: float = 1.0e-6  # downlink cheaper than uplink


@dataclass
class SyncEvent:
    kind: str      # "gmm" | "weights"
    frame: int
    bytes: int
    energy_j: float
    # wall-clock of the emitting tick, from the CALLER's clock (the
    # gateway threads its injectable ``clock=`` through ``on_frame``'s
    # ``now=`` so sync timelines are deterministic under a fake clock —
    # 0.0 when the caller tracks frames only)
    at_s: float = 0.0


class LazySync:
    def __init__(self, cfg: SyncCfg = SyncCfg()):
        self.cfg = cfg
        self.last_gmm = 0
        self.last_weights = -cfg.t_weights_min_frames
        self.total_bytes = 0
        self.total_energy_j = 0.0
        self.events: list[SyncEvent] = []

    def on_frame(self, frame, *, charging=False, bandwidth_mbps=0.0,
                 now=0.0):
        out = []
        if frame - self.last_gmm >= self.cfg.t_sync_frames:
            out.append(self._emit("gmm", frame, self.cfg.gmm_bytes, now))
            self.last_gmm = frame
        if ((charging or bandwidth_mbps >= self.cfg.wifi_mbps_threshold)
                and frame - self.last_weights >= self.cfg.t_weights_min_frames):
            out.append(self._emit("weights", frame, self.cfg.encoder_bytes,
                                  now))
            self.last_weights = frame
        return out

    def _emit(self, kind, frame, nbytes, now=0.0):
        e = SyncEvent(kind, frame, nbytes,
                      nbytes * self.cfg.joules_per_byte_down, at_s=now)
        self.total_bytes += nbytes
        self.total_energy_j += e.energy_j
        self.events.append(e)
        return e

    def energy_mj_per_frame(self, frames):
        return 1e3 * self.total_energy_j / max(frames, 1)
