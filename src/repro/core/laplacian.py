"""Affinity metric: temporal-graph Dirichlet energy (paper §3.2, Eq. 4/6/14).

The temporal graph connects each frame to its k nearest *temporal*
neighbours (|i−j| ≤ k).  Missing frames (dropped by the splitter or the
network) are expressed with a validity mask: edges touching a missing
frame vanish, which is exactly the paper's "buffer with temporal gaps".

Minimizing the energy is the "manifold stitching" spring force
(Fig. 5); Theorem 3.2's interpolation bound is implemented in
``interpolation_error_bound`` and property-tested.

Theorem 3.2 regime (documented here per the test-debt note): the bound
Eq. 5 only holds for *sparse* temporal graphs, ``2k < T``.  As the window
approaches the trajectory length the graph becomes complete, λ₂ stops
separating local from global structure, and the bound is genuinely
violated (not a numerical artifact — see ``tests/test_laplacian.py``).
``interpolation_error_bound`` warns when asked to evaluate a
near-complete graph.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np


def dirichlet_energy(z, *, k=5, mask=None, weights=None):
    """(1/|E|) Σ_{(i,j)∈E} w_ij ||z_i − z_j||²  over the temporal k-window.

    z: (T, d) or (B, T, d); mask: matching (T,)/(B, T) validity (1=present).
    """
    batched = z.ndim == 3
    if not batched:
        z = z[None]
        if mask is not None:
            mask = mask[None]
    B, T, d = z.shape
    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)
    mask = mask.astype(jnp.float32)
    z = z.astype(jnp.float32)
    total = jnp.float32(0.0)
    count = jnp.float32(0.0)
    for delta in range(1, min(k, T - 1) + 1):
        w = 1.0 if weights is None else weights[delta - 1]
        diff = z[:, delta:] - z[:, :-delta]
        pair = mask[:, delta:] * mask[:, :-delta]
        total = total + w * jnp.sum(jnp.sum(jnp.square(diff), -1) * pair)
        count = count + jnp.sum(pair)
    return total / jnp.maximum(count, 1.0)


def laplacian_loss(z, *, k=5, mask=None):
    """L_Lap (Eq. 14) — alias with the paper's name."""
    return dirichlet_energy(z, k=k, mask=mask)


# ---------------------------------------------------------------------------
# Dense-graph utilities (validation / theorem checks — numpy-scale)
# ---------------------------------------------------------------------------

def temporal_adjacency(T, k=5, mask=None):
    """Dense (T, T) adjacency of the temporal k-window graph."""
    idx = np.arange(T)
    A = (np.abs(idx[:, None] - idx[None, :]) <= k) & (idx[:, None] != idx[None, :])
    A = A.astype(np.float64)
    if mask is not None:
        m = np.asarray(mask, np.float64)
        A = A * m[:, None] * m[None, :]
    return A


def graph_laplacian(A):
    return np.diag(A.sum(1)) - A


def spectral_gap(A):
    """λ₂ of the Laplacian (second-smallest eigenvalue)."""
    L = graph_laplacian(A)
    ev = np.linalg.eigvalsh(L)
    return float(ev[1])


def dirichlet_energy_dense(z, A):
    """Tr(ZᵀLZ)/|E| against an explicit adjacency (oracle for tests)."""
    z = np.asarray(z, np.float64)
    L = graph_laplacian(A)
    e = float(np.trace(z.T @ L @ z))
    n_edges = A.sum()  # directed count = 2|E|; energy double-counts too
    return e / max(n_edges / 1.0, 1.0) * (1.0 if n_edges else 0.0)


def neighbor_average(z, A, t):
    """ẑ_t = weighted neighbour average (Theorem 3.2's reconstruction)."""
    w = A[t]
    deg = w.sum()
    return (w @ z) / max(deg, 1e-12)


def interpolation_error_bound(z, A, t):
    """RHS of Eq. 5: 2·α·|E| / (λ₂·|N(t)|) with α = Tr(ZᵀLZ)/|E|.

    Only valid in Theorem 3.2's sparse-graph regime ``2k < T`` (see the
    module docstring).  The guard recovers the window size from the first
    node that has any edges — for a temporal k-window graph a *boundary*
    node's degree is ~``min(k, T_eff - 1)`` — and compares against the
    count of participating (unmasked) nodes, so masked graphs are judged
    on their effective trajectory length.  When ``2k >= T_eff`` the
    window spans most of the trajectory, the graph is near-complete, and
    the returned value is NOT a valid bound — a ``UserWarning`` is
    issued.
    """
    z = np.asarray(z, np.float64)
    A = np.asarray(A, np.float64)
    deg = (A > 0).sum(axis=1)
    live = np.where(deg > 0)[0]
    if live.size > 1:
        t_eff = int(live.size)
        k_est = int(deg[live[0]])
        if 2 * k_est >= t_eff:
            warnings.warn(
                "interpolation_error_bound: temporal window k="
                f"{k_est} with T={t_eff} participating frames violates "
                "Theorem 3.2's sparse-graph regime (2k < T); the graph is "
                "near-complete and the returned value is not a valid "
                "bound.", UserWarning, stacklevel=2)
    L = graph_laplacian(A)
    tr = float(np.trace(z.T @ L @ z)) / 2.0  # undirected total energy
    n_edges = A.sum() / 2.0
    alpha = tr / max(n_edges, 1e-12)
    lam2 = spectral_gap(A)
    deg = A[t].sum()
    return 2.0 * alpha * n_edges / max(lam2 * deg, 1e-12)
