"""PPO for the Uncertainty-Guided Adaptive Splitter (paper §4.2.3).

Pure-JAX PPO (clipped objective, GAE) with the paper's lightweight
policy: a two-layer MLP whose first layer is *shared* between the policy
and value heads.  Trained offline on simulator traces (core/env.py) across
platforms/network profiles, deployed label-free (state-only).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PPOCfg:
    hidden: int = 64
    lr: float = 3e-4
    gamma: float = 0.99
    lam: float = 0.95
    clip: float = 0.2
    epochs: int = 4
    minibatch: int = 256
    steps_per_iter: int = 2048
    iters: int = 40
    ent_coef: float = 0.01
    vf_coef: float = 0.5
    seed: int = 0


def init_policy(key, obs_dim, n_actions, hidden=64):
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda k, shp: (1.0 / np.sqrt(shp[0])) * jax.random.normal(k, shp)
    return {
        "w1": s(k1, (obs_dim, hidden)), "b1": jnp.zeros((hidden,)),
        "wp": 0.01 * s(k2, (hidden, n_actions)), "bp": jnp.zeros((n_actions,)),
        "wv": s(k3, (hidden, 1)), "bv": jnp.zeros((1,)),
    }


def policy_apply(params, obs):
    h = jnp.tanh(obs @ params["w1"] + params["b1"])   # shared first layer
    logits = h @ params["wp"] + params["bp"]
    value = (h @ params["wv"] + params["bv"])[..., 0]
    return logits, value


@jax.jit
def _act(params, obs, key):
    logits, value = policy_apply(params, obs)
    a = jax.random.categorical(key, logits)
    logp = jax.nn.log_softmax(logits)[a]
    return a, logp, value


def greedy_action(params, obs):
    logits, _ = policy_apply(params, jnp.asarray(obs, jnp.float32))
    return int(jnp.argmax(logits))


def gae(rewards, values, dones, last_value, gamma, lam):
    T = len(rewards)
    adv = np.zeros(T, np.float32)
    last = 0.0
    next_v = last_value
    for t in reversed(range(T)):
        nonterm = 1.0 - dones[t]
        delta = rewards[t] + gamma * next_v * nonterm - values[t]
        last = delta + gamma * lam * nonterm * last
        adv[t] = last
        next_v = values[t]
    return adv, adv + values


@partial(jax.jit, static_argnames=("clip", "ent_coef", "vf_coef", "lr"))
def _update(params, opt_state, batch, *, clip, ent_coef, vf_coef, lr):
    def loss_fn(p):
        logits, value = policy_apply(p, batch["obs"])
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, batch["act"][:, None], 1)[:, 0]
        ratio = jnp.exp(logp - batch["logp"])
        adv = batch["adv"]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        pg = -jnp.mean(jnp.minimum(
            ratio * adv, jnp.clip(ratio, 1 - clip, 1 + clip) * adv))
        vf = jnp.mean(jnp.square(value - batch["ret"]))
        ent = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, -1))
        return pg + vf_coef * vf - ent_coef * ent, (pg, vf, ent)

    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    # inline Adam
    m, v, step = opt_state
    step = step + 1
    m = jax.tree.map(lambda a, g: 0.9 * a + 0.1 * g, m, grads)
    v = jax.tree.map(lambda a, g: 0.999 * a + 0.001 * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - 0.9 ** step), m)
    vh = jax.tree.map(lambda a: a / (1 - 0.999 ** step), v)
    params = jax.tree.map(
        lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh)
    return params, (m, v, step), loss


def train_ppo(env_factory, n_actions, cfg: PPOCfg = PPOCfg(), *,
              obs_dim=3, verbose=False):
    """env_factory() -> fresh env (cycled across profiles by the caller)."""
    key = jax.random.PRNGKey(cfg.seed)
    key, k0 = jax.random.split(key)
    params = init_policy(k0, obs_dim, n_actions, cfg.hidden)
    zeros = jax.tree.map(jnp.zeros_like, params)
    opt_state = (zeros, jax.tree.map(jnp.zeros_like, params), jnp.int32(0))
    env = env_factory()
    obs = env.reset()
    history = []
    rng = np.random.default_rng(cfg.seed)

    for it in range(cfg.iters):
        T = cfg.steps_per_iter
        buf = {k: np.zeros((T,) + s, np.float32) for k, s in
               [("obs", (obs_dim,)), ("logp", ()), ("adv", ()), ("ret", ())]}
        buf["act"] = np.zeros((T,), np.int32)
        rewards = np.zeros(T, np.float32)
        values = np.zeros(T, np.float32)
        dones = np.zeros(T, np.float32)
        ep_rews = []
        ep_acc = 0.0
        for t in range(T):
            key, ka = jax.random.split(key)
            a, logp, v = _act(params, jnp.asarray(obs), ka)
            a = int(a)
            buf["obs"][t] = obs
            buf["act"][t] = a
            buf["logp"][t] = float(logp)
            values[t] = float(v)
            obs, r, done, info = env.step(a)
            rewards[t] = r
            ep_acc += r
            dones[t] = float(done)
            if done:
                ep_rews.append(ep_acc)
                ep_acc = 0.0
                env = env_factory()
                obs = env.reset(seed=int(rng.integers(1 << 31)))
        _, last_v = policy_apply(params, jnp.asarray(obs))
        adv, ret = gae(rewards, values, dones, float(last_v),
                       cfg.gamma, cfg.lam)
        buf["adv"], buf["ret"] = adv, ret

        idx = np.arange(T)
        for _ in range(cfg.epochs):
            rng.shuffle(idx)
            for s in range(0, T, cfg.minibatch):
                mb = idx[s:s + cfg.minibatch]
                batch = {k: jnp.asarray(v[mb]) for k, v in buf.items()}
                params, opt_state, loss = _update(
                    params, opt_state, batch, clip=cfg.clip,
                    ent_coef=cfg.ent_coef, vf_coef=cfg.vf_coef, lr=cfg.lr)
        mean_rew = float(np.mean(ep_rews)) if ep_rews else float(rewards.sum())
        history.append(mean_rew)
        if verbose:
            print(f"[ppo] iter {it:3d}  mean episode reward {mean_rew:9.2f}")
    return params, history
