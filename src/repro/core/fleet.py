"""Fleet serving: many concurrent client sessions, one refinement step.

The single-stream path (``core/server.py``) refines one ``TemporalBuffer``
per ``ServerRefiner.refine`` call — fine for a demo, hopeless for the
ROADMAP's "millions of users" regime where the server juggles thousands of
parallel split-learning sessions (cf. parallel split learning: EPSL /
AdaSplit).  This module packs the whole fleet into dense arrays so the
server does ONE device dispatch per refinement round:

- ``FleetBuffer`` — N session rings in ``(N, W, d)`` / ``(N, W)`` arrays
  with per-session write cursors, gap masks and O(1) admission/eviction
  through a free-list.  Row semantics are identical to ``TemporalBuffer``
  (same ``-(1 << 60)`` timestamp sentinel, same ring expiry, same
  gap-mask snapshot).
- ``FleetRefiner`` — the ServerRefiner hybrid loss vmapped over the
  session axis inside a single jit: one fleet-shared SWD draw (common
  random numbers), mask-weighted task/Laplacian terms (the SWD term sees
  gap-zeroed embeddings, exactly as in ServerRefiner), inactive rows
  weighted out of the gradient, one optimizer update for the shared head.

A ``FleetRefiner`` step over N=1 is numerically the ``ServerRefiner``
step (tested to fp32 tolerance in ``tests/test_fleet.py``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridCfg
from repro.core.laplacian import laplacian_loss
from repro.core.swd import (bitonic_diff_sort, diff_sort, random_directions,
                            sphere_prior_samples)

# Timestamp sentinel: far below any reachable negative window index, so an
# empty slot can never alias a real frame index (see test_fleet.py).
T_SENTINEL = -(1 << 60)


class FleetFullError(RuntimeError):
    """Raised by ``FleetBuffer.admit`` when every session row is in use."""


class FleetBuffer:
    """N temporal ring buffers packed into dense arrays.

    Each *row* is one client session with ``TemporalBuffer`` semantics:
    frames keyed by absolute index ``t`` land in slot ``t % window``,
    older frames expire by overwrite, and ``snapshot`` returns the last
    ``window`` frames in temporal order with a validity (gap) mask.
    Admission hands out the lowest free row in O(1); eviction resets the
    row and returns it to the free-list in O(1).
    """

    def __init__(self, capacity=32, window=100, dim=128):
        self.capacity = capacity
        self.window = window
        self.dim = dim
        self.z = np.zeros((capacity, window, dim), np.float32)
        self.t = np.full((capacity, window), T_SENTINEL, np.int64)
        self.label = np.full((capacity, window), -1, np.int64)
        self.newest = np.full((capacity,), -1, np.int64)
        self.active = np.zeros((capacity,), bool)
        self._dirty = np.zeros((capacity,), bool)      # lazy wipe-on-admit
        self._free = list(range(capacity - 1, -1, -1))  # stack: pop -> row 0

    # -- session lifecycle (O(1)) -------------------------------------------
    @property
    def n_active(self):
        return int(self.active.sum())

    def admit(self):
        """-> session row id (sid).  Raises FleetFullError when full.

        O(1) except when re-admitting onto a row left dirty by ``evict``,
        which pays the deferred O(W·d) wipe here — a future tenant never
        sees the previous tenant's frames (tested against a clean-row
        oracle in ``tests/test_fleet.py``)."""
        if not self._free:
            raise FleetFullError(f"all {self.capacity} session rows in use")
        sid = self._free.pop()
        if self._dirty[sid]:
            self.z[sid] = 0.0
            self.t[sid] = T_SENTINEL
            self.label[sid] = -1
            self.newest[sid] = -1
            self._dirty[sid] = False
        self.active[sid] = True
        return sid

    def evict(self, sid):
        """Release a session row.  O(1) in *bytes* as well as bookkeeping:
        the row is only marked dirty — ``snapshot`` already masks inactive
        rows out of every consumer, and the wipe is deferred to the next
        ``admit`` of this row (lazy wipe-on-admit)."""
        if not self.active[sid]:
            raise KeyError(f"session {sid} is not active")
        self.active[sid] = False
        self._dirty[sid] = True
        self._free.append(sid)

    # -- ingest --------------------------------------------------------------
    def insert(self, sid, t, z, label=-1):
        if not self.active[sid]:
            raise KeyError(f"session {sid} is not active")
        slot = t % self.window
        self.z[sid, slot] = np.asarray(z, np.float32)
        self.t[sid, slot] = t
        self.label[sid, slot] = label
        self.newest[sid] = max(self.newest[sid], t)

    def insert_batch(self, sids, ts, zs, labels=None):
        """Vectorized ingest of one frame per (distinct) session."""
        sids = np.asarray(sids, np.int64)
        ts = np.asarray(ts, np.int64)
        if not self.active[sids].all():
            raise KeyError("insert_batch into inactive session")
        slots = ts % self.window
        self.z[sids, slots] = np.asarray(zs, np.float32)
        self.t[sids, slots] = ts
        if labels is None:
            self.label[sids, slots] = -1
        else:
            self.label[sids, slots] = np.asarray(labels, np.int64)
        np.maximum.at(self.newest, sids, ts)

    # -- snapshot ------------------------------------------------------------
    def snapshot(self):
        """-> (z (N, W, d), mask (N, W), labels (N, W)) in temporal order.

        mask=0 marks gaps, expired frames, empty sessions, and every slot
        of inactive rows — exactly the weights the vmapped loss consumes.
        """
        N, W = self.capacity, self.window
        lo = self.newest - W + 1                       # (N,)
        order = lo[:, None] + np.arange(W)[None, :]     # (N, W)
        slots = order % W
        rows = np.arange(N)[:, None]
        valid = (self.t[rows, slots] == order)
        valid &= (self.newest >= 0)[:, None] & self.active[:, None]
        z = np.where(valid[:, :, None], self.z[rows, slots], 0.0)
        labels = np.where(valid, self.label[rows, slots], -1)
        return z.astype(np.float32), valid.astype(np.float32), labels

    def fill_fraction(self, sid):
        """Fraction of this session's window that holds live frames —
        O(W) from the timestamp ring, no fleet-wide snapshot."""
        if not self.active[sid] or self.newest[sid] < 0:
            return 0.0
        order = np.arange(self.newest[sid] - self.window + 1,
                          self.newest[sid] + 1)
        return float((self.t[sid, order % self.window] == order).mean())


@dataclass
class FleetRefinerState:
    params: dict
    opt_state: tuple
    step: int = 0


class FleetRefiner:
    """One hybrid-loss refinement step for the whole fleet in a single jit.

    Per-session losses reuse the exact ``ServerRefiner`` math (masked CE
    task term when sparse labels exist, SWD + Laplacian regularizers over
    the gap-masked snapshot) vmapped over the session axis.  The SWD
    directions/prior are drawn ONCE per step and shared by every session
    (common random numbers — see fleet_loss).  Session losses are
    averaged over *active* rows only and one SGD step updates the shared
    head.
    """

    def __init__(self, head_init, head_apply, *, cfg: HybridCfg = HybridCfg(),
                 lr=1e-2, seed=0):
        from repro.optim.sgd import sgd_init, sgd_update
        self.cfg = cfg
        self.head_apply = head_apply
        params = head_init(jax.random.PRNGKey(seed))
        self._sgd_update = sgd_update
        self.state = FleetRefinerState(params, sgd_init(params), 0)
        self.lr = lr

        def session_loss(params, z, mask, labels, dirs, prior_q):
            # per-session math identical to ServerRefiner's loss_fn (the
            # N=1 parity test pins this); the SWD slice quantile targets
            # arrive precomputed
            logits = head_apply(params, z)
            have_labels = labels >= 0
            lab = jnp.maximum(labels, 0)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ce = -jnp.take_along_axis(logp, lab[:, None], 1)[:, 0]
            w = mask * have_labels.astype(jnp.float32)
            task = jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)
            px = bitonic_diff_sort(z.astype(jnp.float32) @ dirs.T)
            sw = jnp.mean(jnp.square(px - prior_q))
            lap = laplacian_loss(z, k=cfg.knn, mask=mask)
            loss = task + cfg.lam_sw * sw + cfg.lam_lap * lap
            return loss, {"task": task, "sw": sw, "lap": lap}

        def fleet_loss(params, key, z, mask, labels, active):
            # Common random numbers across the fleet: ONE directions/prior
            # draw (exactly ServerRefiner's draw from the same key, so N=1
            # stays bit-identical) shared by every session.  Besides
            # variance reduction, this sorts the prior slice quantiles once
            # instead of once per session — the sequential path's dominant
            # cost after the data sort itself.
            kd, kp = jax.random.split(key)
            dirs = random_directions(kd, cfg.n_dirs, z.shape[-1])
            prior = sphere_prior_samples(kp, z.shape[1], z.shape[-1])
            prior_q = diff_sort(prior @ dirs.T, axis=0)       # (W, M)
            losses, parts = jax.vmap(
                session_loss, in_axes=(None, 0, 0, 0, None, None))(
                    params, z, mask, labels, dirs, prior_q)
            w = active / jnp.maximum(jnp.sum(active), 1.0)
            parts = {k: jnp.sum(v * w) for k, v in parts.items()}
            return jnp.sum(losses * w), (losses, parts)

        self._grad = jax.jit(jax.value_and_grad(fleet_loss, has_aux=True))

    def refine(self, key, fleet: FleetBuffer):
        """One fleet-wide step with ``key`` seeding the single
        fleet-shared SWD draw — pass ServerRefiner's key to reproduce its
        N=1 step exactly (the parity test does).

        -> (mean active loss, mean active parts, per-session losses (N,)).
        """
        z, mask, labels = fleet.snapshot()
        return self.refine_arrays(key, z, mask, labels, fleet.active)

    def refine_arrays(self, key, z, mask, labels, active):
        """Device-side step on a prepared snapshot (benchmark hot path)."""
        (loss, (losses, parts)), grads = self._grad(
            self.state.params, key, jnp.asarray(z), jnp.asarray(mask),
            jnp.asarray(labels), jnp.asarray(active, jnp.float32))
        params, opt_state = self._sgd_update(
            self.state.params, grads, self.state.opt_state, lr=self.lr,
            momentum=0.9)
        self.state = FleetRefinerState(params, opt_state, self.state.step + 1)
        return (float(loss), {k: float(v) for k, v in parts.items()},
                np.asarray(losses))
