"""Compatibility facade over the fleet data plane.

The original single-module fleet layer was split along the backend seam:

- ``core/fleet_buffer.py``  — host-side ``FleetBuffer`` session rings;
- ``core/fleet_refiner.py`` — ``FleetRefiner`` + the shared
  ``make_fleet_loss`` builder (with the cross-shard ``axis_name`` hooks);
- ``core/fleet_backend.py`` — the ``FleetBackend`` abstraction:
  ``HostFleetBackend`` (the old path) and ``ShardedFleetBackend``
  (device-resident rings over a ``sessions`` mesh axis).

Every pre-split import keeps working through this module.
"""
from repro.core.fleet_backend import (FleetBackend, HostFleetBackend,
                                      ShardedFleetBackend, T_SENTINEL_DEV,
                                      make_backend)
from repro.core.fleet_buffer import (FleetBuffer, FleetFullError, T_SENTINEL,
                                     as_host, pad_pow2)
from repro.core.fleet_refiner import (FleetRefiner, FleetRefinerState,
                                      make_fleet_loss)

__all__ = [
    "FleetBuffer", "FleetFullError", "T_SENTINEL", "as_host", "pad_pow2",
    "FleetRefiner", "FleetRefinerState", "make_fleet_loss",
    "FleetBackend", "HostFleetBackend", "ShardedFleetBackend",
    "T_SENTINEL_DEV", "make_backend",
]
