"""Distributional Memory: streaming Gaussian Mixture Model (paper §4.1).

Replaces the O(N·d) contrastive memory bank with a C-component diagonal GMM
(~33 KB at C=64, d=128, fp16) updated by *stepwise online EM*
(Cappé–Moulines EMA over sufficient statistics).  Provides:

- ``responsibilities`` / ``entropy``  — the zero-cost uncertainty signal
  U_t = H(p(c|z)) (Eq. 11) that drives the RL splitter;
- ``sample_virtual_negatives`` — boundary-aware virtual hard negatives
  (Eq. 9), synthesized, l2-normalized and discarded after the gradient;
- ``em_update`` — optionally *distributed*: sufficient statistics are
  psum'd over a mesh axis, giving exact data-parallel streaming EM.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

LOG2PI = 1.8378770664093453


class GMMState(NamedTuple):
    s0: jax.Array      # (C,)    EMA count per component
    s1: jax.Array      # (C, d)  EMA sum of r*z
    s2: jax.Array      # (C, d)  EMA sum of r*z^2
    step: jax.Array    # ()      update counter

    @property
    def n_components(self):
        return self.s0.shape[0]

    @property
    def dim(self):
        return self.s1.shape[1]


def init_gmm(key, n_components, dim, *, var0=0.05):
    mu = jax.random.normal(key, (n_components, dim), jnp.float32)
    mu = mu / jnp.linalg.norm(mu, axis=-1, keepdims=True)
    s0 = jnp.ones((n_components,), jnp.float32)
    s1 = mu
    s2 = jnp.square(mu) + var0
    return GMMState(s0=s0, s1=s1, s2=s2, step=jnp.zeros((), jnp.int32))


def params_of(state: GMMState, *, var_floor=1e-4):
    """-> (pi (C,), mu (C,d), var (C,d))."""
    s0 = jnp.maximum(state.s0, 1e-8)
    pi = s0 / jnp.sum(s0)
    mu = state.s1 / s0[:, None]
    var = jnp.maximum(state.s2 / s0[:, None] - jnp.square(mu), var_floor)
    return pi, mu, var


def size_bytes(state: GMMState, *, dtype_bytes=2):
    """Wire/storage size of the distributional memory (Eq. 8)."""
    C, d = state.n_components, state.dim
    return 2 * C * d * dtype_bytes + C * dtype_bytes


def log_joint(state: GMMState, z):
    """log pi_c + log N(z; mu_c, diag var_c) -> (B, C)."""
    pi, mu, var = params_of(state)
    z = z.astype(jnp.float32)
    diff = z[:, None, :] - mu[None]                       # (B, C, d)
    maha = jnp.sum(jnp.square(diff) / var[None], axis=-1)
    logdet = jnp.sum(jnp.log(var), axis=-1)               # (C,)
    d = z.shape[-1]
    return jnp.log(pi)[None] - 0.5 * (maha + logdet + d * LOG2PI)


def responsibilities(state: GMMState, z):
    """Posterior p(c | z) via Bayes' rule -> (B, C)."""
    return jax.nn.softmax(log_joint(state, z), axis=-1)


def entropy(state: GMMState, z):
    """U_t = H(p(c|z_t)) in nats (Eq. 11) -> (B,)."""
    lj = log_joint(state, z)
    logp = lj - jax.nn.logsumexp(lj, axis=-1, keepdims=True)
    p = jnp.exp(logp)
    return -jnp.sum(p * logp, axis=-1)


def normalized_entropy(state: GMMState, z):
    """U_t / log C in [0, 1] — the RL state feature."""
    return entropy(state, z) / jnp.log(state.n_components)


def em_update(state: GMMState, z, *, decay=0.05, axis_name=None,
              reseed_frac=0.2, weights=None) -> GMMState:
    """One streaming-EM step on a batch of embeddings z: (B, d).

    Stepwise EM: S <- (1-λ) S + λ * batch_sufficient_stats.  When
    ``axis_name`` is given the batch statistics are psum'd across that mesh
    axis first — distributed streaming EM with identical fixed point.

    ``weights`` (B,) optionally down-weights frames in the sufficient
    statistics (0 drops a frame entirely) — the fleet backends feed the
    gap-masked session snapshot this way, so padding/invalid frames never
    move the memory.  ``weights=None`` is bit-identical to the original
    unweighted update.

    Dead-component reinitialization: components whose mixing weight falls
    below ``reseed_frac / C`` are re-seeded at the batch's *least-explained*
    frames (the novel/hard ones).  Without this, stale components keep
    frozen means forever (the EMA shrinks s0 and s1 at the same rate) and
    the virtual negatives they generate go permanently easy — the failure
    mode behind dimensional collapse with distributional memory.
    """
    z = z.astype(jnp.float32)
    r = responsibilities(state, z)                        # (B, C)
    if weights is None:
        b0 = jnp.sum(r, axis=0)                           # (C,)
        b1 = r.T @ z                                      # (C, d)
        b2 = r.T @ jnp.square(z)                          # (C, d)
        n = jnp.float32(z.shape[0])
    else:
        w = weights.astype(jnp.float32)
        rw = r * w[:, None]                               # (B, C)
        b0 = jnp.sum(rw, axis=0)
        b1 = rw.T @ z
        b2 = rw.T @ jnp.square(z)
        n = jnp.sum(w)
    if axis_name is not None:
        b0 = jax.lax.psum(b0, axis_name)
        b1 = jax.lax.psum(b1, axis_name)
        b2 = jax.lax.psum(b2, axis_name)
        n = jax.lax.psum(n, axis_name)
    # normalize batch stats to per-sample scale so decay is batch-size free
    scale = jnp.sum(state.s0) / jnp.maximum(n, 1.0)
    lam = jnp.float32(decay)
    s0 = (1 - lam) * state.s0 + lam * b0 * scale
    s1 = (1 - lam) * state.s1 + lam * b1 * scale
    s2 = (1 - lam) * state.s2 + lam * b2 * scale

    if reseed_frac:
        C = s0.shape[0]
        pi = s0 / jnp.maximum(jnp.sum(s0), 1e-8)
        dead = pi < (reseed_frac / C)                      # (C,)
        # least-explained frames first (novelty = low max responsibility);
        # zero-weight frames must never seed a component, so they sort
        # strictly last (max responsibility is <= 1)
        novelty = jnp.max(r, axis=-1)
        if weights is not None:
            novelty = novelty + 2.0 * (1.0 - jnp.minimum(w, 1.0))
        novelty_order = jnp.argsort(novelty)               # (B,)
        rank = jnp.cumsum(dead.astype(jnp.int32)) - 1      # slot per dead c
        rows = novelty_order[jnp.clip(rank, 0, z.shape[0] - 1)]
        seed_z = z[rows]                                   # (C, d)
        s0_new = jnp.full_like(s0, jnp.mean(s0))
        mean_var = jnp.mean(jnp.maximum(
            s2 / jnp.maximum(s0[:, None], 1e-8)
            - jnp.square(s1 / jnp.maximum(s0[:, None], 1e-8)), 1e-4))
        s1_new = seed_z * s0_new[:, None]
        s2_new = (jnp.square(seed_z) + mean_var) * s0_new[:, None]
        s0 = jnp.where(dead, s0_new, s0)
        s1 = jnp.where(dead[:, None], s1_new, s1)
        s2 = jnp.where(dead[:, None], s2_new, s2)

    return GMMState(s0=s0, s1=s1, s2=s2, step=state.step + 1)


def assign(state: GMMState, z):
    """Hard component assignment c* -> (B,) int32."""
    return jnp.argmax(log_joint(state, z), axis=-1).astype(jnp.int32)


def boundary_logits(state: GMMState, c_star, *, tau=0.1):
    """Eq. 9: p(c | z+, c*) ∝ pi_c * exp(-||mu_c* - mu_c||² / 2τ²), c != c*.

    c_star: (B,) -> (B, C) sampling logits."""
    pi, mu, _ = params_of(state)
    d2 = jnp.sum(jnp.square(mu[:, None] - mu[None]), axis=-1)  # (C, C)
    logits = jnp.log(pi)[None] - d2 / (2.0 * tau * tau)        # (C, C)
    logits = jnp.where(jnp.eye(len(pi), dtype=bool), -jnp.inf, logits)
    return logits[c_star]                                      # (B, C)


def sample_virtual_negatives(key, state: GMMState, z_anchor, n_syn,
                             *, tau=0.1):
    """Boundary-aware virtual negatives (Eq. 9) -> (B, n_syn, d), l2-normed.

    Samples a component near the anchor's decision boundary per negative,
    then draws from that component's Gaussian and projects to the sphere.
    """
    B = z_anchor.shape[0]
    _, mu, var = params_of(state)
    c_star = assign(state, z_anchor)
    logits = boundary_logits(state, c_star, tau=tau)           # (B, C)
    k1, k2 = jax.random.split(key)
    comps = jax.random.categorical(k1, logits[:, None, :],
                                   axis=-1, shape=(B, n_syn))  # (B, n_syn)
    eps = jax.random.normal(k2, (B, n_syn, state.dim), jnp.float32)
    z = mu[comps] + eps * jnp.sqrt(var[comps])
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)
    return z
