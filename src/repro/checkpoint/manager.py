"""Fault-tolerant checkpoint manager.

- atomic: write to a temp name, ``os.replace`` + COMMIT marker — a crash
  mid-write can never corrupt the latest checkpoint;
- keep-K garbage collection;
- optional async (background thread) so the train loop never blocks on
  HBM->host->disk;
- ``restore_latest`` scans for the newest COMMITted step — the restart
  path after a node failure.
"""
from __future__ import annotations

import os
import re
import shutil
import threading

import jax
import numpy as np

from repro.checkpoint.serial import load_tree, save_tree

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory, *, keep=3, async_save=False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step):
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(m.group(1)))
        return sorted(out)

    # -- save ----------------------------------------------------------------
    def save(self, step, state, *, block=True):
        state_host = jax.tree.map(np.asarray, state)  # snapshot before async
        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, state_host), daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, state_host)

    def _save_sync(self, step, state_host):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        save_tree(os.path.join(tmp, "state.npz"), state_host)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step, template):
        return load_tree(os.path.join(self._step_dir(step), "state.npz"),
                         template)

    def restore_latest(self, template):
        steps = self.steps()
        if not steps:
            return None, -1
        step = steps[-1]
        return self.restore(step, template), step
