"""Pytree <-> npz serialization (no orbax offline; self-contained)."""
from __future__ import annotations

import io
import os

import jax
import numpy as np


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _ in flat:
        keys.append("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path))
    return keys, [v for _, v in flat], treedef


def save_tree(path, tree):
    keys, vals, _ = _paths(tree)
    arrs = {k: np.asarray(v) for k, v in zip(keys, vals)}
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrs)
    os.replace(tmp, path)


def load_tree(path, template):
    """Restore into the structure of ``template`` (values replaced)."""
    keys, vals, treedef = _paths(template)
    with np.load(path) as data:
        new_vals = []
        for k, v in zip(keys, vals):
            arr = data[k]
            if hasattr(v, "dtype"):
                arr = arr.astype(v.dtype)
            new_vals.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_vals)
