"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints are mesh-agnostic (full arrays); re-entry onto a new mesh is a
``jax.device_put`` against the new rules — so a job can restart on a
degraded fleet (e.g. 512 -> 448 chips after failures) as long as the new
mesh divides the sharded dims.  ``largest_feasible_mesh`` picks the biggest
(data, model) grid for a surviving-device count.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules, make_rules, param_sharding


def reshard_state(state, axes_tree, new_mesh: Mesh, *, fsdp=False):
    """Place a host-side state pytree onto ``new_mesh`` per logical axes."""
    rules = make_rules(new_mesh, fsdp=fsdp)
    from repro.distributed import sharding as shd
    with shd.axis_rules(rules):
        shardings = param_sharding(axes_tree, new_mesh)
    return jax.device_put(state, shardings)


def largest_feasible_mesh(devices, *, model_divisors, prefer_model=None):
    """Choose (data, model) from a (possibly degraded) device list.

    model must divide head/expert counts — callers pass the divisor set;
    data gets the rest.  Returns a Mesh or None."""
    n = len(devices)
    candidates = sorted(model_divisors, reverse=True)
    if prefer_model in model_divisors:
        candidates = [prefer_model] + [c for c in candidates
                                       if c != prefer_model]
    for m in candidates:
        if n % m == 0 and n // m >= 1:
            import numpy as np
            arr = np.array(devices[: (n // m) * m]).reshape(n // m, m)
            return Mesh(arr, ("data", "model"))
    return None
