"""Grouped-query attention with qk-norm, biases, soft-capping, sliding
windows, a chunked online-softmax path for long sequences, and a KV-cache
decode path.

Shapes follow (batch, seq, heads, head_dim).  KV heads may be fewer than Q
heads (GQA); Q heads are grouped as (kv_heads, q_per_kv).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import (apply_dense, apply_rmsnorm, apply_rope,
                                 dense_init, softcap)

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def init_attention(key, cfg, *, dtype=jnp.float32):
    """cfg needs: d_model, n_heads, n_kv_heads, head_dim, qk_norm, qkv_bias."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params, axes = {}, {}
    params["wq"], axes["wq"] = dense_init(
        ks[0], (d, h, hd), ("q_in", "heads", "q_hd"), dtype=dtype,
        bias=cfg.qkv_bias, bias_axes=("heads", "q_hd"))
    params["wk"], axes["wk"] = dense_init(
        ks[1], (d, kv, hd), ("kv_in", "kv_heads", "kv_hd"), dtype=dtype,
        bias=cfg.qkv_bias, bias_axes=("kv_heads", "kv_hd"))
    params["wv"], axes["wv"] = dense_init(
        ks[2], (d, kv, hd), ("kv_in", "kv_heads", "kv_hd"), dtype=dtype,
        bias=cfg.qkv_bias, bias_axes=("kv_heads", "kv_hd"))
    params["wo"], axes["wo"] = dense_init(
        ks[3], (h, hd, d), ("heads", "o_hd", "embed"), dtype=dtype,
        scale=1.0 / math.sqrt(h * hd))
    if cfg.qk_norm:
        params["q_norm"] = {"scale": jnp.zeros((hd,), dtype)}
        axes["q_norm"] = {"scale": (None,)}
        params["k_norm"] = {"scale": jnp.zeros((hd,), dtype)}
        axes["k_norm"] = {"scale": (None,)}
    return params, axes


def _project_qkv(p, cfg, x, positions):
    q = apply_dense(p["wq"], x)            # (B, S, H, hd)
    k = apply_dense(p["wk"], x)            # (B, S, KV, hd)
    v = apply_dense(p["wv"], x)
    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q)
        k = apply_rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _scale(cfg):
    base = cfg.attn_scale if cfg.attn_scale is not None else 1.0 / math.sqrt(cfg.head_dim)
    return base


def _mask_bias(q_pos, k_pos, window):
    """(Q, K) additive mask: causal + optional sliding window."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        causal &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(causal, 0.0, NEG_INF)


def _attend_dense(cfg, q, k, v, q_pos, k_pos, window):
    """Reference einsum attention. q: (B,Sq,H,hd) k/v: (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * _scale(cfg)
    scores = softcap(scores, cfg.attn_softcap)
    scores = scores + _mask_bias(q_pos, k_pos, window)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _attend_chunked(cfg, q, k, v, q_pos, k_pos, window, chunk):
    """Flash-style: scan over KV chunks with an online softmax so the full
    (Sq, Sk) score matrix is never materialized.

    Mixed precision: matmul I/O stays in the model dtype (bf16 on TPU —
    halves the HBM/ICI bytes of every attention tensor) while the softmax
    statistics (m, l) and the output accumulator run in f32
    (MXU-accumulated via preferred_element_type).  The scan body is
    rematerialized so the backward pass recomputes score tiles instead of
    saving a stacked (n_chunks, B, ..., chunk) probability tensor
    (EXPERIMENTS.md §Perf iteration 1)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Sk = k.shape[1]
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad positions with +inf-like sentinel so padded KV is causally masked
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=(1 << 30))
    dt = q.dtype
    qh = q * jnp.asarray(_scale(cfg), dt)                 # (B, Sq, H, hd)
    # expand KV heads to H: replicated k/v are cheap, and every attention
    # tensor then carries an H-dim that shards cleanly over 'model' even
    # when KV doesn't divide it (EXPERIMENTS.md §Perf iteration 3)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    qh = shard(qh, "batch", "seq", "heads", "head_dim")
    k_c = k.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    p_c = k_pos.reshape(n_chunks, chunk)

    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        s = jnp.einsum("bqhd,bshd->bhqs", qh, kc,
                       preferred_element_type=jnp.float32)
        s = shard(s, "batch", "heads", "seq", None)
        s = softcap(s, cfg.attn_softcap)
        s = s + _mask_bias(q_pos, pc, window)[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p.astype(dt), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_c, v_c, p_c))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)                       # (B, Sq, H, hd)
    return out.astype(q.dtype)


def attention(p, cfg, x, positions, *, window=None):
    """Full-sequence (training / prefill) attention."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    S = x.shape[1]
    pos1 = positions[0] if positions.ndim > 1 else positions
    if cfg.attn_chunk and S > cfg.attn_chunk:
        out = _attend_chunked(cfg, q, k, v, pos1, pos1, window, cfg.attn_chunk)
    else:
        out = _attend_dense(cfg, q, k, v, pos1, pos1, window)
    out = shard(out, "batch", "seq", "heads", "head_dim")
    y = apply_dense(p["wo"], out, contract=2)
    return shard(y, "batch", "seq", "embed")


class KVCache(NamedTuple):
    k: jax.Array  # (B, KV, max_len, hd)
    v: jax.Array
    # index is carried at the stack level (same for every layer)


def init_kv_cache(cfg, batch, max_len, dtype):
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(p, cfg, x, cache: KVCache, index, *, window=None):
    """Single-token decode. x: (B, 1, d); cache holds max_len positions;
    `index` is the write position (== number of tokens already cached)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    # write new kv at `index`
    k_new = jnp.swapaxes(k, 1, 2)  # (B, KV, 1, hd)
    v_new = jnp.swapaxes(v, 1, 2)
    ck = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                      (0, 0, index, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                      (0, 0, index, 0))
    max_len = ck.shape[2]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    H = cfg.n_heads
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd).astype(jnp.float32) * _scale(cfg)
    scores = jnp.einsum("bqkgd,bksd->bkgqs", qg, ck.astype(jnp.float32))
    scores = softcap(scores, cfg.attn_softcap)
    k_pos = jnp.arange(max_len)
    valid = k_pos[None] <= index
    if window is not None:
        valid &= (index - k_pos[None]) < window
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bqkgd", probs, cv.astype(jnp.float32))
    out = out.reshape(B, 1, H, hd).astype(x.dtype)
    y = apply_dense(p["wo"], out, contract=2)
    return y, KVCache(ck, cv)


def attention_prefill(p, cfg, x, positions, max_len, *, window=None):
    """Prefill: run full attention and return the populated cache."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    S = x.shape[1]
    pos1 = positions[0] if positions.ndim > 1 else positions
    if cfg.attn_chunk and S > cfg.attn_chunk:
        out = _attend_chunked(cfg, q, k, v, pos1, pos1, window, cfg.attn_chunk)
    else:
        out = _attend_dense(cfg, q, k, v, pos1, pos1, window)
    y = apply_dense(p["wo"], out, contract=2)
    B = x.shape[0]
    ck = jnp.zeros((B, cfg.n_kv_heads, max_len, cfg.head_dim), k.dtype)
    cv = jnp.zeros_like(ck)
    ck = jax.lax.dynamic_update_slice(ck, jnp.swapaxes(k, 1, 2), (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, jnp.swapaxes(v, 1, 2), (0, 0, 0, 0))
    return y, KVCache(ck, cv)
