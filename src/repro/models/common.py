"""Shared building blocks: param trees, norms, RoPE, embeddings.

Everything is functional: ``init_*`` returns ``(params, axes)`` where
``params`` is a pytree of arrays and ``axes`` is a matching pytree of
logical-axis tuples (leaves are tuples of str).  The axes tree drives
sharding (distributed/sharding.py) and is never needed at apply time.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


def is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def truncated_normal(key, shape, scale, dtype):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, shape, axes, *, dtype=jnp.float32, scale=None, bias=False,
               bias_axes=None):
    """A (possibly fused) linear weight; fan-in = prod of dims before the
    split point implied by scale=None (default: first dim)."""
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    w = truncated_normal(key, shape, scale, dtype)
    params = {"w": w}
    ax = {"w": tuple(axes)}
    if bias:
        nb = shape[len(shape) - len(bias_axes):] if bias_axes else shape[1:]
        params["b"] = jnp.zeros(nb, dtype)
        ax["b"] = tuple(bias_axes) if bias_axes else tuple(axes[1:])
    return params, ax


def apply_dense(p, x, contract=1):
    """x @ w over the last `contract` dims of x and first `contract` of w."""
    w = p["w"].astype(x.dtype)
    xdims = tuple(range(x.ndim - contract, x.ndim))
    wdims = tuple(range(contract))
    y = jax.lax.dot_general(x, w, ((xdims, wdims), ((), ())))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim, *, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype)}, {"scale": ("embed",)}


def apply_rmsnorm(p, x, *, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + w): zero-init scale == identity.
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(dim, *, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def apply_layernorm(p, x, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def init_norm(kind, dim, *, dtype=jnp.float32):
    if kind == "layernorm":
        return init_layernorm(dim, dtype=dtype)
    return init_rmsnorm(dim, dtype=dtype)


def apply_norm(kind, p, x):
    if kind == "layernorm":
        return apply_layernorm(p, x)
    return apply_rmsnorm(p, x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, dim, *, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0
    tbl = truncated_normal(key, (vocab, dim), scale, dtype)
    return {"table": tbl}, {"table": ("vocab", "embed")}


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def embed_logits(p, x):
    """Tied read-out: x @ table.T -> (..., vocab)."""
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # squared ReLU (Primer / nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name}")
