"""LM assembly: composable decoder stacks for every assigned family.

Families
  dense / vlm / audio-lm : [norm→attn, norm→ffn] × L   (pattern-cycled windows)
  moe                    : same with MoE ffn (+ shared expert / dense residual)
  ssm                    : [norm→mamba] × L
  hybrid (zamba2)        : mamba stack with a *shared* attn+mlp block every k

Layers are scanned (stacked params, leading 'layers' axis) with optional
remat.  The CE loss is computed in sequence chunks so (B, S, vocab) logits
are never materialized.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (apply_norm, dense_init, embed_logits,
                                 embed_lookup, init_embedding, init_norm,
                                 softcap)

GLOBAL_WINDOW = 1 << 30


def _stack_init(fn, key, n):
    """vmap an init over n layer keys; returns (stacked params, axes)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    _, axes = fn(keys[0])
    axes = jax.tree.map(lambda a: ("layers",) + a, axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


# ---------------------------------------------------------------------------
# Block initializers
# ---------------------------------------------------------------------------

def _init_dense_block(cfg, key):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = init_norm(cfg.norm, cfg.d_model, dtype=cfg.pdtype)
    p["attn"], a["attn"] = attn_mod.init_attention(ks[0], cfg, dtype=cfg.pdtype)
    p["ln2"], a["ln2"] = init_norm(cfg.norm, cfg.d_model, dtype=cfg.pdtype)
    p["mlp"], a["mlp"] = mlp_mod.init_mlp(
        ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp, dtype=cfg.pdtype)
    return p, a


def _init_moe_block(cfg, key):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = init_norm(cfg.norm, cfg.d_model, dtype=cfg.pdtype)
    p["attn"], a["attn"] = attn_mod.init_attention(ks[0], cfg, dtype=cfg.pdtype)
    p["ln2"], a["ln2"] = init_norm(cfg.norm, cfg.d_model, dtype=cfg.pdtype)
    p["moe"], a["moe"] = moe_mod.init_moe(ks[1], cfg.moe, cfg.d_model,
                                          dtype=cfg.pdtype)
    if cfg.moe.n_shared_experts:
        ff = cfg.moe.d_ff_expert * cfg.moe.n_shared_experts
        p["shared_mlp"], a["shared_mlp"] = mlp_mod.init_mlp(
            ks[2], cfg.d_model, ff, gated=cfg.gated_mlp, dtype=cfg.pdtype)
    if cfg.moe.dense_residual:
        p["dense_mlp"], a["dense_mlp"] = mlp_mod.init_mlp(
            ks[3], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp, dtype=cfg.pdtype)
    return p, a


def _init_mamba_block(cfg, key):
    p, a = {}, {}
    p["ln"], a["ln"] = init_norm(cfg.norm, cfg.d_model, dtype=cfg.pdtype)
    p["mamba"], a["mamba"] = ssm_mod.init_mamba(key, cfg.ssm, cfg.d_model,
                                                dtype=cfg.pdtype)
    return p, a


# ---------------------------------------------------------------------------
# Block forwards
# ---------------------------------------------------------------------------

def _dense_block(cfg, p, x, positions, window):
    h = apply_norm(cfg.norm, p["ln1"], x)
    h = attn_mod.attention(p["attn"], cfg, h, positions, window=window)
    x = x + h
    h = apply_norm(cfg.norm, p["ln2"], x)
    h = mlp_mod.apply_mlp(p["mlp"], h, act=cfg.act)
    return x + h, jnp.float32(0.0)


def _moe_block(cfg, p, x, positions, window):
    h = apply_norm(cfg.norm, p["ln1"], x)
    h = attn_mod.attention(p["attn"], cfg, h, positions, window=window)
    x = x + h
    h = apply_norm(cfg.norm, p["ln2"], x)
    y, aux = moe_mod.apply_moe(p["moe"], cfg.moe, h)
    if "shared_mlp" in p:
        y = y + mlp_mod.apply_mlp(p["shared_mlp"], h, act=cfg.act)
    if "dense_mlp" in p:
        y = y + mlp_mod.apply_mlp(p["dense_mlp"], h, act=cfg.act)
    return x + y, aux


def _mamba_block(cfg, p, x):
    h = apply_norm(cfg.norm, p["ln"], x)
    h = ssm_mod.mamba_forward(p["mamba"], cfg.ssm, h)
    return x + h, jnp.float32(0.0)


def _maybe_remat(cfg, fn):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_lm(cfg, key):
    ks = jax.random.split(key, 8)
    params, axes = {}, {}
    params["embed"], axes["embed"] = init_embedding(
        ks[0], cfg.vocab, cfg.d_model, dtype=cfg.pdtype)
    blocks_p, blocks_a = {}, {}
    if cfg.family in ("dense", "vlm", "audio"):
        blocks_p["layers"], blocks_a["layers"] = _stack_init(
            partial(_init_dense_block, cfg), ks[1], cfg.n_layers)
    elif cfg.family == "moe":
        k_dense = cfg.moe.first_k_dense
        if k_dense:
            dense_cfg = cfg
            blocks_p["dense_layers"], blocks_a["dense_layers"] = _stack_init(
                partial(_init_dense_block, cfg), ks[2], k_dense)
        blocks_p["layers"], blocks_a["layers"] = _stack_init(
            partial(_init_moe_block, cfg), ks[1], cfg.n_layers - k_dense)
    elif cfg.family == "ssm":
        blocks_p["layers"], blocks_a["layers"] = _stack_init(
            partial(_init_mamba_block, cfg), ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        blocks_p["layers"], blocks_a["layers"] = _stack_init(
            partial(_init_mamba_block, cfg), ks[1], cfg.n_layers)
        blocks_p["shared"], blocks_a["shared"] = _init_dense_block(cfg, ks[2])
    else:
        raise ValueError(cfg.family)
    params["blocks"], axes["blocks"] = blocks_p, blocks_a
    params["final_norm"], axes["final_norm"] = init_norm(
        cfg.norm, cfg.d_model, dtype=cfg.pdtype)
    if not cfg.tie_embeddings:
        params["lm_head"], axes["lm_head"] = dense_init(
            ks[3], (cfg.d_model, cfg.vocab), ("embed", "vocab"),
            dtype=cfg.pdtype)
    return params, axes


def param_count(params):
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward (training / full-sequence)
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg):
    """(#full groups, tail) for the hybrid mamba/shared-attn pattern."""
    p = cfg.hybrid_period
    return cfg.n_layers // p, cfg.n_layers % p


def forward(cfg, params, tokens=None, embeds=None, positions=None):
    """-> (hidden (B, S, d), aux)."""
    if embeds is not None:
        x = embeds.astype(cfg.xdtype)
    else:
        x = embed_lookup(params["embed"], tokens).astype(cfg.xdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.xdtype)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = shard(x, "batch", "seq", "embed")
    aux = jnp.float32(0.0)
    blocks = params["blocks"]

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        block_fn = _moe_block if cfg.family == "moe" else _dense_block

        if cfg.family == "moe" and cfg.moe.first_k_dense:
            body_d = _maybe_remat(cfg, lambda x, p_l, w: _dense_block(
                cfg, p_l, x, positions, w))

            def scan_dense(carry, xs):
                x, aux = carry
                p_l, w = xs
                x, a = body_d(x, p_l, w)
                return (x, aux + a), None

            wins = cfg.layer_windows()[: cfg.moe.first_k_dense]
            (x, aux), _ = jax.lax.scan(
                scan_dense, (x, aux), (blocks["dense_layers"], wins))
            windows = cfg.layer_windows()[cfg.moe.first_k_dense:]
        else:
            windows = cfg.layer_windows()

        body = _maybe_remat(cfg, lambda x, p_l, w: block_fn(
            cfg, p_l, x, positions, w))

        def scan_body(carry, xs):
            x, aux = carry
            p_l, w = xs
            x, a = body(x, p_l, w)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, aux),
                                   (blocks["layers"], windows))

    elif cfg.family == "ssm":
        body = _maybe_remat(cfg, lambda x, p_l: _mamba_block(cfg, p_l, x))

        def scan_body(x, p_l):
            x, _ = body(x, p_l)
            return x, None

        x, _ = jax.lax.scan(scan_body, x, blocks["layers"])

    elif cfg.family == "hybrid":
        G, tail = _hybrid_layout(cfg)
        per = cfg.hybrid_period
        m_params = blocks["layers"]
        head_p = jax.tree.map(lambda t: t[: G * per].reshape(
            (G, per) + t.shape[1:]), m_params)
        tail_p = jax.tree.map(lambda t: t[G * per:], m_params)
        shared = blocks["shared"]
        win = jnp.int32(GLOBAL_WINDOW)
        m_body = _maybe_remat(cfg, lambda x, p_l: _mamba_block(cfg, p_l, x))
        s_body = _maybe_remat(cfg, lambda x: _dense_block(
            cfg, shared, x, positions, win))

        def group_body(x, p_group):
            def inner(x, p_l):
                x, _ = m_body(x, p_l)
                return x, None
            x, _ = jax.lax.scan(inner, x, p_group)
            x, _ = s_body(x)
            return x, None

        x, _ = jax.lax.scan(group_body, x, head_p)
        if tail:
            def inner(x, p_l):
                x, _ = m_body(x, p_l)
                return x, None
            x, _ = jax.lax.scan(inner, x, tail_p)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x, aux


def logits_from_hidden(cfg, params, h):
    if cfg.tie_embeddings:
        logits = embed_logits(params["embed"], h)
    else:
        from repro.models.common import apply_dense
        logits = apply_dense(params["lm_head"], h)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


# ---------------------------------------------------------------------------
# Loss (chunked CE)
# ---------------------------------------------------------------------------

def chunked_ce(cfg, params, hidden, labels):
    """Mean next-token CE, computed in sequence chunks.

    hidden: (B, S, d); labels: (B, S) (already shifted by the caller)."""
    B, S, d = hidden.shape
    c = min(cfg.loss_chunk, S)
    nc = -(-S // c)
    pad = nc * c - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def body(acc, xs):
        h, l = xs
        logits = logits_from_hidden(cfg, params, h)          # (B, c, V) fp32
        logits = shard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        return (acc[0] + nll.sum(), acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(cfg, params, batch):
    """batch: {tokens|embeds, labels} -> (loss, metrics)."""
    h, aux = forward(cfg, params, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"))
    ce = chunked_ce(cfg, params, h, batch["labels"])
    loss = ce
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_coef * aux
    return loss, {"ce": ce, "moe_aux": aux, "hidden": h}


# ---------------------------------------------------------------------------
# Decode state / prefill / decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg, batch, max_len, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    st = {"index": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        L = cfg.n_layers
        st["k"] = jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.head_dim), dt)
        st["v"] = jnp.zeros_like(st["k"])
    elif cfg.family == "ssm":
        s = cfg.ssm
        L = cfg.n_layers
        st["ssm"] = jnp.zeros((L, batch, s.n_heads, s.head_dim, s.d_state), dt)
        st["conv"] = jnp.zeros((L, batch, s.conv_width - 1, s.n_heads, s.head_dim), dt)
    elif cfg.family == "hybrid":
        s = cfg.ssm
        L = cfg.n_layers
        G, _ = _hybrid_layout(cfg)
        st["ssm"] = jnp.zeros((L, batch, s.n_heads, s.head_dim, s.d_state), dt)
        st["conv"] = jnp.zeros((L, batch, s.conv_width - 1, s.n_heads, s.head_dim), dt)
        st["k"] = jnp.zeros((G, batch, cfg.n_kv_heads, max_len, cfg.head_dim), dt)
        st["v"] = jnp.zeros_like(st["k"])
    return st


def decode_state_specs(cfg, batch, max_len, *, kind="act"):
    """Logical axes for the decode state (for shardings)."""
    ax = {"index": ()}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        ax["k"] = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
        ax["v"] = ax["k"]
    elif cfg.family in ("ssm", "hybrid"):
        ax["ssm"] = ("layers", "batch", "ssm_heads", "head_dim", "ssm_state")
        ax["conv"] = ("layers", "batch", "conv", "ssm_heads", "head_dim")
        if cfg.family == "hybrid":
            ax["k"] = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
            ax["v"] = ax["k"]
    return ax


def prefill(cfg, params, tokens=None, embeds=None, max_len=None):
    """Full-sequence prefill -> (decode_state, last-token logits)."""
    if embeds is not None:
        x = embeds.astype(cfg.xdtype)
    else:
        x = embed_lookup(params["embed"], tokens).astype(cfg.xdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.xdtype)
    B, S = x.shape[:2]
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = shard(x, "batch", "seq", "embed")
    blocks = params["blocks"]
    st = init_decode_state(cfg, B, max_len, dtype=cfg.xdtype)
    st["index"] = jnp.int32(S)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        windows = cfg.layer_windows()

        def make_body(kind):
            def body(x, xs):
                p_l, w = xs
                h = apply_norm(cfg.norm, p_l["ln1"], x)
                h, kv = attn_mod.attention_prefill(
                    p_l["attn"], cfg, h, positions, max_len, window=w)
                x = x + h
                h = apply_norm(cfg.norm, p_l["ln2"], x)
                if kind == "moe":
                    y, _ = moe_mod.apply_moe(p_l["moe"], cfg.moe, h)
                    if "shared_mlp" in p_l:
                        y = y + mlp_mod.apply_mlp(p_l["shared_mlp"], h, act=cfg.act)
                    if "dense_mlp" in p_l:
                        y = y + mlp_mod.apply_mlp(p_l["dense_mlp"], h, act=cfg.act)
                else:
                    y = mlp_mod.apply_mlp(p_l["mlp"], h, act=cfg.act)
                x = x + y
                return x, (kv.k, kv.v)
            return body

        kd = cfg.moe.first_k_dense if cfg.family == "moe" else 0
        if kd:
            x, (ks_d, vs_d) = jax.lax.scan(
                make_body("dense"), x, (blocks["dense_layers"], windows[:kd]))
        kind = "moe" if cfg.family == "moe" else "dense"
        x, (ks, vs) = jax.lax.scan(
            make_body(kind), x, (blocks["layers"], windows[kd:]))
        if kd:
            ks = jnp.concatenate([ks_d, ks], 0)
            vs = jnp.concatenate([vs_d, vs], 0)
        st["k"], st["v"] = ks, vs

    elif cfg.family == "ssm":
        def body(x, p_l):
            h = apply_norm(cfg.norm, p_l["ln"], x)
            h, s = ssm_mod.mamba_forward(p_l["mamba"], cfg.ssm, h,
                                         return_state=True)
            return x + h, (s.ssm, s.conv)

        x, (ss, cs) = jax.lax.scan(body, x, blocks["layers"])
        st["ssm"], st["conv"] = ss, cs

    elif cfg.family == "hybrid":
        G, tail = _hybrid_layout(cfg)
        per = cfg.hybrid_period
        m_params = blocks["layers"]
        head_p = jax.tree.map(lambda t: t[: G * per].reshape(
            (G, per) + t.shape[1:]), m_params)
        tail_p = jax.tree.map(lambda t: t[G * per:], m_params)
        shared = blocks["shared"]
        win = jnp.int32(GLOBAL_WINDOW)

        def m_body(x, p_l):
            h = apply_norm(cfg.norm, p_l["ln"], x)
            h, s = ssm_mod.mamba_forward(p_l["mamba"], cfg.ssm, h,
                                         return_state=True)
            return x + h, (s.ssm, s.conv)

        def group_body(x, p_group):
            x, states = jax.lax.scan(m_body, x, p_group)
            h = apply_norm(cfg.norm, shared["ln1"], x)
            h, kv = attn_mod.attention_prefill(
                shared["attn"], cfg, h, positions, max_len, window=win)
            x = x + h
            h = apply_norm(cfg.norm, shared["ln2"], x)
            x = x + mlp_mod.apply_mlp(shared["mlp"], h, act=cfg.act)
            return x, (states, (kv.k, kv.v))

        x, (m_states, kvs) = jax.lax.scan(group_body, x, head_p)
        ss = m_states[0].reshape((G * per,) + m_states[0].shape[2:])
        cs = m_states[1].reshape((G * per,) + m_states[1].shape[2:])
        if tail:
            x, tail_states = jax.lax.scan(m_body, x, tail_p)
            ss = jnp.concatenate([ss, tail_states[0]], 0)
            cs = jnp.concatenate([cs, tail_states[1]], 0)
        st["ssm"], st["conv"] = ss, cs
        st["k"], st["v"] = kvs

    x_last = apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    logits = logits_from_hidden(cfg, params, x_last)[:, 0]
    return st, logits


def decode_step(cfg, params, state, tokens):
    """One decode step. tokens: (B,) -> (logits (B, V), new state)."""
    B = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens[:, None]).astype(cfg.xdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.xdtype)
    idx = state["index"]
    blocks = params["blocks"]
    new_state = dict(state)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        windows = cfg.layer_windows()

        def make_body(kind):
            def body(x, xs):
                p_l, w, k_l, v_l = xs
                h = apply_norm(cfg.norm, p_l["ln1"], x)
                h, kv = attn_mod.attention_decode(
                    p_l["attn"], cfg, h, attn_mod.KVCache(k_l, v_l), idx,
                    window=w)
                x = x + h
                h = apply_norm(cfg.norm, p_l["ln2"], x)
                if kind == "moe":
                    y, _ = moe_mod.apply_moe(p_l["moe"], cfg.moe, h)
                    if "shared_mlp" in p_l:
                        y = y + mlp_mod.apply_mlp(p_l["shared_mlp"], h, act=cfg.act)
                    if "dense_mlp" in p_l:
                        y = y + mlp_mod.apply_mlp(p_l["dense_mlp"], h, act=cfg.act)
                else:
                    y = mlp_mod.apply_mlp(p_l["mlp"], h, act=cfg.act)
                x = x + y
                return x, (kv.k, kv.v)
            return body

        kd = cfg.moe.first_k_dense if cfg.family == "moe" else 0
        if kd:
            x, (ks_d, vs_d) = jax.lax.scan(
                make_body("dense"), x,
                (blocks["dense_layers"], windows[:kd],
                 state["k"][:kd], state["v"][:kd]))
        kind = "moe" if cfg.family == "moe" else "dense"
        x, (ks, vs) = jax.lax.scan(
            make_body(kind), x,
            (blocks["layers"], windows[kd:], state["k"][kd:], state["v"][kd:]))
        if kd:
            ks = jnp.concatenate([ks_d, ks], 0)
            vs = jnp.concatenate([vs_d, vs], 0)
        new_state["k"], new_state["v"] = ks, vs

    elif cfg.family == "ssm":
        def body(x, xs):
            p_l, s_l, c_l = xs
            h = apply_norm(cfg.norm, p_l["ln"], x)
            h, s = ssm_mod.mamba_decode(p_l["mamba"], cfg.ssm, h,
                                        ssm_mod.SSMState(s_l, c_l))
            return x + h, (s.ssm, s.conv)

        x, (ss, cs) = jax.lax.scan(
            body, x, (blocks["layers"], state["ssm"], state["conv"]))
        new_state["ssm"], new_state["conv"] = ss, cs

    elif cfg.family == "hybrid":
        G, tail = _hybrid_layout(cfg)
        per = cfg.hybrid_period
        m_params = blocks["layers"]
        head_p = jax.tree.map(lambda t: t[: G * per].reshape(
            (G, per) + t.shape[1:]), m_params)
        tail_p = jax.tree.map(lambda t: t[G * per:], m_params)
        shared = blocks["shared"]
        win = jnp.int32(GLOBAL_WINDOW)

        def m_body(x, xs):
            p_l, s_l, c_l = xs
            h = apply_norm(cfg.norm, p_l["ln"], x)
            h, s = ssm_mod.mamba_decode(p_l["mamba"], cfg.ssm, h,
                                        ssm_mod.SSMState(s_l, c_l))
            return x + h, (s.ssm, s.conv)

        head_ss = jax.tree.map(lambda t: t[: G * per].reshape(
            (G, per) + t.shape[1:]), state["ssm"])
        head_cs = jax.tree.map(lambda t: t[: G * per].reshape(
            (G, per) + t.shape[1:]), state["conv"])

        def group_body(x, xs):
            p_g, s_g, c_g, k_g, v_g = xs
            x, states = jax.lax.scan(m_body, x, (p_g, s_g, c_g))
            h = apply_norm(cfg.norm, shared["ln1"], x)
            h, kv = attn_mod.attention_decode(
                shared["attn"], cfg, h, attn_mod.KVCache(k_g, v_g), idx,
                window=win)
            x = x + h
            h = apply_norm(cfg.norm, shared["ln2"], x)
            x = x + mlp_mod.apply_mlp(shared["mlp"], h, act=cfg.act)
            return x, (states, (kv.k, kv.v))

        x, (m_states, kvs) = jax.lax.scan(
            group_body, x, (head_p, head_ss, head_cs, state["k"], state["v"]))
        ss = m_states[0].reshape((G * per,) + m_states[0].shape[2:])
        cs = m_states[1].reshape((G * per,) + m_states[1].shape[2:])
        if tail:
            x, tail_states = jax.lax.scan(
                m_body, x, (tail_p, state["ssm"][G * per:],
                            state["conv"][G * per:]))
            ss = jnp.concatenate([ss, tail_states[0]], 0)
            cs = jnp.concatenate([cs, tail_states[1]], 0)
        new_state["ssm"], new_state["conv"] = ss, cs
        new_state["k"], new_state["v"] = kvs

    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    new_state["index"] = idx + 1
    return logits, new_state
