"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain (squared-ReLU etc.)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import activation, apply_dense, dense_init


def init_mlp(key, d_model, d_ff, *, gated=True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    params, axes = {}, {}
    params["w_up"], axes["w_up"] = dense_init(
        ks[0], (d_model, d_ff), ("embed", "mlp"), dtype=dtype)
    if gated:
        params["w_gate"], axes["w_gate"] = dense_init(
            ks[1], (d_model, d_ff), ("embed", "mlp"), dtype=dtype)
    params["w_down"], axes["w_down"] = dense_init(
        ks[2], (d_ff, d_model), ("mlp", "embed"), dtype=dtype,
        scale=1.0 / math.sqrt(d_ff))
    return params, axes


def apply_mlp(p, x, *, act="silu"):
    fn = activation(act)
    up = apply_dense(p["w_up"], x)
    if "w_gate" in p:
        h = fn(apply_dense(p["w_gate"], x)) * up
    else:
        h = fn(up)
    h = shard(h, "batch", "seq", "mlp")
    y = apply_dense(p["w_down"], h)
    return shard(y, "batch", "seq", "embed")
