"""Mixture-of-Experts with top-k routing.

Two execution paths:

* ``moe_reference`` — every expert on every token (einsum over the full
  expert dim).  Exact, no capacity drops; used by smoke configs, unit tests
  and as the oracle for the EP path.

* ``moe_ep`` — expert parallelism via ``shard_map``: experts sharded over
  the 'model' mesh axis, tokens sequence-sharded over 'model', dispatched
  with a fixed-capacity all-to-all (GShard-style dropping), grouped batched
  matmul per local expert, and a return all-to-all.  This is the scalable
  path used by the kimi-k2 / arctic dry-runs.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.common import activation, dense_init


def init_moe(key, moe_cfg, d_model, *, dtype=jnp.float32):
    E, ff = moe_cfg.n_experts, moe_cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    params, axes = {}, {}
    params["router"], axes["router"] = dense_init(
        ks[0], (d_model, E), ("router", "router"), dtype=jnp.float32)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(ff)
    def expert_w(k, shape, scale):
        return scale * jax.random.truncated_normal(k, -2.0, 2.0, shape,
                                                   jnp.float32).astype(dtype)
    params["w_up"] = expert_w(ks[1], (E, d_model, ff), s_in)
    axes["w_up"] = ("experts", "embed", "expert_mlp")
    if moe_cfg.gated:
        params["w_gate"] = expert_w(ks[2], (E, d_model, ff), s_in)
        axes["w_gate"] = ("experts", "embed", "expert_mlp")
    params["w_down"] = expert_w(ks[3], (E, ff, d_model), s_out)
    axes["w_down"] = ("experts", "expert_mlp", "embed")
    return params, axes


def _router(p, moe_cfg, x2d):
    """x2d: (T, d) -> (top_p, top_e, probs).  Softmax-then-topk-renorm."""
    logits = x2d.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, moe_cfg.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    return top_p, top_e, probs


def _aux_loss(moe_cfg, probs, top_e):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    E = moe_cfg.n_experts
    assign = jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(axis=1)  # (T, E)
    f = assign.mean(axis=0) / moe_cfg.top_k * E
    P = probs.mean(axis=0)
    return jnp.sum(f * P)


def _expert_ffn(moe_cfg, w_up, w_gate, w_down, xb):
    """xb: (E_local, C, d) -> (E_local, C, d)."""
    fn = activation(moe_cfg.act)
    up = jnp.einsum("ecd,edf->ecf", xb, w_up.astype(xb.dtype))
    if w_gate is not None:
        h = fn(jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(xb.dtype))) * up
    else:
        h = fn(up)
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(xb.dtype))


# ---------------------------------------------------------------------------
# Reference path (tiny configs, oracle)
# ---------------------------------------------------------------------------

def moe_reference(p, moe_cfg, x):
    """x: (B, S, d).  Computes all experts on all tokens — exact."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    top_p, top_e, probs = _router(p, moe_cfg, x2d)
    fn = activation(moe_cfg.act)
    up = jnp.einsum("td,edf->tef", x2d, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        h = fn(jnp.einsum("td,edf->tef", x2d, p["w_gate"].astype(x.dtype))) * up
    else:
        h = fn(up)
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(x.dtype))  # (T,E,d)
    w_full = jnp.zeros((x2d.shape[0], moe_cfg.n_experts), jnp.float32)
    w_full = w_full.at[jnp.arange(x2d.shape[0])[:, None], top_e].add(top_p)
    y = jnp.einsum("te,ted->td", w_full.astype(x.dtype), y_all)
    aux = _aux_loss(moe_cfg, probs, top_e)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel path
# ---------------------------------------------------------------------------

def _local_moe(moe_cfg, R, E_local, cap_factor, mesh_axes, x_local, router_w,
               w_up, w_gate, w_down):
    """Per-device body under shard_map.

    x_local: (B_l, S_l, d) — tokens owned by this device (seq split over
    'model', batch split over data axes).  Experts [rank*E_local, ...) live
    here as w_* blocks.
    """
    B_l, S_l, d = x_local.shape
    T = B_l * S_l
    k = moe_cfg.top_k
    x2d = x_local.reshape(T, d)
    top_p, top_e, probs = _router({"router": {"w": router_w}}, moe_cfg, x2d)
    # globally exact load-balance loss: pmean the per-expert fractions f_e
    # and mean probs P_e across shards BEFORE taking the product (a mean of
    # per-shard products is a biased estimator).
    E = moe_cfg.n_experts
    assign = jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(axis=1)
    f = jax.lax.pmean(assign.mean(axis=0), mesh_axes) / moe_cfg.top_k * E
    Pm = jax.lax.pmean(probs.mean(axis=0), mesh_axes)
    aux = jnp.sum(f * Pm)

    copies = T * k
    CAP = int(math.ceil(copies / R * cap_factor))
    ECAP = int(math.ceil(R * CAP / E_local * cap_factor))

    eid = top_e.reshape(-1)                      # (T*k,)
    gate = top_p.reshape(-1).astype(x2d.dtype)
    src = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    dst = eid // E_local                          # destination model-rank

    onehot_dst = (dst[:, None] == jnp.arange(R)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot_dst, axis=0) - 1
    pos = jnp.sum(pos * onehot_dst, axis=-1)
    keep = pos < CAP
    slot = jnp.where(keep, dst * CAP + pos, R * CAP)  # overflow -> dump row

    send_x = jnp.zeros((R * CAP + 1, d), x2d.dtype).at[slot].set(x2d[src])
    send_le = jnp.full((R * CAP + 1,), -1, jnp.int32).at[slot].set(
        (eid % E_local).astype(jnp.int32))
    slot_src = jnp.full((R * CAP + 1,), -1, jnp.int32).at[slot].set(src)
    slot_w = jnp.zeros((R * CAP + 1,), x2d.dtype).at[slot].set(gate)

    recv_x = jax.lax.all_to_all(
        send_x[: R * CAP].reshape(R, CAP, d), "model", 0, 0).reshape(R * CAP, d)
    recv_le = jax.lax.all_to_all(
        send_le[: R * CAP].reshape(R, CAP), "model", 0, 0).reshape(R * CAP)

    onehot_e = (recv_le[:, None] == jnp.arange(E_local)[None, :]).astype(jnp.int32)
    epos = jnp.cumsum(onehot_e, axis=0) - 1
    epos = jnp.sum(epos * onehot_e, axis=-1)
    ekeep = (recv_le >= 0) & (epos < ECAP)
    eslot = jnp.where(ekeep, recv_le * ECAP + epos, E_local * ECAP)

    ebuf = jnp.zeros((E_local * ECAP + 1, d), x2d.dtype).at[eslot].set(recv_x)
    ebuf = ebuf[:-1].reshape(E_local, ECAP, d)
    ybuf = _expert_ffn(moe_cfg, w_up, w_gate, w_down, ebuf)
    ypad = jnp.concatenate(
        [ybuf.reshape(E_local * ECAP, d), jnp.zeros((1, d), ybuf.dtype)], 0)
    ret = jnp.where(ekeep[:, None], ypad[eslot], 0)

    back = jax.lax.all_to_all(
        ret.reshape(R, CAP, d), "model", 0, 0).reshape(R * CAP, d)
    out_src = jnp.where(slot_src[: R * CAP] >= 0, slot_src[: R * CAP], T)
    out = jnp.zeros((T + 1, d), x2d.dtype).at[out_src].add(
        slot_w[: R * CAP, None] * back)
    return out[:T].reshape(B_l, S_l, d), aux


def _local_moe_replicated(moe_cfg, R, E_local, cap_factor, mesh_axes,
                          x_local, router_w, w_up, w_gate, w_down):
    """EP without token dispatch — for decode-style tiny token counts.

    Tokens are replicated over 'model'; each rank computes only its local
    experts' contributions and the outputs are psum'd.  No all-to-all."""
    B_l, S_l, d = x_local.shape
    T = B_l * S_l
    k = moe_cfg.top_k
    x2d = x_local.reshape(T, d)
    top_p, top_e, probs = _router({"router": {"w": router_w}}, moe_cfg, x2d)
    E = moe_cfg.n_experts
    assign = jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(axis=1)
    f = jax.lax.pmean(assign.mean(axis=0), mesh_axes) / moe_cfg.top_k * E
    Pm = jax.lax.pmean(probs.mean(axis=0), mesh_axes)
    aux = jnp.sum(f * Pm)

    rank = jax.lax.axis_index("model")
    eid = top_e.reshape(-1)
    gate = top_p.reshape(-1).astype(x2d.dtype)
    src = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    le = eid - rank * E_local                      # local expert id
    mine = (le >= 0) & (le < E_local)
    ECAP = int(math.ceil(T * k / E_local * cap_factor))

    onehot_e = (jnp.where(mine, le, -1)[:, None]
                == jnp.arange(E_local)[None, :]).astype(jnp.int32)
    epos = jnp.cumsum(onehot_e, axis=0) - 1
    epos = jnp.sum(epos * onehot_e, axis=-1)
    keep = mine & (epos < ECAP)
    eslot = jnp.where(keep, le * ECAP + epos, E_local * ECAP)
    ebuf = jnp.zeros((E_local * ECAP + 1, d), x2d.dtype).at[eslot].set(
        x2d[src])
    ebuf = ebuf[:-1].reshape(E_local, ECAP, d)
    ybuf = _expert_ffn(moe_cfg, w_up, w_gate, w_down, ebuf)
    ypad = jnp.concatenate(
        [ybuf.reshape(E_local * ECAP, d), jnp.zeros((1, d), ybuf.dtype)], 0)
    contrib = jnp.where(keep[:, None], ypad[jnp.minimum(eslot,
                                                        E_local * ECAP)], 0)
    out_src = jnp.where(keep, src, T)
    out = jnp.zeros((T + 1, d), x2d.dtype).at[out_src].add(
        gate[:, None] * contrib)[:T]
    out = jax.lax.psum(out, "model")
    return out.reshape(B_l, S_l, d), aux


def moe_ep(p, moe_cfg, x, *, cap_factor=1.25):
    """Expert-parallel MoE. x: (B, S, d) with batch data-sharded."""
    rules = shd.current_rules()
    mesh = rules.mesh
    R = mesh.shape["model"]
    E = moe_cfg.n_experts
    assert E % R == 0, f"experts {E} must divide model axis {R}"
    E_local = E // R
    batch = rules.act_rules.get("batch")
    if batch is None:
        batch_axes = ()
    elif isinstance(batch, tuple):
        batch_axes = batch
    else:
        batch_axes = (batch,)
    P = jax.sharding.PartitionSpec
    mesh_axes = tuple(mesh.axis_names)
    w_gate = p.get("w_gate")
    # dispatch (all-to-all) path needs the seq dim to split over 'model';
    # decode-style tiny sequences use the replicated-token path instead.
    seq_split = x.shape[1] % R == 0
    body = _local_moe if seq_split else _local_moe_replicated
    x_spec = P(batch_axes if batch_axes else None,
               "model" if seq_split else None, None)
    fn = partial(body, moe_cfg, R, E_local, cap_factor, mesh_axes)
    in_specs = (
        x_spec,                                                 # x
        P(None, None),                                          # router
        P("model", None, None),                                 # w_up
        None if w_gate is None else P("model", None, None),     # w_gate
        P("model", None, None),                                 # w_down
    )
    out_specs = (x_spec, P())
    from repro.compat import shard_map
    y, aux = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(x, p["router"]["w"], p["w_up"], w_gate, p["w_down"])
    return y, aux


def apply_moe(p, moe_cfg, x, *, force_reference=False):
    """Dispatch between EP and reference paths based on the installed mesh."""
    rules = shd.current_rules()
    use_ep = (
        not force_reference
        and rules is not None
        and rules.mesh is not None
        and "model" in rules.mesh.axis_names
        and rules.mesh.shape["model"] > 1
        and moe_cfg.n_experts % rules.mesh.shape["model"] == 0
    )
    if use_ep:
        return moe_ep(p, moe_cfg, x)
    return moe_reference(p, moe_cfg, x)
