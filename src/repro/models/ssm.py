"""Mamba-2 (SSD, state-space duality) blocks.

Chunked SSD for training/prefill (block-diagonal intra-chunk "attention"
plus a low-rank inter-chunk recurrence — arXiv:2405.21060) and an O(1)
recurrent step for decode.  Projections are unfused so heads shard cleanly
over the 'model' mesh axis.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import apply_dense, dense_init


def init_mamba(key, ssm_cfg, d_model, *, dtype=jnp.float32):
    H, P, N, G = ssm_cfg.n_heads, ssm_cfg.head_dim, ssm_cfg.d_state, ssm_cfg.n_groups
    W = ssm_cfg.conv_width
    ks = jax.random.split(key, 9)
    params, axes = {}, {}
    params["wz"], axes["wz"] = dense_init(
        ks[0], (d_model, H, P), ("embed", "ssm_heads", "head_dim"), dtype=dtype)
    params["wx"], axes["wx"] = dense_init(
        ks[1], (d_model, H, P), ("embed", "ssm_heads", "head_dim"), dtype=dtype)
    params["wB"], axes["wB"] = dense_init(
        ks[2], (d_model, G, N), ("embed", "ssm_group", "ssm_state"), dtype=dtype)
    params["wC"], axes["wC"] = dense_init(
        ks[3], (d_model, G, N), ("embed", "ssm_group", "ssm_state"), dtype=dtype)
    params["wdt"], axes["wdt"] = dense_init(
        ks[4], (d_model, H), ("embed", "ssm_heads"), dtype=dtype)
    # depthwise causal conv over the x-path channels (H*P)
    params["conv_x"] = 0.1 * jax.random.normal(ks[5], (W, H, P), jnp.float32).astype(dtype)
    axes["conv_x"] = ("conv", "ssm_heads", "head_dim")
    # per-head dynamics
    dt0 = jnp.exp(jax.random.uniform(ks[6], (H,), jnp.float32,
                                     math.log(1e-3), math.log(1e-1)))
    params["dt_bias"] = dt0 + jnp.log(-jnp.expm1(-dt0))  # inv softplus
    axes["dt_bias"] = ("ssm_heads",)
    params["A_log"] = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    axes["A_log"] = ("ssm_heads",)
    params["D"] = jnp.ones((H,), jnp.float32)
    axes["D"] = ("ssm_heads",)
    params["norm_scale"] = jnp.zeros((H, P), dtype)
    axes["norm_scale"] = ("ssm_heads", "head_dim")
    params["wo"], axes["wo"] = dense_init(
        ks[7], (H, P, d_model), ("ssm_heads", "head_dim", "embed"),
        dtype=dtype, scale=1.0 / math.sqrt(H * P))
    return params, axes


def _causal_depthwise_conv(x, w):
    """x: (B, S, H, P), w: (W, H, P) — causal depthwise conv along S."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W is tiny (4): unrolled adds beat a conv primitive
        out = out + xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    return out


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-triangular segment sums
    L[i, j] = sum_{j < t <= i} x[t]  (NEG at j > i)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    """y, z: (..., H, P).  y <- RMSNorm(y * silu(z)) per (H, P) channel."""
    h = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return h * (1.0 + scale.astype(jnp.float32))


class SSMState(NamedTuple):
    ssm: jax.Array    # (B, H, P, N)
    conv: jax.Array   # (B, W-1, H, P)


def init_ssm_state(ssm_cfg, batch, dtype=jnp.float32):
    H, P, N, W = (ssm_cfg.n_heads, ssm_cfg.head_dim, ssm_cfg.d_state,
                  ssm_cfg.conv_width)
    return SSMState(
        ssm=jnp.zeros((batch, H, P, N), dtype),
        conv=jnp.zeros((batch, W - 1, H, P), dtype),
    )


def _project(p, ssm_cfg, u):
    z = apply_dense(p["wz"], u)                       # (B,S,H,P)
    x = apply_dense(p["wx"], u)                       # (B,S,H,P)
    Bv = apply_dense(p["wB"], u).astype(jnp.float32)  # (B,S,G,N)
    Cv = apply_dense(p["wC"], u).astype(jnp.float32)  # (B,S,G,N)
    dt = apply_dense(p["wdt"], u).astype(jnp.float32) # (B,S,H)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    return z, x, Bv, Cv, dt


def mamba_forward(p, ssm_cfg, u, *, return_state=False):
    """u: (B, S, d_model) -> (B, S, d_model) via chunked SSD."""
    H, P, N, G = ssm_cfg.n_heads, ssm_cfg.head_dim, ssm_cfg.d_state, ssm_cfg.n_groups
    Q = ssm_cfg.chunk
    B_, S, _ = u.shape
    z, x, Bv, Cv, dt = _project(p, ssm_cfg, u)
    x = jax.nn.silu(_causal_depthwise_conv(x, p["conv_x"]).astype(jnp.float32))
    x = shard(x.astype(u.dtype), "batch", "seq", "ssm_heads", "head_dim")
    A = -jnp.exp(p["A_log"])                          # (H,)

    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        z_p = jnp.pad(z, ((0, 0), (0, pad), (0, 0), (0, 0)))
        x_p = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_p = jnp.pad(Bv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_p = jnp.pad(Cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    else:
        z_p, x_p, B_p, C_p, dt_p = z, x, Bv, Cv, dt

    def ch(t, extra=()):  # (B, nc, Q, ...)
        return t.reshape((B_, nc, Q) + t.shape[2:])

    xc = ch(x_p).astype(jnp.float32)      # (B,nc,Q,H,P)
    Bc = ch(B_p)                          # (B,nc,Q,G,N)
    Cc = ch(C_p)
    dtc = ch(dt_p)                        # (B,nc,Q,H)
    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=3)      # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A                          # (B,nc,Q,H)
    dA_cs = jnp.cumsum(dA, axis=2)        # (B,nc,Q,H)
    # intra-chunk (block-diagonal) term
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                            # (B,nc,Q,H,P)
    Ydiag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp", Ch, Bh, L, xdt)
    # chunk-final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn", Bh, decay_states, xdt)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (B,nc,H)

    def scan_body(s_prev, xs):
        st, dec = xs
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((B_, H, P, N), jnp.float32)
    s_final, prev_states = jax.lax.scan(
        scan_body, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,H,P,N)
    state_decay = jnp.exp(dA_cs)                         # (B,nc,Q,H)
    Yoff = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, state_decay)

    y = (Ydiag + Yoff).reshape(B_, nc * Q, H, P)[:, :S]
    y = y + x.astype(jnp.float32) * p["D"][:, None]
    y = _gated_rmsnorm(y, z, p["norm_scale"]).astype(u.dtype)
    y = shard(y, "batch", "seq", "ssm_heads", "head_dim")
    out = apply_dense(p["wo"], y, contract=2)
    out = shard(out, "batch", "seq", "embed")
    if return_state:
        # conv state: last W-1 raw x-path inputs (pre-conv)
        x_raw = apply_dense(p["wx"], u)
        W = ssm_cfg.conv_width
        conv_state = x_raw[:, -(W - 1):]
        if S < W - 1:
            conv_state = jnp.pad(x_raw, ((0, 0), (W - 1 - S, 0), (0, 0), (0, 0)))
        return out, SSMState(ssm=s_final.astype(u.dtype),
                             conv=conv_state.astype(u.dtype))
    return out


def mamba_decode(p, ssm_cfg, u, state: SSMState):
    """Single-step recurrence. u: (B, 1, d_model)."""
    H, P, N, G = ssm_cfg.n_heads, ssm_cfg.head_dim, ssm_cfg.d_state, ssm_cfg.n_groups
    W = ssm_cfg.conv_width
    z, x_raw, Bv, Cv, dt = _project(p, ssm_cfg, u)
    x_raw = x_raw[:, 0]                                   # (B,H,P)
    # conv with buffered history
    hist = jnp.concatenate([state.conv,
                            x_raw[:, None].astype(state.conv.dtype)], axis=1)
    w = p["conv_x"].astype(jnp.float32)                   # (W,H,P)
    x = jnp.einsum("bwhp,whp->bhp", hist.astype(jnp.float32), w)
    x = jax.nn.silu(x)
    new_conv = hist[:, 1:]

    A = -jnp.exp(p["A_log"])                              # (H,)
    dt1 = dt[:, 0]                                        # (B,H)
    dA = jnp.exp(dt1 * A)                                 # (B,H)
    rep = H // G
    Bh = jnp.repeat(Bv[:, 0], rep, axis=1)                # (B,H,N)
    Chh = jnp.repeat(Cv[:, 0], rep, axis=1)
    xdt = x * dt1[..., None]                              # (B,H,P)
    s = state.ssm.astype(jnp.float32)
    s = s * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", s, Chh)
    y = y + x * p["D"][:, None]
    y = _gated_rmsnorm(y[:, None], z, p["norm_scale"]).astype(u.dtype)
    out = apply_dense(p["wo"], y, contract=2)             # (B,1,d)
    return out, SSMState(ssm=s.astype(state.ssm.dtype),
                         conv=new_conv.astype(state.conv.dtype))
