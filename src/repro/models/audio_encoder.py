"""The paper's encoder: ResNet-18-style 1D CNN over mel spectrograms with
L=8 *splittable* blocks and a d=128 projection head (§5 Reproducibility).

Adaptation note (DESIGN.md): BatchNorm is undefined for streaming batch
sizes (the paper itself excludes BN-reliant baselines) — we use GroupNorm.

``apply_blocks(params, x, start, end)`` runs blocks [start, end) so the
split engine can execute any prefix on the "edge" stage and the suffix on
the "server" stage; the activation at the boundary is the wire payload.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AudioEncCfg:
    name: str = "streamsplit-audio"
    family: str = "audio_enc"
    n_mels: int = 128
    d_embed: int = 128
    widths: tuple = (64, 64, 128, 128, 256, 256, 512, 512)
    strides: tuple = (1, 2, 1, 2, 1, 2, 1, 2)
    kernel: int = 3
    groups: int = 8        # GroupNorm groups
    frames: int = 100      # 1 s @ 10 ms hop

    @property
    def n_blocks(self):
        return len(self.widths)


def _conv_init(key, k, cin, cout):
    scale = 1.0 / math.sqrt(k * cin)
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, (k, cin, cout),
                                               jnp.float32)


def _conv1d(x, w, stride=1):
    """x: (B, T, C); w: (K, Cin, Cout); causal 'SAME' padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))


def _groupnorm(p, x, groups, eps=1e-5):
    B, T, C = x.shape
    g = x.reshape(B, T, groups, C // groups)
    mu = g.mean(axis=(1, 3), keepdims=True)
    var = g.var(axis=(1, 3), keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + eps)
    return g.reshape(B, T, C) * p["scale"] + p["bias"]


def init_audio_encoder(cfg: AudioEncCfg, key):
    ks = jax.random.split(key, 3 + 4 * cfg.n_blocks)
    params = {"stem": {"w": _conv_init(ks[0], 7, cfg.n_mels, cfg.widths[0])}}
    blocks = []
    cin = cfg.widths[0]
    for i, (w, s) in enumerate(zip(cfg.widths, cfg.strides)):
        kk = ks[3 + 4 * i: 7 + 4 * i]
        blk = {
            "conv1": {"w": _conv_init(kk[0], cfg.kernel, cin, w)},
            "gn1": {"scale": jnp.ones((w,)), "bias": jnp.zeros((w,))},
            "conv2": {"w": _conv_init(kk[1], cfg.kernel, w, w)},
            "gn2": {"scale": jnp.ones((w,)), "bias": jnp.zeros((w,))},
        }
        if s != 1 or cin != w:
            blk["proj"] = {"w": _conv_init(kk[2], 1, cin, w)}
        blocks.append(blk)
        cin = w
    params["blocks"] = blocks
    params["head"] = {
        "w": _conv_init(ks[1], 1, cin, cfg.d_embed)[0],  # (Cin, d)
    }
    return params


def apply_stem(cfg, params, mel):
    """mel: (B, T, n_mels) -> (B, T, widths[0])."""
    return jax.nn.relu(_conv1d(mel, params["stem"]["w"]))


def apply_block(cfg, blk, x, stride):
    h = _conv1d(x, blk["conv1"]["w"], stride)
    h = jax.nn.relu(_groupnorm(blk["gn1"], h, cfg.groups))
    h = _conv1d(h, blk["conv2"]["w"])
    h = _groupnorm(blk["gn2"], h, cfg.groups)
    if "proj" in blk:
        x = _conv1d(x, blk["proj"]["w"], stride)
    return jax.nn.relu(x + h)


def apply_blocks(cfg, params, x, start, end):
    """Run blocks [start, end) — the split engine's stage executor."""
    for i in range(start, end):
        x = apply_block(cfg, params["blocks"][i], x, cfg.strides[i])
    return x


def apply_head(cfg, params, x):
    """(B, T', C) -> l2-normalized (B, d_embed).

    The projection is written as an explicit multiply-reduce rather than
    ``pooled @ w``: XLA CPU partitions a (B, C) @ (C, d) GEMM differently
    per batch size (K-splitting), so the GEMM form makes the same sample
    produce different low bits at B=1 vs B=32.  The reduce form keeps the
    per-sample accumulation order batch-invariant, which is what lets the
    gateway's k-bucketed dispatch bit-match per-frame ``SplitEngine.run``
    (tests/test_gateway.py pins this).  Accepted global cost: the reduce
    form materializes a (B, C, d) intermediate and skips GEMM kernels —
    negligible next to the conv stack at this model family's head sizes,
    and paid on training paths too so every consumer sees one set of
    numerics.
    """
    pooled = x.mean(axis=1)
    w = params["head"]["w"]
    z = jnp.sum(pooled[:, :, None] * w[None, :, :], axis=1)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)


def encode(cfg, params, mel, *, start=0, end=None):
    """Full path: stem -> blocks -> head (start/end for split execution)."""
    end = cfg.n_blocks if end is None else end
    x = apply_stem(cfg, params, mel) if start == 0 else mel
    x = apply_blocks(cfg, params, x, start, end)
    if end == cfg.n_blocks:
        return apply_head(cfg, params, x)
    return x  # intermediate activation (the wire payload)


def block_flops(cfg, frames=None):
    """Per-block forward FLOPs for one sample — drives the latency/energy
    models in core/env.py."""
    T = frames or cfg.frames
    out = []
    cin = cfg.widths[0]
    t = T
    for w, s in zip(cfg.widths, cfg.strides):
        t_out = t // s
        f = 2 * cfg.kernel * cin * w * t_out + 2 * cfg.kernel * w * w * t_out
        if s != 1 or cin != w:
            f += 2 * cin * w * t_out
        out.append(f)
        cin, t = w, t_out
    return out


def boundary_bytes(cfg, frames=None, *, dtype_bytes=4):
    """Wire payload size (bytes/sample) if split AFTER block i (i=0 => raw
    input; i=n_blocks => embedding only)."""
    T = frames or cfg.frames
    sizes = [T * cfg.n_mels * dtype_bytes]  # k=0: send raw mel
    t = T
    for w, s in zip(cfg.widths, cfg.strides):
        t = t // s
        sizes.append(t * w * dtype_bytes)
    sizes[-1] = cfg.d_embed * dtype_bytes  # after last block only z crosses
    return sizes
