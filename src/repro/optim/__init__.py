"""Pure-JAX optimizers + schedules + gradient compression."""
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.sgd import sgd_init, sgd_update

OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
    "sgd": (sgd_init, sgd_update),
}


def get_optimizer(name):
    return OPTIMIZERS[name]
