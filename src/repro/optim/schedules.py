"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak, warmup, total, floor=0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def rsqrt(step, *, peak, warmup):
    step = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(step / jnp.maximum(warmup, 1),
                              jnp.sqrt(warmup / jnp.maximum(step, 1.0)))


def constant(step, *, peak, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak)


SCHEDULES = {"cosine": warmup_cosine, "rsqrt": rsqrt, "constant": constant}
