"""AdamW (decoupled weight decay) — the edge-side optimizer (paper §5 uses
Adam lr=1e-3) and the default LM trainer optimizer.

State: fp32 m, v (+ int32 step).  For multi-billion-param archs prefer
Adafactor (optim/adafactor.py); EXPERIMENTS.md §Dry-run quantifies why.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    f32 = lambda t: jnp.zeros(t.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0, grad_clip=1.0):
    step = state["step"] + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) *
                     jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    params = jax.tree.map(upd, params, m, v)
    return params, {"m": m, "v": v, "step": step}
