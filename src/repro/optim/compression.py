"""Gradient compression for cross-pod synchronization.

``int8_psum`` is a *real* int8-wire all-reduce: the scale is agreed via a
pmax, payloads cross the link as int8 (summed in int32), and the result is
dequantized — 4x fewer bytes than fp32 on the slow inter-pod link.
``ErrorFeedback`` keeps the quantization residual and re-injects it next
step (Seide et al. / EF-SGD), which restores convergence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_psum(x, axis_name, *, n_shards=None):
    """All-reduce-sum with an int8 wire format (per-tensor shared scale)."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def compress_decompress(x):
    """Local quantize→dequantize (what the wire does to the tensor)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q * scale


class ErrorFeedback:
    """Functional error-feedback state for compressed gradient sync."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, ef_state, axis_name=None):
        """-> (synced_grads, new_ef_state).

        g' = compress(g + e);  e' = (g + e) - g'_local_payload
        With ``axis_name`` the compressed payload is int8-psum'd."""
        def leaf(g, e):
            y = g.astype(jnp.float32) + e
            if axis_name is None:
                payload = compress_decompress(y)
                synced = payload
            else:
                amax = jax.lax.pmax(jnp.max(jnp.abs(y)), axis_name)
                scale = jnp.maximum(amax / 127.0, 1e-12)
                q = jnp.clip(jnp.round(y / scale), -127, 127)
                payload = q * scale
                synced = jax.lax.pmean(payload, axis_name)
            return synced.astype(g.dtype), y - payload

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(ef_state)
        out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))


def wire_bytes_fp32(params):
    return sum(t.size * 4 for t in jax.tree.leaves(params))


def wire_bytes_int8(params):
    return sum(t.size * 1 + 4 for t in jax.tree.leaves(params))
