"""SGD with momentum (the paper's server-side optimizer, §5)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params):
    return (jax.tree.map(jnp.zeros_like, params),)


def sgd_update(params, grads, state, *, lr, momentum=0.9, nesterov=False):
    (m,) = state
    m = jax.tree.map(lambda a, g: momentum * a + g, m, grads)
    if nesterov:
        upd = jax.tree.map(lambda g, a: g + momentum * a, grads, m)
    else:
        upd = m
    params = jax.tree.map(lambda p, u: p - lr * u.astype(p.dtype), params, upd)
    return params, (m,)
