"""Adafactor (Shazeer & Stern, arXiv:1804.04235) with factored second
moments — the memory-feasible optimizer for kimi-k2-1t: Adam fp32 states
for 1T params need ~12 TB (> the 8 TB of a 512-chip v5e fleet); factored
row/col statistics cut optimizer memory to O(rows+cols) per matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def leaf(t):
        if _factored(t.shape):
            return {
                "vr": jnp.zeros(t.shape[:-1], jnp.float32),   # reduce last
                "vc": jnp.zeros(t.shape[:-2] + t.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(t.shape, jnp.float32)}

    return {
        "stats": jax.tree.map(leaf, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)          # increasing-decay schedule

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p.shape):
            vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            r = vr / jnp.maximum(denom, eps)
            u = g * jax.lax.rsqrt(r)[..., None] * jax.lax.rsqrt(
                vc)[..., None, :]
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(v)
            new_s = {"v": v}
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["stats"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    params = tdef.unflatten([o[0] for o in out])
    stats = tdef.unflatten([o[1] for o in out])
    return params, {"stats": stats, "step": step}
