"""Logical-axis sharding rules + context.

Model code annotates params and activations with *logical* axis names
("batch", "heads", "mlp", ...).  The launcher installs a rule set mapping
logical names to mesh axes; outside any context (unit tests, smoke runs on
one device) every annotation is a no-op.

Params and activations use separate rule dicts because the same logical
name ("embed") is FSDP-sharded on params but replicated on activations.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


class AxisRules:
    """A mapping logical-axis-name -> mesh axis (str | tuple | None)."""

    def __init__(self, param_rules: dict, act_rules: dict, mesh: Mesh | None):
        self.param_rules = dict(param_rules)
        self.act_rules = dict(act_rules)
        self.mesh = mesh

    def spec(self, axes: tuple, *, kind: str = "act") -> P:
        rules = self.param_rules if kind == "param" else self.act_rules
        return P(*[rules.get(a) for a in axes])


def current_rules() -> AxisRules | None:
    return getattr(_CTX, "rules", None)


@contextmanager
def axis_rules(rules: AxisRules):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield rules
    finally:
        _CTX.rules = prev


def logical_spec(axes: tuple, *, kind: str = "act") -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(axes, kind=kind)


def shard(x, *axes):
    """Constrain activation ``x`` to the sharding implied by logical axes.

    No-op when no rules are installed (single-device tests) so model code can
    annotate unconditionally.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(axes, kind="act")
    return jax.lax.with_sharding_constraint(x, spec)


def param_sharding(axes_tree, mesh: Mesh | None = None):
    """Tree of NamedShardings for a params tree of logical-axes tuples."""
    rules = current_rules()
    if rules is None:
        return None
    mesh = mesh or rules.mesh
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec(axes, kind="param")),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def param_pspecs(axes_tree):
    """Tree of PartitionSpecs for a params tree of logical-axes tuples."""
    rules = current_rules()
    if rules is None:
        return jax.tree.map(
            lambda axes: P(), axes_tree, is_leaf=lambda x: isinstance(x, tuple)
        )
    return jax.tree.map(
        lambda axes: rules.spec(axes, kind="param"),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


# ---------------------------------------------------------------------------
# Standard rule sets.
# ---------------------------------------------------------------------------

def make_rules(
    mesh: Mesh,
    *,
    fsdp: bool = False,
    seq_sharded: bool = False,
) -> AxisRules:
    """Build the standard DP/TP(/EP/SP) rules for a ('pod'?,'data','model') mesh.

    - batch      -> ('pod','data')  (DP; 'pod' folded in when present)
    - heads/mlp/vocab/experts -> 'model'  (TP / EP)
    - embed      -> 'data' on *params* when fsdp=True (FSDP weight shard)
    - seq        -> 'data' on activations when seq_sharded (SP, used by the
                    500k-context cells where batch==1)
    """
    axis_names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    batch = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    common = {
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "ssm_group": None,
        "conv": None,
        "layers": None,
        "stack": None,
        "proj": None,
        "classes": None,
    }
    param_rules = dict(common)
    param_rules["embed"] = "data" if fsdp else None
    param_rules["batch"] = None
    param_rules["seq"] = None

    act_rules = dict(common)
    act_rules["embed"] = None
    act_rules["batch"] = batch
    act_rules["seq"] = "data" if seq_sharded else None
    # activations never sharded along these on top of batch:
    act_rules["experts"] = "model"

    return AxisRules(param_rules, act_rules, mesh)


def rules_for(mesh: Mesh, cfg, *, batch=None, kind="train",
              fsdp=False) -> AxisRules:
    """Arch- and shape-aware rules for the production mesh.

    TP strategy per tensor class (DESIGN.md / EXPERIMENTS.md §Dry-run):
    - q/kv heads shard over 'model' when the head count divides it
      (column-parallel); otherwise the projection falls back to
      *row-parallel* (contract dim over 'model', psum'd output) so the
      matmul FLOPs still shard even when heads don't (arctic/llava 56H,
      gemma2 8H on a 16-way axis).
    - mlp/vocab/experts always shard over 'model'.
    - fsdp=True additionally shards the weights' embed dim over 'data'
      (gathered per layer inside the scan) — required for >=15B archs.
    - decode KV caches shard kv_heads over 'model' when divisible, else
      the *sequence* dim ("kv_seq") — flash-decoding style.
    - batch shards over ('pod','data') when divisible; the 500k-context
      batch=1 cells leave batch unsharded and shard cache seq over 'data'.
    """
    ms = mesh.shape["model"]
    ds = mesh.shape.get("data", 1)
    axis_names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    batch_spec = (batch_axes if len(batch_axes) > 1 else batch_axes[0]) \
        if (batch is None or batch % dp == 0) and batch != 1 else None

    heads_ok = bool(getattr(cfg, "n_heads", 0)) and cfg.n_heads % ms == 0
    kv_ok = bool(getattr(cfg, "n_kv_heads", 0)) and cfg.n_kv_heads % ms == 0
    hd = getattr(cfg, "head_dim", 0) or 0
    hd_ok = hd % ds == 0 if hd else False
    small_batch = batch == 1

    param_rules = {
        # attention.  (A replicated-k/v variant for GQA with kv < TP was
        # explored — it cuts the collective term 2.4x but doubles the
        # memory/compute terms via replicated score tensors; net MFU
        # regression, so row-parallel k/v stays the default.  See
        # EXPERIMENTS.md §Perf iterations 2-3.)
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "q_in": (("data" if fsdp else None) if heads_ok else "model"),
        "kv_in": (("data" if fsdp else None) if kv_ok else "model"),
        "q_hd": ("data" if (fsdp and not heads_ok and hd_ok) else None),
        "kv_hd": ("data" if (fsdp and not kv_ok and hd_ok) else None),
        "o_hd": None if heads_ok else "model",
        # mlp / embeddings / moe / ssm
        "embed": "data" if fsdp else None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "router": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "ssm_group": None,
        "conv": None,
        "head_dim": None,
        "layers": None,
        "batch": None,
        "seq": None,
        "kv_seq": None,
        "classes": None,
        "stack": "pod" if "pod" in axis_names else None,
    }
    act_rules = {
        "batch": batch_spec,
        "seq": ("data" if small_batch and kind != "train" else None),
        "embed": None,
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "layers": None,
        "conv": None,
        "kv_seq": ("model" if not kv_ok else
                   ("data" if small_batch else None)),
        "classes": None,
    }
    return AxisRules(param_rules, act_rules, mesh)


# ---------------------------------------------------------------------------
# Fleet/session axis (the serving data plane).
# ---------------------------------------------------------------------------

# The mesh axis the fleet data plane shards the session dimension over:
# every (N, W, d) session ring, its timestamp/label rings, and the
# per-session masks are partitioned on dim 0 (see docs/SHARDING.md and
# core/fleet_backend.py::ShardedFleetBackend).
SESSIONS_AXIS = "sessions"


def sessions_spec(axis: str = SESSIONS_AXIS) -> P:
    """PartitionSpec sharding dim 0 (the session axis) over ``axis`` and
    replicating everything trailing (window, embed)."""
    return P(axis)


def sessions_sharding(mesh: Mesh, axis: str = SESSIONS_AXIS) -> NamedSharding:
    """NamedSharding placing fleet state on a ``sessions`` mesh axis."""
    return NamedSharding(mesh, sessions_spec(axis))


def mesh_axis_size(name: str) -> int:
    rules = current_rules()
    if rules is None or rules.mesh is None or name not in rules.mesh.axis_names:
        return 1
    return rules.mesh.shape[name]


def get_mesh() -> Mesh | None:
    rules = current_rules()
    return None if rules is None else rules.mesh
