"""Manual data-parallel training step with *compressed* cross-shard
gradient synchronization (int8 wire + error feedback) — the
distributed-optimization trick for the slow inter-pod link.

Under GSPMD the gradient all-reduce is implicit (and fp32/bf16 on the
wire); this explicit shard_map variant trades that for a 4x smaller
payload on the designated axis, with EF-SGD convergence (tests verify
parity with uncompressed sync on a quadratic and an LM smoke model).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.compression import ErrorFeedback


def pmean_grads(grads, axis_name):
    """Cross-shard gradient mean — the uncompressed synchronization used
    by the sharded fleet backend's refine step (the loss is pre-scaled by
    the shard count, so the pmean reconstructs the global psum; see
    ``core.fleet_refiner.make_fleet_loss``)."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)


def psum_grads(grads, axis_name):
    """Cross-shard gradient sum, for losses that already carry global
    normalization."""
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads)


def make_compressed_dp_step(mesh, loss_fn, opt_update, *, axis="data",
                            lr=1e-3, compress=True, opt_kwargs=None):
    """loss_fn(params, batch) -> scalar;  batch sharded over ``axis``.

    Returns step(params, opt_state, ef_state, batch) with params replicated
    and gradients synchronized via int8 psum + error feedback."""
    opt_kwargs = opt_kwargs or {}

    def local_step(params, opt_state, ef, batch):
        grads = jax.grad(loss_fn)(params, batch)
        if compress:
            grads, ef = ErrorFeedback.apply(grads, ef, axis_name=axis)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        params, opt_state = opt_update(params, grads, opt_state, lr=lr,
                                       **opt_kwargs)
        return params, opt_state, ef

    def batch_spec(batch):
        return jax.tree.map(lambda _: P(axis), batch)

    jitted = {}   # one jitted step per batch tree structure — rebuilding
                  # per call would retrace/recompile every training step

    def step(params, opt_state, ef, batch):
        structure = jax.tree.structure(batch)
        if structure not in jitted:
            from repro.compat import shard_map
            jitted[structure] = jax.jit(shard_map(
                local_step, mesh=mesh,
                in_specs=(P(), P(), P(), batch_spec(batch)),
                out_specs=(P(), P(), P()),
                check_vma=False))
        return jitted[structure](params, opt_state, ef, batch)

    return step


def ef_init(params):
    return ErrorFeedback.init(params)
