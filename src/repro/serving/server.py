"""``StreamServer`` — the always-on streaming runtime over the gateway.

The paper's premise is *continuous* ambient audio meeting discrete batch
compute; this module closes that gap.  Instead of a hand-rolled
``submit``/``tick`` loop, clients talk to a server that owns:

- a **background serving thread** draining bounded per-QoS-class ingest
  queues (``serving/queues.py``) — clients ``submit`` from any thread
  and get backpressure (``QueueFullError``), never silent loss;
- the **deadline-aware ``TickScheduler``** (``serving/scheduler.py``)
  composing each tick by class priority, with BULK preemption under
  load and per-class wait/deadline accounting;
- **cross-tick pipelining** over the gateway's ``tick_launch`` /
  ``tick_collect`` seam: tick t+1 is staged H2D and its bucket chains
  launched while tick t's chains are still in flight, so the dispatch
  plane never idles between ticks and ``device_syncs_per_tick`` stays 1
  (double-buffered: at most one collected-pending tick at a time).

Determinism is load-bearing: the serving thread only ever runs
``step()``, which is also public — tests drive it synchronously with a
fake clock and get byte-for-byte reproducible schedules, and the
benchmark replays a recorded schedule through a plain sequential
gateway to assert the served embeddings are **bit-identical**
(``benchmarks/stream_serve.py``; docs/STREAMING.md).

One serving-order caveat, by design: when a fleet refine round is due,
the server drains its pipeline first (collects tick t before launching
t+1), so refinement sees exactly the frames a sequential gateway would
have ingested by that tick — pipelining never reorders learning.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import replace

from repro.api.types import (FrameRequest, QoSClass,
                             QueuedFrameSnapshot, ResourceSignals,
                             ServerSessionSnapshot, SessionInfo,
                             SessionSnapshot, StreamStats)
from repro.obs import FlightRecorder, Tracer, to_prometheus
from repro.serving.queues import (QoSQueues, QueuedFrame,  # noqa: F401
                                  QueueFullError, RateLimitError,
                                  TokenBucket)
from repro.serving.scheduler import (SchedulerCfg, TickScheduler,
                                     clamp_weight)

_UNSET = object()          # open_session(rate_limit=...) sentinel


class _ServedSession:
    """Server-side session record (the gateway keeps its own)."""

    __slots__ = ("sid", "qos", "submitted", "served", "shed", "weight",
                 "bucket", "closing", "closed")

    def __init__(self, sid, qos, *, weight=1.0, bucket=None):
        self.sid = sid
        self.qos = qos
        self.submitted = 0       # frames accepted into the queues
        self.served = 0          # frames delivered as FrameResults
        self.shed = 0            # frames visibly dropped past the horizon
        self.weight = weight     # STANDARD fair-share weight (DRR)
        self.bucket = bucket     # per-session TokenBucket (or None)
        self.closing = False     # no new submits; drain then evict
        self.closed = threading.Event()


class StreamServer:
    """Always-on serving runtime over a ``StreamSplitGateway``.

    Parameters
    ----------
    gateway : a ``StreamSplitGateway`` built with ``overlap=True`` (the
        phased tick is the pipelining seam).  The server owns the
        gateway once serving starts: all ``submit``/``tick`` traffic
        must flow through the server.
    cfg : ``SchedulerCfg`` — tick width, per-class deadline budgets,
        BULK preemption.
    queue_maxlen / queue_maxlens : bounded ingest queue capacity
        (per-class override via ``queue_maxlens``).
    pipeline : ``False`` degrades to launch+collect back-to-back (no
        cross-tick overlap) — the measured baseline knob.
    on_result : optional callable invoked with each ``FrameResult`` on
        the serving thread (keep it cheap).  With a callback installed
        results are NOT also buffered — an always-on server must not
        grow with uptime; without one they accumulate until
        ``drain_results()``, which the caller is expected to poll.
    on_shed : optional callable invoked with each shed ``QueuedFrame``
        on the serving thread, right after the shed pass folds it into
        the per-session books — the federation layer
        (``repro.cluster``) counts cluster-wide sheds here.  Same
        contract as ``on_result``: keep it cheap, exceptions are
        printed and swallowed.
    on_admit : optional callable invoked with the ``QueuedFrame`` the
        moment ``submit`` accepts a frame into the queues (on the
        SUBMITTING thread, past the rate-limit and capacity checks) —
        the journal-ack seam: the federation layer's replication plane
        (``repro.cluster.replication``) write-ahead-journals exactly
        the frames the member accepted, with their original enqueue
        time and deadline.  Frames implanted by ``import_session`` do
        NOT fire it — their ledger (and journal entry) travelled with
        them.  Exceptions propagate to the submitter: a frame whose
        journal append failed was never durably accepted.
    clock : timing source; defaults to the gateway's injected clock so
        one fake clock drives queue waits, deadlines, rate limits and
        tick latency.
    rate_limit : optional ``(rate_per_s, burst)`` default token-bucket
        admission control applied to every session (override or disable
        per session at ``open_session``).  An exhausted bucket refuses
        the frame with the typed ``RateLimitError``, counted in
        ``StreamStats.rejected_rate_limited`` — never silent.
    schedule_keep : how many recent ticks of the admitted schedule to
        retain for ``schedule()`` replay/debugging (bounded for the
        same always-on reason).
    """

    def __init__(self, gateway, *, cfg: SchedulerCfg | None = None,
                 queue_maxlen: int = 256, queue_maxlens=None,
                 pipeline: bool = True, on_result=None, on_shed=None,
                 on_admit=None, clock=None,
                 rate_limit: tuple | None = None,
                 schedule_keep: int = 4096,
                 trace_sample: float = 0.0, recorder=None):
        if not gateway.overlap:
            raise ValueError(
                "StreamServer pipelines tick_launch/tick_collect — "
                "construct the gateway with overlap=True")
        self.gateway = gateway
        self.cfg = cfg = cfg if cfg is not None else SchedulerCfg()
        self.pipeline = pipeline
        self._clock = clock if clock is not None else gateway.clock
        # one telemetry plane for the whole stack (repro.obs;
        # docs/OBSERVABILITY.md): the gateway's registry is shared down
        # into the queues and scheduler, the flight recorder collects
        # every anomaly, and the tracer samples per-frame spans
        # (trace_sample=0.0 — the default — stamps NOTHING on the hot
        # path: frames carry trace=None and every stamp site is one
        # attribute test)
        self.registry = gateway.registry
        self.recorder = recorder if recorder is not None \
            else FlightRecorder(clock=self._clock)
        self.tracer = Tracer(trace_sample, clock=self._clock,
                             recorder=self.recorder)
        self.queues = QoSQueues(maxlen=queue_maxlen, maxlens=queue_maxlens,
                                registry=self.registry)
        self.scheduler = TickScheduler(cfg, registry=self.registry,
                                       recorder=self.recorder)
        self._on_result = on_result
        self._on_shed = on_shed
        self._on_admit = on_admit
        self._rate_limit = rate_limit
        self._sessions: dict[int, _ServedSession] = {}
        self._lock = threading.RLock()        # session table + gateway admin
        # serializes start()/stop() against each other: without it two
        # threads can both observe a dead _thread and spawn two serving
        # loops (check-then-act race)
        self._life = threading.Lock()
        # serializes step(): normally only the serving thread runs it,
        # but close_session's caller-driven fallback (no live thread)
        # may be entered from several client threads at once
        self._step_lock = threading.Lock()
        self._plan = None                     # the in-flight TickPlan
        self._plan_classes: list[str] = []    # its frames' classes
        self._plan_traces: list = []          # its frames' FrameTraces
        #                                       (parallel; None when off)
        self._results: list = []              # drained by drain_results()
        # per tick: [(sid, t), ...] — BOUNDED: an always-on server must
        # not grow host state with uptime, so only the newest
        # ``schedule_keep`` ticks are retained for replay/debugging
        self._schedule: deque = deque(maxlen=schedule_keep)
        R = self.registry
        self._pipelined_ticks = R.counter("stream_pipelined_ticks")
        self._ticks = R.counter("stream_ticks")
        self._served = {q.value: R.counter("stream_frames_served",
                                           qos=q.value) for q in QoSClass}
        # frames admitted out of the queues but not yet delivered —
        # updated under _lock inside the admit/collect transitions so
        # the StreamStats conservation invariant holds at every snapshot
        # (a Counter, not a Gauge: it is an integer level in the
        # conservation identity and must stay bit-exact)
        self._inflight = {q.value: R.counter("stream_in_flight",
                                             qos=q.value)
                          for q in QoSClass}
        # token-bucket refusals per class — admission control happens
        # before a frame touches the queues, so the counter lives here
        # (mutated and snapshotted under _lock)
        self._rate_limited = {q.value: R.counter(
            "stream_rejected_rate_limited", qos=q.value)
            for q in QoSClass}
        self._thread: threading.Thread | None = None
        self._stopping = False
        self._drain_on_stop = True
        self._closing_n = 0                   # sessions draining to close
        self._fault: BaseException | None = None   # serving-loop death

    # -- session lifecycle (any thread) --------------------------------------
    def open_session(self, platform="pi4",
                     qos: QoSClass = QoSClass.STANDARD, *,
                     weight: float = 1.0,
                     rate_limit=_UNSET) -> SessionInfo:
        """Admit a session (delegates to the gateway, which may raise
        the typed ``AdmissionError``).

        ``weight`` is the session's STANDARD fair-share weight (DRR;
        clamped, only meaningful for ``QoSClass.STANDARD``).
        ``rate_limit`` is a per-session ``(rate_per_s, burst)`` token
        bucket; leave unset to inherit the server default, pass ``None``
        to disable for this session."""
        limit = self._rate_limit if rate_limit is _UNSET else rate_limit
        bucket = (TokenBucket(limit[0], limit[1], now=self._clock())
                  if limit is not None else None)
        with self._lock:
            info = self.gateway.open_session(platform=platform, qos=qos)
            self._sessions[info.sid] = _ServedSession(
                info.sid, qos, weight=clamp_weight(weight), bucket=bucket)
            return info

    def close_session(self, sid, *, timeout: float | None = 30.0) -> None:
        """Graceful close: no new submits are accepted, every frame
        already accepted for the session is still served (or, with a
        shed horizon configured, visibly shed once its deadline is long
        past — never silently dropped), then the gateway evicts the
        row.  Blocks until drained when the serving thread runs (raises
        ``TimeoutError`` past ``timeout``); otherwise the caller drives
        ``step()`` to completion."""
        with self._lock:
            s = self._require(sid)
            if not s.closing:       # concurrent closers all wait below
                s.closing = True
                self._closing_n += 1
        with self.queues.cond:
            self.queues.cond.notify_all()
        t = self._thread
        if threading.current_thread() is t:
            # called ON the serving thread (e.g. from an on_result
            # callback): waiting would self-deadlock — the close is
            # marked and _process_closes completes it this same loop
            return
        if t is not None and t.is_alive():
            if not s.closed.wait(timeout):
                self._check_fault()    # the real cause, if the loop died
                raise TimeoutError(f"session {sid} did not drain in "
                                   f"{timeout}s")
        else:
            while not s.closed.is_set():
                self._check_fault()
                self.step()

    def _require(self, sid) -> _ServedSession:
        s = self._sessions.get(sid)
        if s is None:
            raise KeyError(f"session {sid} is not open")
        return s

    # -- live migration (repro.cluster; docs/FEDERATION.md) ------------------
    def quiesce(self) -> int:
        """Collect the in-flight tick, if any, and deliver its results —
        the migration barrier: after ``quiesce()`` no frame is between
        ``tick_launch`` and ``tick_collect``, so ``export_session`` can
        take a complete snapshot.  Returns frames delivered.  Intended
        for stepped (thread-less) operation; with the serving thread
        running, ``stop(drain=False)`` first."""
        with self._step_lock:
            return self._collect() if self._plan is not None else 0

    def export_session(self, sid) -> SessionSnapshot:
        """Freeze one session — gateway state (ring row, sync books,
        counters) PLUS the serving-side books: submitted/served/shed,
        DRR weight, token-bucket level, and every waiting frame (queued
        or staged) with its ORIGINAL arrival time and deadline.  The
        session leaves this server: its frames leave the queues with
        their ledger (per-member conservation holds on both sides of a
        migration), and the row is evicted.  Raises ``RuntimeError`` if
        an in-flight tick still holds the session's frames
        (``quiesce()`` first) and ``KeyError`` for unknown or closing
        sessions."""
        with self._step_lock:
            if self._plan is not None and any(
                    p[0] == sid for p in self._plan.pending):
                raise RuntimeError(
                    f"session {sid} has frames in the in-flight tick — "
                    "quiesce() before export_session()")
            with self.queues.cond:
                with self._lock:
                    s = self._require(sid)
                    if s.closing:
                        raise KeyError(f"session {sid} is closing")
                    staged = self.scheduler.extract_session_locked(sid)
                    if staged:
                        self.queues.uncount_locked(s.qos, len(staged))
                    queued = self.queues.extract_session_locked(s.qos, sid)
                    frames = sorted(staged + queued, key=lambda qf: qf.seq)
                    now = None      # lazy clock: only if a trace is live
                    for qf in frames:
                        if qf.trace is not None:
                            if now is None:
                                now = self._clock()
                            qf.trace.add("migrate_out", now)
                    snap = self.gateway.export_session(sid)
                    del self._sessions[sid]
                    bucket = (None if s.bucket is None else
                              (s.bucket.rate_per_s, s.bucket.burst,
                               s.bucket.tokens, s.bucket._last))
                    server = ServerSessionSnapshot(
                        submitted=s.submitted, served=s.served,
                        shed=s.shed, weight=s.weight, bucket=bucket,
                        queued=tuple(
                            QueuedFrameSnapshot(
                                frame=qf.frame, enq_s=qf.enq_s,
                                deadline_s=qf.deadline_s,
                                preemptions=qf.preemptions,
                                promoted=qf.promoted, weight=qf.weight,
                                trace=qf.trace)
                            for qf in frames))
                    return replace(snap, server=server)

    def checkpoint_session(self, sid) -> SessionSnapshot:
        """Non-destructive copy of one session — the cluster's
        failure-recovery checkpoint.  Unlike ``export_session`` the
        session KEEPS serving here, and waiting frames are NOT captured
        (a checkpoint restore resumes from the last served frame; it
        cannot resurrect a dead member's queues — the cluster counts
        those frames in ``lost_in_flight`` instead).  The snapshot's
        books are therefore SETTLED — ``submitted == served + shed``,
        ``queued=()`` — so a restored session can always drain to
        close.  Same quiesce precondition as ``export_session``."""
        with self._step_lock:
            if self._plan is not None and any(
                    p[0] == sid for p in self._plan.pending):
                raise RuntimeError(
                    f"session {sid} has frames in the in-flight tick — "
                    "quiesce() before checkpoint_session()")
            with self.queues.cond:
                with self._lock:
                    s = self._require(sid)
                    if s.closing:
                        raise KeyError(f"session {sid} is closing")
                    snap = self.gateway.export_session(sid, remove=False)
                    bucket = (None if s.bucket is None else
                              (s.bucket.rate_per_s, s.bucket.burst,
                               s.bucket.tokens, s.bucket._last))
                    server = ServerSessionSnapshot(
                        submitted=s.served + s.shed, served=s.served,
                        shed=s.shed, weight=s.weight, bucket=bucket,
                        queued=())
                    return replace(snap, server=server)

    def import_session(self, snap: SessionSnapshot) -> SessionInfo:
        """Resume an exported session here — the other half of a
        migration.  The gateway re-admits the row (same ``AdmissionError``
        surface as ``open_session``; the sid is fresh), the serving
        books and token-bucket level are restored, and the snapshot's
        waiting frames re-enter the queues at their ``enq_s``-sorted
        positions with their ORIGINAL deadlines — no re-validation, no
        rate-limit charge, no submit-count: their ledger arrived with
        them.  Returns the new ``SessionInfo``."""
        with self._step_lock:
            with self.queues.cond:
                with self._lock:
                    info = self.gateway.import_session(snap)
                    sv = snap.server
                    if sv is None:          # bare gateway-level snapshot
                        sv = ServerSessionSnapshot(
                            submitted=0, served=0, shed=0, weight=1.0)
                    if sv.bucket is not None:
                        rate, burst, tokens, last = sv.bucket
                        bucket = TokenBucket(rate, burst, now=last)
                        bucket.tokens = tokens
                    elif snap.server is None and self._rate_limit:
                        bucket = TokenBucket(self._rate_limit[0],
                                             self._rate_limit[1],
                                             now=self._clock())
                    else:
                        bucket = None
                    s = _ServedSession(info.sid, snap.qos,
                                       weight=clamp_weight(sv.weight),
                                       bucket=bucket)
                    s.submitted, s.served, s.shed = (
                        sv.submitted, sv.served, sv.shed)
                    self._sessions[info.sid] = s
                    implanted = self.queues.implant_frames_locked(
                        info.sid, sv.queued, snap.qos)
                    now = None   # lazy clock: only if a trace travelled
                    for qf in implanted:
                        if qf.trace is not None:
                            if now is None:
                                now = self._clock()
                            qf.trace.add("migrate_in", now,
                                         sid=info.sid)
                    return info

    def _check_fault(self) -> None:
        """Re-raise a serving-loop death at the caller: producers and
        waiters must fail fast, not hang on a server that will never
        serve again (the original traceback was already printed)."""
        if self._fault is not None:
            raise RuntimeError(
                "serving loop died mid-run") from self._fault

    # -- ingest (any thread) -------------------------------------------------
    def submit(self, sid, frame: FrameRequest) -> None:
        """Enqueue one frame.  Validates + converts the mel HERE (on the
        client's thread) so the serving thread never pays conversion;
        raises ``RateLimitError`` when the session's token bucket is
        empty, ``QueueFullError`` when the session's class queue is at
        capacity, and ``KeyError`` once the session is closing."""
        self._check_fault()
        with self._lock:
            s = self._require(sid)
            if s.closing:
                raise KeyError(f"session {sid} is closing")
        mel = self.gateway.validate_mel(frame.mel)   # the one validation
        if mel is not frame.mel:
            frame = replace(frame, mel=mel)
        now = self._clock()
        # count the frame BEFORE it becomes visible in the queues (and
        # roll back on refusal): _process_closes compares served + shed
        # == submitted, so an enqueued-but-uncounted frame could let a
        # racing close_session evict the row out from under it
        with self._lock:
            if s.closing:
                raise KeyError(f"session {sid} is closing")
            if s.bucket is not None and not s.bucket.try_take(now):
                self._rate_limited[s.qos.value].inc()
                self.recorder.record("rate_limited", now, sid=sid,
                                     qos=s.qos.value, t=frame.t)
                raise RateLimitError(sid, s.qos,
                                     s.bucket.retry_after_s(now))
            s.submitted += 1
        # per-frame span begins here; with sampling off (the default)
        # this is one float compare and tr stays None everywhere
        tr = self.tracer.maybe_begin(sid, frame.t, now,
                                     qos=s.qos.value) \
            if self.tracer.sample > 0.0 else None
        try:
            qf = self.queues.submit(sid, frame, s.qos, now=now,
                                    deadline_s=now
                                    + self.cfg.deadline_s(s.qos),
                                    weight=s.weight, trace=tr)
        except BaseException as e:
            with self._lock:
                s.submitted -= 1
                if s.bucket is not None:
                    s.bucket.give_back()    # a refused frame costs no budget
            if isinstance(e, QueueFullError):
                self.recorder.record("queue_full", now, sid=sid,
                                     qos=s.qos.value, t=frame.t,
                                     depth=e.depth, maxlen=e.maxlen)
            raise
        if self._on_admit is not None:
            # the journal-ack seam (repro.cluster.replication): a frame
            # is only durably accepted once its write-ahead append
            # succeeded.  On failure the frame is withdrawn (identity
            # match — QueuedFrame's field equality is meaningless) and
            # the books roll back like any other refusal; if the
            # serving thread already staged it, acceptance stands.
            try:
                self._on_admit(qf)
            except BaseException:
                withdrawn = False
                with self.queues.cond:
                    cq = self.queues.by_class[s.qos]
                    for i, x in enumerate(cq.q):
                        if x is qf:
                            del cq.q[i]
                            cq.submitted -= 1
                            withdrawn = True
                            break
                if withdrawn:
                    with self._lock:
                        s.submitted -= 1
                        if s.bucket is not None:
                            s.bucket.give_back()
                raise

    # -- the serving loop ----------------------------------------------------
    def start(self) -> "StreamServer":
        """Launch the background serving thread (idempotent, and safe
        to race: the check-and-spawn is serialized under ``_life`` so
        two callers can never start two serving loops)."""
        with self._life:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stopping = False
            self._thread = threading.Thread(target=self._loop,
                                            name="streamsplit-serve",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = 60.0):
        """Stop serving.  ``drain=True`` (default) serves every queued
        frame first; ``drain=False`` collects only the in-flight tick
        and leaves the backlog measurable in ``stats().queue_depth``.
        Serialized against ``start()`` (and concurrent ``stop()``s)
        under ``_life`` — the serving thread itself never takes that
        lock, so joining under it cannot deadlock."""
        with self._life:
            self._drain_on_stop = drain
            self._stopping = True
            with self.queues.cond:
                self.queues.cond.notify_all()
            t = self._thread
            if t is not None and t is not threading.current_thread():
                t.join(timeout)
                if t.is_alive():
                    raise TimeoutError("serving thread did not stop")
            self._thread = None
            if self._fault is not None:
                # the loop died on an exception earlier (already printed
                # with traceback): surface it loudly at stop time instead
                # of letting the session end "cleanly"
                fault, self._fault = self._fault, None
                raise RuntimeError("serving loop died mid-run") from fault
        return self

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop(drain=not any(exc))

    def _loop(self):
        try:
            while True:
                with self.queues.cond:
                    work = (self.queues.pending_locked()
                            or self.scheduler.staged
                            or self._plan is not None
                            or self._closes_pending())
                    if self._stopping and (not work
                                           or not self._drain_on_stop):
                        break
                    if not work:
                        self.queues.cond.wait(timeout=0.05)
                        continue
                self.step()
            # never leave a launched tick dangling
            with self._step_lock:
                if self._plan is not None:
                    self._collect()
                self._process_closes()
        except BaseException as e:      # noqa: BLE001 — loop boundary
            # an unhandled serving-loop exception must not vanish with
            # the daemon thread: print it now, re-raise it at stop()
            import traceback
            traceback.print_exc()
            self._fault = e

    def step(self) -> int:
        """One serving iteration — public so deterministic tests can
        drive the exact thread loop synchronously.  Returns the number
        of frames delivered.

        Order of operations IS the pipeline:

        1. ``admit`` the staged batch (backfill + BULK preemption),
        2. launch it (``tick_launch``) — while the PREVIOUS tick's
           chains are still in flight (unless a refine round is due or
           ``pipeline=False``, in which case the previous tick collects
           first: learning order always matches the sequential
           gateway),
        3. stage the next batch under the fresh chains,
        4. collect the previous tick and deliver its results,
        5. process session closes whose frames have fully drained.
        """
        with self._step_lock:   # close_session fallbacks may race here
            return self._step_locked()

    def _step_locked(self) -> int:
        gw = self.gateway
        with self.queues.cond:                 # Condition wraps an RLock
            batch = self.scheduler.admit(self.queues, self._clock())
            shed = self.scheduler.pop_shed()
            with self._lock:                   # queue -> in-flight, atomic
                for qf in batch:
                    self._inflight[qf.qos.value].inc()
                # shed frames leave the system here: fold them into the
                # per-session books so a draining close still completes
                for qf in shed:
                    s = self._sessions.get(qf.sid)
                    if s is not None:
                        s.shed += 1
        for qf in shed:
            if qf.trace is not None:
                # the scheduler already stamped the terminal "shed";
                # hand the finished span to the flight recorder
                self.tracer.retire(qf.trace)
        if shed and self._on_shed is not None:
            for qf in shed:        # outside the locks, like on_result
                try:
                    self._on_shed(qf)
                except Exception:   # user code must not kill serving
                    import traceback
                    traceback.print_exc()
        new_plan = None
        new_classes: list[str] = []
        new_traces: list = []
        served = 0
        if batch:
            if self._plan is not None and (not self.pipeline
                                           or gw.refine_due_next_tick()):
                served += self._collect()
            for qf in batch:
                # already validated/converted at enqueue (validate_mel
                # on the client's thread) — skip the re-check here
                gw.submit_validated(qf.sid, qf.frame)
                new_classes.append(qf.qos.value)
                new_traces.append(qf.trace)
            if self._plan is not None:
                with self._lock:               # stats() reads under _lock
                    self._pipelined_ticks.inc()
            new_plan = gw.tick_launch()
            if any(tr is not None for tr in new_traces):
                # stamp dispatch with the bucket/shard the launch chose;
                # idx indexes the submission-ordered batch
                now = self._clock()
                for k, idx, _wire, _ms, sh in new_plan.launched:
                    for i in idx:
                        tr = new_traces[i]
                        if tr is not None:
                            tr.add("dispatch", now, k=int(k),
                                   shard=int(sh))
        self.scheduler.stage(self.queues, self._clock())
        if self._plan is not None:
            served += self._collect()
        self._plan, self._plan_classes = new_plan, new_classes
        self._plan_traces = new_traces
        self._process_closes()
        return served

    def _collect(self) -> int:
        plan, classes = self._plan, self._plan_classes
        traces = self._plan_traces
        self._plan, self._plan_classes, self._plan_traces = None, [], []
        results = self.gateway.tick_collect(plan)
        now = None          # lazy: no clock read unless a trace is live
        for r, tr in zip(results, traces):
            if tr is not None:
                if now is None:
                    now = self._clock()
                tr.add("collect", now)
                self.tracer.finish(tr, "serve", now, route=r.route,
                                   k=r.k, latency_ms=r.latency_ms)
        with self._lock:
            self._ticks.inc()
            self._schedule.append([(r.sid, r.t) for r in results])
            for r, cls in zip(results, classes):
                self._served[cls].inc()
                self._inflight[cls].inc(-1)
                s = self._sessions.get(r.sid)
                if s is not None:
                    s.served += 1
            if self._on_result is None:
                # buffer only when the caller drains: with a callback
                # installed, delivery happens below and an always-on
                # server must not accumulate every result forever
                self._results.extend(results)
        if self._on_result is not None:
            for r in results:
                try:
                    self._on_result(r)
                except Exception:       # user code must not kill serving
                    import traceback
                    traceback.print_exc()
        return len(results)

    def _closes_pending(self) -> bool:
        return self._closing_n > 0            # bare-int read: hot loop

    def _process_closes(self):
        if not self._closing_n:
            return
        with self._lock:
            # every accepted frame is accounted: served as a result or
            # shed visibly past the horizon — only then may the row go
            done = [s for s in self._sessions.values()
                    if s.closing and not s.closed.is_set()
                    and s.served + s.shed == s.submitted
                    and not self._in_pipeline(s.sid)]
            for s in done:
                self.gateway.close_session(s.sid)
                del self._sessions[s.sid]
                self._closing_n -= 1
                s.closed.set()

    def _in_pipeline(self, sid) -> bool:
        if self._plan is not None and any(
                p[0] == sid for p in self._plan.pending):
            return True
        return any(qf.sid == sid for qf in self.scheduler.staged)

    # -- results + observability ---------------------------------------------
    def busy(self) -> bool:
        """Queued, staged, in-flight, or closing work exists right now
        — what the serving loop's own work check sees.  Stepped drivers
        (``repro.cluster``, benchmarks) loop ``step()`` on this."""
        with self.queues.cond:
            return bool(self.queues.pending_locked()
                        or self.scheduler.staged
                        or self._plan is not None
                        or self._closes_pending())

    @property
    def served_total(self) -> int:
        """Frames delivered so far — a bare counter, cheap enough to
        poll from a hot loop (``stats()`` builds percentiles; don't spin
        on it).  Raises if the serving loop died, so progress pollers
        fail fast instead of spinning forever."""
        self._check_fault()
        return sum(c.value for c in self._served.values())

    def drain_results(self) -> list:
        """All ``FrameResult``s delivered since the last drain."""
        self._check_fault()
        with self._lock:
            out, self._results = self._results, []
        return out

    def schedule(self) -> list[list[tuple]]:
        """The admitted schedule (newest ``schedule_keep`` ticks): per
        collected tick, the served ``(sid, t)`` pairs in submission
        order.  Replaying it through a sequential gateway reproduces
        every embedding bit-for-bit (``benchmarks/stream_serve.py``
        asserts this)."""
        with self._lock:       # _collect appends under the same lock
            return [list(t) for t in self._schedule]

    def stats(self) -> StreamStats:
        # one consistent snapshot: queue/staged state and the
        # served/in-flight/shed counters are read under the same lock
        # pair (cond -> _lock, the loop's nesting order) that every
        # frame transition mutates them under, so the conservation
        # invariant documented on StreamStats holds at EVERY snapshot
        with self.queues.cond:
            qc = self.queues.counters()
            depth = self.queues.depths()
            staged = self.scheduler.staged_depths()
            # admission accounting (wait samples, deadline misses,
            # aged promotions) is written while step() holds the cond —
            # read it there too
            misses = dict(self.scheduler.deadline_misses)
            promoted = dict(self.scheduler.promoted)
            waits = self.scheduler.wait_percentiles()
            with self._lock:
                served = {c: m.value for c, m in self._served.items()}
                in_flight = {c: m.value
                             for c, m in self._inflight.items()}
                rate_limited = {c: m.value
                                for c, m in self._rate_limited.items()}
                ticks = self._ticks.value
                pipelined = self._pipelined_ticks.value
        t = self._thread
        return StreamStats(
            running=t is not None and t.is_alive(),
            ticks=ticks,
            pipelined_ticks=pipelined,
            frames_submitted=qc["submitted"],
            frames_served=served,
            queue_depth={c: depth[c] + staged[c] for c in depth},
            in_flight=in_flight,
            rejected_full=qc["rejected"],
            rejected_rate_limited=rate_limited,
            preempted=qc["preempted"],
            requeued=qc["requeued"],
            shed_expired=qc["shed_expired"],
            promoted=promoted,
            deadline_misses=misses,
            queue_wait_ms=waits,
            gateway=self.gateway.stats())

    def metrics(self) -> str:
        """The whole stack's registry in Prometheus text exposition
        format (gateway + queues + scheduler + server share one
        registry).  Calls ``gateway.stats()`` first so lazily-synced
        gauges (per-shard frame counts) are fresh."""
        self.gateway.stats()
        return to_prometheus(self.registry)

    def dump_trace(self, reason: str = "on_demand") -> dict:
        """Flight-recorder dump: recent sampled spans plus every
        anomalous event (shed, deadline miss, preemption, rate-limit /
        queue-full refusal) with exact cumulative counts — see
        ``repro.obs.FlightRecorder.dump``."""
        return self.recorder.dump(reason=reason)

    def resource_signals(self) -> ResourceSignals:
        """Cheap load signals for adaptive policies — the same numbers
        ``stats()`` reports, but as a small fixed-shape record whose
        ``as_observation()`` vector a ``SplitPolicy`` can consume as
        features (docs/OBSERVABILITY.md).  Safe to poll from a hot
        loop: no percentile lists are built, only registry reads."""
        with self.queues.cond:
            depth = (self.queues.pending_locked()
                     + len(self.scheduler.staged))
            capacity = sum(cq.maxlen
                           for cq in self.queues.by_class.values())
            submitted = rejected = shed = 0
            for cq in self.queues.by_class.values():
                submitted += cq.submitted
                rejected += cq.rejected
                shed += cq.shed_expired
            p95 = 0.0
            for h in self.scheduler.wait_hist.values():
                if h.count:
                    p95 = max(p95, h.summary()["p95"])
            with self._lock:
                in_flight = sum(c.value
                                for c in self._inflight.values())
                served = sum(c.value for c in self._served.values())
                limited = sum(c.value
                              for c in self._rate_limited.values())
        stage = self.registry.value("gateway_stage_ewma_ms",
                                    stage="tick")
        refused = rejected + limited
        offered = submitted + refused
        uptime = self._clock() - self.gateway._t_start
        return ResourceSignals(
            queue_depth=depth,
            queue_fill=depth / capacity if capacity else 0.0,
            in_flight=in_flight,
            wait_p95_ms=p95,
            stage_ewma_ms=stage,
            shed_rate=shed / submitted if submitted else 0.0,
            reject_rate=refused / offered if offered else 0.0,
            throughput_fps=served / uptime if uptime > 0 else 0.0)
