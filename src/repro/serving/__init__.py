"""Streaming serving runtime: continuous audio in, ticked batches out.

    from repro.serving import StreamServer, SchedulerCfg

    server = StreamServer(gateway, cfg=SchedulerCfg(max_batch=64))
    with server:                         # starts the serving thread
        info = server.open_session(qos=QoSClass.INTERACTIVE)
        server.submit(info.sid, FrameRequest(t=0, mel=mel))
        ...
        server.close_session(info.sid)   # drains, then evicts

The subsystem (docs/STREAMING.md): bounded per-QoS-class ingest queues
(``queues``), a deadline-aware preempting tick scheduler
(``scheduler``), and the always-on ``StreamServer`` (``server``) that
pipelines tick t+1's staging under tick t's in-flight device chains via
the gateway's ``tick_launch``/``tick_collect`` seam.
"""
from repro.api.types import StreamStats
from repro.runtime.fault import (FailureInjector, StragglerEvent,
                                 StragglerMonitor)
from repro.serving.queues import (ClassQueue, QoSQueues, QueuedFrame,
                                  QueueFullError, RateLimitError,
                                  TokenBucket)
from repro.serving.scheduler import (DEADLINE_MS, MAX_WAIT_MS, PRIORITY,
                                     SchedulerCfg, TickScheduler)
from repro.serving.server import StreamServer

__all__ = [
    "StreamServer",
    "TickScheduler", "SchedulerCfg", "DEADLINE_MS", "MAX_WAIT_MS",
    "PRIORITY",
    "QoSQueues", "ClassQueue", "QueuedFrame", "QueueFullError",
    "RateLimitError", "TokenBucket",
    "StreamStats",
    "FailureInjector", "StragglerEvent", "StragglerMonitor",
]
