"""Bounded per-QoS-class ingest queues of the streaming runtime.

Clients (any thread) ``submit`` frames into one of three bounded FIFO
queues — ``INTERACTIVE`` / ``STANDARD`` / ``BULK`` — and the serving
thread drains them tick by tick through the ``TickScheduler``
(``serving/scheduler.py``).  Design rules:

- **Bounded, never silently lossy.**  A full class queue refuses the
  frame with the typed ``QueueFullError`` (backpressure to the caller)
  and counts the refusal; an accepted frame can only leave the system
  as a served ``FrameResult`` or as a *visible* shed
  (``shed_expired_locked`` — deadline long expired, dropped and
  counted, see the scheduler's shed pass).  Preempted frames re-enter
  at the FRONT of their queue with their original deadline.
- **Deterministic.**  No internal clock: every timestamp
  (``QueuedFrame.enq_s`` / ``deadline_s``) comes from the caller, so a
  fake clock reproduces every queue-wait, deadline and shed decision
  exactly (``tests/test_serving.py``).
- **One lock for all three queues.**  ``QoSQueues.cond`` is a single
  condition variable shared by every class, so the serving thread can
  sleep on "any frame arrived" and ``submit`` wakes it with one notify.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass

from repro.api.types import FrameRequest, QoSClass
from repro.obs import MetricsRegistry


class QueueFullError(RuntimeError):
    """Typed backpressure signal of ``QoSQueues.submit``: the class
    queue is at capacity.  The frame was NOT enqueued — the caller owns
    the retry/shed decision, and the refusal is counted
    (``StreamStats.rejected_full``), never silent."""

    def __init__(self, qos: QoSClass, depth: int, maxlen: int):
        self.qos = qos
        self.depth = depth
        self.maxlen = maxlen
        super().__init__(
            f"{qos.value} queue full: {depth}/{maxlen} frames waiting")


class RateLimitError(RuntimeError):
    """Typed admission-control signal of ``StreamServer.submit``: the
    session's token bucket is empty.  The frame was NOT enqueued — the
    refusal is counted (``StreamStats.rejected_rate_limited``), never
    silent, and ``retry_after_s`` tells the caller when one token will
    have refilled (exact under the injected clock)."""

    def __init__(self, sid: int, qos: QoSClass, retry_after_s: float):
        self.sid = sid
        self.qos = qos
        self.retry_after_s = retry_after_s
        super().__init__(
            f"session {sid} ({qos.value}) rate-limited: next token in "
            f"{retry_after_s:.3f}s")


@dataclass
class TokenBucket:
    """Deterministic token bucket: ``rate_per_s`` tokens/s up to
    ``burst``.  No internal clock — every ``try_take(now)`` refills from
    the caller's timestamp, so admission-control decisions are exact
    under a fake clock.  Not thread-safe on its own; the owner
    (``StreamServer``) serializes access."""

    rate_per_s: float
    burst: float
    now: float = 0.0           # clock at construction (refill anchor)

    def __post_init__(self):
        if self.rate_per_s <= 0 or self.burst < 1:
            raise ValueError("token bucket needs rate_per_s > 0 and "
                             "burst >= 1")
        self.tokens = float(self.burst)
        self._last = float(self.now)

    def _refill(self, now: float) -> None:
        if now > self._last:
            self.tokens = min(float(self.burst),
                              self.tokens + (now - self._last)
                              * self.rate_per_s)
        self._last = max(self._last, now)

    def try_take(self, now: float) -> bool:
        """Consume one token if available; never blocks."""
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def give_back(self) -> None:
        """Refund the token of a frame the queue then refused — a
        rejected frame must not also burn rate budget."""
        self.tokens = min(float(self.burst), self.tokens + 1.0)

    def retry_after_s(self, now: float) -> float:
        """Seconds until one full token exists (0 if one does now)."""
        self._refill(now)
        if self.tokens >= 1.0:
            return 0.0
        return (1.0 - self.tokens) / self.rate_per_s


@dataclass
class QueuedFrame:
    """One frame waiting for (or staged toward) admission into a tick."""

    sid: int
    frame: FrameRequest
    qos: QoSClass
    seq: int                   # global arrival number (FIFO tiebreak)
    enq_s: float               # caller clock at submit
    deadline_s: float          # enq_s + the class deadline budget
    preemptions: int = 0       # times bumped out of a staged tick
    weight: float = 1.0        # fair-share weight of the session (DRR)
    promoted: bool = False     # staged via the aging lane (max_wait_ms)
    trace: object = None       # FrameTrace when this frame is sampled
    #                            (repro.obs.trace; None on the hot path)


class ClassQueue:
    """One bounded FIFO plus its conservation counters.  Never locked on
    its own — the owning ``QoSQueues`` serializes every access.

    The counters live in the shared ``MetricsRegistry``
    (``stream_frames_submitted{class=...}`` etc.) so exporters and the
    ``StreamStats`` view read the very objects this queue mutates; the
    attribute names (``cq.submitted += 1``) are properties over those
    registry counters, preserved because migration bookkeeping and the
    scheduler write through them under ``QoSQueues.cond``."""

    __slots__ = ("qos", "maxlen", "q", "_submitted", "_rejected",
                 "_preempted", "_requeued", "_shed_expired")

    def __init__(self, qos: QoSClass, maxlen: int,
                 registry: MetricsRegistry):
        self.qos = qos
        self.maxlen = maxlen
        self.q: deque = deque()
        c = qos.value
        # frames accepted (rejections excluded); decremented when a
        # migration relocates the ledger to another member
        self._submitted = registry.counter(
            "stream_frames_submitted", qos=c)
        # QueueFullError refusals
        self._rejected = registry.counter("stream_rejected_full", qos=c)
        # frames bumped from a staged tick ... and put back (==)
        self._preempted = registry.counter("stream_preempted", qos=c)
        self._requeued = registry.counter("stream_requeued", qos=c)
        # frames dropped with deadline long past
        self._shed_expired = registry.counter("stream_shed_expired",
                                              qos=c)

    @property
    def submitted(self) -> int:
        return self._submitted.value

    @submitted.setter
    def submitted(self, v: int) -> None:
        self._submitted.value = v

    @property
    def rejected(self) -> int:
        return self._rejected.value

    @rejected.setter
    def rejected(self, v: int) -> None:
        self._rejected.value = v

    @property
    def preempted(self) -> int:
        return self._preempted.value

    @preempted.setter
    def preempted(self, v: int) -> None:
        self._preempted.value = v

    @property
    def requeued(self) -> int:
        return self._requeued.value

    @requeued.setter
    def requeued(self, v: int) -> None:
        self._requeued.value = v

    @property
    def shed_expired(self) -> int:
        return self._shed_expired.value

    @shed_expired.setter
    def shed_expired(self, v: int) -> None:
        self._shed_expired.value = v


class QoSQueues:
    """The three bounded class queues behind one condition variable.

    ``maxlen`` bounds each class queue (override per class with
    ``maxlens={QoSClass.BULK: 512, ...}``).  All mutation goes through
    methods that take ``self.cond``; ``cond`` is also the sleep/wake
    channel between client threads and the serving thread.

    Removal order invariant: frames enqueue in nondecreasing ``enq_s``
    (and, per class, nondecreasing ``deadline_s`` — one budget per
    class), preempted frames re-enter at the FRONT with their original
    deadline, and mid-queue removals (``pop_sid_locked``) preserve
    relative order — so the front of each class queue is always its
    oldest frame AND its earliest deadline.  The scheduler's shed and
    aging passes both lean on this.
    """

    def __init__(self, *, maxlen: int = 256, maxlens=None,
                 registry: MetricsRegistry | None = None):
        self.cond = threading.Condition()
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        over = maxlens or {}
        self.by_class = {q: ClassQueue(q, int(over.get(q, maxlen)),
                                       self.registry)
                         for q in QoSClass}
        self._seq = 0

    # -- producer side (any thread) ------------------------------------------
    def submit(self, sid, frame: FrameRequest, qos: QoSClass, *, now: float,
               deadline_s: float, weight: float = 1.0,
               trace=None) -> QueuedFrame:
        """Enqueue one frame; raises ``QueueFullError`` at capacity."""
        with self.cond:
            cq = self.by_class[qos]
            if len(cq.q) >= cq.maxlen:
                cq.rejected += 1
                raise QueueFullError(qos, len(cq.q), cq.maxlen)
            qf = QueuedFrame(sid=sid, frame=frame, qos=qos, seq=self._seq,
                             enq_s=now, deadline_s=deadline_s,
                             weight=weight, trace=trace)
            self._seq += 1
            if trace is not None:
                trace.add("enqueue", now, qos=qos.value,
                          depth=len(cq.q))
            cq.q.append(qf)
            cq.submitted += 1
            self.cond.notify_all()
            return qf

    # -- consumer side (serving thread; caller holds ``cond``) ---------------
    def pop_locked(self, qos: QoSClass) -> QueuedFrame | None:
        """Oldest waiting frame of the class (FIFO == EDF: every frame
        of a class carries the same deadline budget), or None."""
        cq = self.by_class[qos].q
        return cq.popleft() if cq else None

    def peek_locked(self, qos: QoSClass) -> QueuedFrame | None:
        """The class's oldest waiting frame without removing it."""
        cq = self.by_class[qos].q
        return cq[0] if cq else None

    def sids_locked(self, qos: QoSClass) -> list:
        """Sessions with waiting frames of the class, ordered by their
        oldest frame (the DRR ring order of the scheduler's STANDARD
        fill)."""
        seen, out = set(), []
        for qf in self.by_class[qos].q:
            if qf.sid not in seen:
                seen.add(qf.sid)
                out.append(qf.sid)
        return out

    def peek_sid_locked(self, qos: QoSClass, sid) -> QueuedFrame | None:
        """The session's oldest waiting frame of the class, in place."""
        for qf in self.by_class[qos].q:
            if qf.sid == sid:
                return qf
        return None

    def pop_sid_locked(self, qos: QoSClass, sid) -> QueuedFrame | None:
        """Remove and return the session's oldest waiting frame of the
        class (relative order of the remaining frames is preserved)."""
        cq = self.by_class[qos].q
        for i, qf in enumerate(cq):
            if qf.sid == sid:
                del cq[i]
                return qf
        return None

    # -- live migration (repro.cluster; docs/FEDERATION.md) ------------------
    def extract_session_locked(self, qos: QoSClass, sid) -> list:
        """Remove and return EVERY waiting frame of the session (oldest
        first, relative order preserved) — the migration move.  The
        frames' ledger leaves with them: ``submitted`` is decremented,
        because migration relocates accounting, it neither serves nor
        sheds (the target's ``implant_frames_locked`` re-counts them, so
        per-member conservation holds on both sides)."""
        cq = self.by_class[qos]
        out = [qf for qf in cq.q if qf.sid == sid]
        if out:
            cq.q = deque(qf for qf in cq.q if qf.sid != sid)
            cq.submitted -= len(out)
        return out

    def uncount_locked(self, qos: QoSClass, n: int) -> None:
        """Move ``n`` frames' submit ledger out of this queue set — for
        frames extracted from the scheduler's STAGED list during a
        migration (they were counted here at submit but no longer sit in
        the deque)."""
        self.by_class[qos].submitted -= n

    def implant_frames_locked(self, sid, snaps, qos: QoSClass) -> list:
        """Re-enqueue migrated frames with their ORIGINAL arrival times
        and deadlines (``QueuedFrameSnapshot``s, oldest first).  Each
        frame is inserted at its ``enq_s``-sorted position so the
        front==oldest==earliest-deadline invariant survives a merge with
        frames the target already holds, and gets a ``seq`` strictly
        between its new neighbours' (fractional when squeezed between
        two live frames) so every seq comparison — aging-lane oldest
        pick, batch sort, preemption LIFO — agrees with queue order.
        Exempt from the ``maxlen`` bound, like ``requeue_front_locked``:
        the frames already held queue slots at the source.  Counted into
        ``submitted`` (the ledger arrives with the frames)."""
        cq = self.by_class[qos]
        out = []
        for snap in snaps:
            q = cq.q
            i = len(q)
            while i > 0 and q[i - 1].enq_s > snap.enq_s:
                i -= 1
            if i == len(q):
                seq = self._seq
                self._seq += 1
            else:
                prev_seq = q[i - 1].seq if i else q[i].seq - 2.0
                seq = (prev_seq + q[i].seq) / 2.0
            qf = QueuedFrame(sid=sid, frame=snap.frame, qos=qos, seq=seq,
                             enq_s=snap.enq_s, deadline_s=snap.deadline_s,
                             preemptions=snap.preemptions,
                             weight=snap.weight, promoted=snap.promoted,
                             trace=getattr(snap, "trace", None))
            q.insert(i, qf)
            cq.submitted += 1
            out.append(qf)
        if out:
            self.cond.notify_all()
        return out

    def shed_expired_locked(self, qos: QoSClass, now: float,
                            horizon_s: float) -> list:
        """Drop (and count) every waiting frame of the class whose
        deadline expired more than ``horizon_s`` ago.  The front of the
        queue is always the earliest deadline (class invariant), so the
        sweep stops at the first survivor.  Returns the shed frames —
        the caller owns miss accounting and per-session bookkeeping;
        the drop itself is counted here (``shed_expired``) so the
        conservation snapshot (depth + shed counter, both under
        ``cond``) is atomic."""
        cq = self.by_class[qos]
        out = []
        while cq.q and now > cq.q[0].deadline_s + horizon_s:
            out.append(cq.q.popleft())
            cq.shed_expired += 1
        return out

    def requeue_front_locked(self, qf: QueuedFrame) -> None:
        """Return a preempted frame to the FRONT of its class queue with
        its original enqueue time and deadline — conservation: the
        preemption is counted, the frame is never dropped.  Re-entry is
        exempt from the maxlen bound (the frame already held a slot)."""
        cq = self.by_class[qf.qos]
        qf.preemptions += 1
        cq.q.appendleft(qf)
        cq.preempted += 1
        cq.requeued += 1

    def depth_locked(self, qos: QoSClass) -> int:
        return len(self.by_class[qos].q)

    def pending_locked(self) -> int:
        return sum(len(c.q) for c in self.by_class.values())

    # -- observability -------------------------------------------------------
    def depths(self) -> dict:
        with self.cond:
            return {q.value: len(c.q) for q, c in self.by_class.items()}

    def counters(self) -> dict:
        """{"submitted"/"rejected"/"preempted"/"requeued"/"shed_expired":
        {class: count}} — one consistent snapshot."""
        with self.cond:
            return {name: {q.value: getattr(c, name)
                           for q, c in self.by_class.items()}
                    for name in ("submitted", "rejected", "preempted",
                                 "requeued", "shed_expired")}
