"""Bounded per-QoS-class ingest queues of the streaming runtime.

Clients (any thread) ``submit`` frames into one of three bounded FIFO
queues — ``INTERACTIVE`` / ``STANDARD`` / ``BULK`` — and the serving
thread drains them tick by tick through the ``TickScheduler``
(``serving/scheduler.py``).  Design rules:

- **Bounded, never silently lossy.**  A full class queue refuses the
  frame with the typed ``QueueFullError`` (backpressure to the caller)
  and counts the refusal; an accepted frame can only leave the system
  as a served ``FrameResult``.  Preempted frames re-enter at the FRONT
  of their queue with their original deadline.
- **Deterministic.**  No internal clock: every timestamp
  (``QueuedFrame.enq_s`` / ``deadline_s``) comes from the caller, so a
  fake clock reproduces every queue-wait and deadline decision exactly
  (``tests/test_serving.py``).
- **One lock for all three queues.**  ``QoSQueues.cond`` is a single
  condition variable shared by every class, so the serving thread can
  sleep on "any frame arrived" and ``submit`` wakes it with one notify.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field

from repro.api.types import FrameRequest, QoSClass


class QueueFullError(RuntimeError):
    """Typed backpressure signal of ``QoSQueues.submit``: the class
    queue is at capacity.  The frame was NOT enqueued — the caller owns
    the retry/shed decision, and the refusal is counted
    (``StreamStats.rejected_full``), never silent."""

    def __init__(self, qos: QoSClass, depth: int, maxlen: int):
        self.qos = qos
        self.depth = depth
        self.maxlen = maxlen
        super().__init__(
            f"{qos.value} queue full: {depth}/{maxlen} frames waiting")


@dataclass
class QueuedFrame:
    """One frame waiting for (or staged toward) admission into a tick."""

    sid: int
    frame: FrameRequest
    qos: QoSClass
    seq: int                   # global arrival number (FIFO tiebreak)
    enq_s: float               # caller clock at submit
    deadline_s: float          # enq_s + the class deadline budget
    preemptions: int = 0       # times bumped out of a staged tick


@dataclass
class ClassQueue:
    """One bounded FIFO plus its conservation counters.  Never locked on
    its own — the owning ``QoSQueues`` serializes every access."""

    qos: QoSClass
    maxlen: int
    q: deque = field(default_factory=deque)
    submitted: int = 0         # frames accepted (rejections excluded)
    rejected: int = 0          # QueueFullError refusals
    preempted: int = 0         # frames bumped from a staged tick ...
    requeued: int = 0          # ... and put back (always == preempted)


class QoSQueues:
    """The three bounded class queues behind one condition variable.

    ``maxlen`` bounds each class queue (override per class with
    ``maxlens={QoSClass.BULK: 512, ...}``).  All mutation goes through
    methods that take ``self.cond``; ``cond`` is also the sleep/wake
    channel between client threads and the serving thread.
    """

    def __init__(self, *, maxlen: int = 256, maxlens=None):
        self.cond = threading.Condition()
        over = maxlens or {}
        self.by_class = {q: ClassQueue(q, int(over.get(q, maxlen)))
                         for q in QoSClass}
        self._seq = 0

    # -- producer side (any thread) ------------------------------------------
    def submit(self, sid, frame: FrameRequest, qos: QoSClass, *, now: float,
               deadline_s: float) -> QueuedFrame:
        """Enqueue one frame; raises ``QueueFullError`` at capacity."""
        with self.cond:
            cq = self.by_class[qos]
            if len(cq.q) >= cq.maxlen:
                cq.rejected += 1
                raise QueueFullError(qos, len(cq.q), cq.maxlen)
            qf = QueuedFrame(sid=sid, frame=frame, qos=qos, seq=self._seq,
                             enq_s=now, deadline_s=deadline_s)
            self._seq += 1
            cq.q.append(qf)
            cq.submitted += 1
            self.cond.notify_all()
            return qf

    # -- consumer side (serving thread; caller holds ``cond``) ---------------
    def pop_locked(self, qos: QoSClass) -> QueuedFrame | None:
        """Oldest waiting frame of the class (FIFO == EDF: every frame
        of a class carries the same deadline budget), or None."""
        cq = self.by_class[qos].q
        return cq.popleft() if cq else None

    def requeue_front_locked(self, qf: QueuedFrame) -> None:
        """Return a preempted frame to the FRONT of its class queue with
        its original enqueue time and deadline — conservation: the
        preemption is counted, the frame is never dropped.  Re-entry is
        exempt from the maxlen bound (the frame already held a slot)."""
        cq = self.by_class[qf.qos]
        qf.preemptions += 1
        cq.q.appendleft(qf)
        cq.preempted += 1
        cq.requeued += 1

    def depth_locked(self, qos: QoSClass) -> int:
        return len(self.by_class[qos].q)

    def pending_locked(self) -> int:
        return sum(len(c.q) for c in self.by_class.values())

    # -- observability -------------------------------------------------------
    def depths(self) -> dict:
        with self.cond:
            return {q.value: len(c.q) for q, c in self.by_class.items()}

    def counters(self) -> dict:
        """{"submitted"/"rejected"/"preempted"/"requeued":
        {class: count}} — one consistent snapshot."""
        with self.cond:
            return {name: {q.value: getattr(c, name)
                           for q, c in self.by_class.items()}
                    for name in ("submitted", "rejected", "preempted",
                                 "requeued")}
