"""Deadline-aware QoS tick scheduler: who rides the next tick.

The scheduler turns the three class queues into one tick batch in two
deterministic phases that mirror the runtime's cross-tick pipeline
(``serving/server.py``):

1. ``stage(queues, now)`` — while the PREVIOUS tick's device chains are
   still in flight, reserve up to ``max_batch`` frames: first the
   **aging lane** (frames waiting past their class ``max_wait_ms``,
   oldest arrival first, capped at ``promote_quota`` of the batch),
   then strict class priority (``INTERACTIVE`` → ``STANDARD`` →
   ``BULK``).  Within ``STANDARD`` the fill is **per-session deficit
   round-robin** (weighted by ``QueuedFrame.weight``), so one chatty
   tenant cannot monopolize the class's slots; ``INTERACTIVE`` and
   ``BULK`` stay FIFO == EDF (one deadline budget per class).
2. ``admit(queues, now)`` — immediately before launch, finalize the
   batch: first the **shed pass** (frames whose deadline expired more
   than ``shed_horizon_ms`` ago are dropped *visibly* — counted as
   sheds AND as the deadline misses they already were), then backfill
   free slots, then the **preemption pass** — while an
   ``INTERACTIVE``/``STANDARD`` frame is still waiting and the staged
   batch holds a non-promoted ``BULK`` frame, the newest-staged such
   frame is bumped back to the FRONT of its queue (original deadline
   intact, bump counted) and the waiting frame takes its slot.
   Promoted frames are preemption-immune — aging would be a no-op if
   its beneficiaries could immediately be bumped again.  Preempted
   frames re-queue; they are never dropped.

**The starvation bound.**  Under ANY sustained higher-class load, a
BULK frame's queue wait is bounded: once it has waited ``max_wait_ms``
it joins the aging lane, which drains oldest-first at
``>= max(1, promote_quota * max_batch)`` frames per tick, and the lane's
backlog is capped by the bounded queues — so

    wait  <=  max_wait_ms  +  ceil(queue_maxlen / promote_slots) ticks

(``promote_slots = max(1, int(promote_quota * max_batch))``).  The
quota is what keeps aging from inverting the starvation: promoted
frames can take at most that share of a batch, so fresh INTERACTIVE
traffic keeps the rest.  With shedding enabled the bound tightens
further — no admitted frame can be older than
``deadline_ms + shed_horizon_ms`` plus one stage→admit window, because
the shed pass runs before every fill.  Both bounds are pinned by
fake-clock tests and the sustained-overload benchmark lane.

Everything here is pure host-side Python and clock-injected: decisions
are a function of (queue contents, ``now``) only, so every policy
property — priority order, per-session EDF, aging bound, preempted-frame
conservation, shed reproducibility — is pinned by deterministic
fake-clock tests (``tests/test_serving.py``).

Wait/deadline accounting happens once per frame, at admission (or at
shed, for frames that starved in queue past the horizon): the queue
wait is ``now - enq_s`` and a deadline miss is ``now > deadline_s`` —
both against the caller's injected clock, and all counter mutation
happens inside ``queues.cond`` so a ``stats()`` snapshot is atomic.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.api.types import QoSClass
from repro.obs import MetricsRegistry
from repro.serving.queues import QoSQueues, QueuedFrame

# Default per-class deadline budgets (ms between submit and tick
# admission).  The INTERACTIVE budget is the paper's ~2 mel-frame
# interactivity envelope.  BULK is best-effort but NOT starvable: a
# frame waiting past its class MAX_WAIT_MS joins the aging lane and its
# wait is provably bounded (see the module docstring).
DEADLINE_MS = {
    QoSClass.INTERACTIVE: 50.0,
    QoSClass.STANDARD: 250.0,
    QoSClass.BULK: 2000.0,
}

# Default per-class aging thresholds (ms of queue wait after which a
# frame is promoted into the aging lane).  ``None`` disables aging for
# the class — INTERACTIVE is already the top priority, so promoting it
# could only reorder it against other promoted frames.
MAX_WAIT_MS = {
    QoSClass.INTERACTIVE: None,
    QoSClass.STANDARD: 2000.0,
    QoSClass.BULK: 4000.0,
}

# Admission order == preemption precedence (first is most privileged).
PRIORITY = (QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK)

# DRR weights are clamped into this range: a zero/negative weight would
# stall the deficit loop, an enormous one would let a single quantum
# round drain the whole batch.
_WEIGHT_MIN, _WEIGHT_MAX = 0.05, 20.0


def clamp_weight(w: float) -> float:
    return float(min(max(w, _WEIGHT_MIN), _WEIGHT_MAX))


@dataclass(frozen=True)
class SchedulerCfg:
    """Tick-composition policy knobs (all deterministic).

    ``deadline_ms`` and ``max_wait_ms`` accept PARTIAL overrides: user
    dicts are merged over the module defaults in ``__post_init__``, so
    ``SchedulerCfg(deadline_ms={QoSClass.BULK: 5000.0})`` keeps the
    other classes' budgets instead of KeyError'ing on their first
    submit.
    """

    max_batch: int = 64                  # frames per tick (dispatch width)
    deadline_ms: dict = field(default_factory=dict)
    preempt_bulk: bool = True            # bump staged BULK for INT/STD
    # aging lane: per-class queue-wait threshold (ms; None = no aging)
    max_wait_ms: dict = field(default_factory=dict)
    # max share of a batch the aging lane may take (always >= 1 slot
    # when any aged frame waits — the starvation bound needs progress)
    promote_quota: float = 0.5
    # shed horizon: a waiting frame whose deadline expired more than
    # this many ms ago is dropped visibly (None = never shed — the
    # bounded queues' backpressure is then the only overload valve)
    shed_horizon_ms: float | None = None
    # DRR quantum (frames per round per unit weight) of the STANDARD
    # per-session fair fill
    drr_quantum: float = 1.0

    def __post_init__(self):
        # frozen dataclass: merge partial user overrides over the
        # defaults via object.__setattr__ (the dicts stay per-instance)
        object.__setattr__(
            self, "deadline_ms", {**DEADLINE_MS, **self.deadline_ms})
        object.__setattr__(
            self, "max_wait_ms", {**MAX_WAIT_MS, **self.max_wait_ms})
        if not 0.0 < self.promote_quota <= 1.0:
            raise ValueError("promote_quota must be in (0, 1]")
        if self.drr_quantum <= 0.0:
            raise ValueError("drr_quantum must be > 0")

    def deadline_s(self, qos: QoSClass) -> float:
        return self.deadline_ms[qos] * 1e-3

    def max_wait_s(self, qos: QoSClass) -> float | None:
        ms = self.max_wait_ms[qos]
        return None if ms is None else ms * 1e-3

    @property
    def promote_slots(self) -> int:
        """Aging-lane batch share: ``max(1, promote_quota*max_batch)``
        — at least one slot, or aged frames could never drain and the
        starvation bound would not exist."""
        return max(1, int(self.promote_quota * self.max_batch))

    @property
    def shed_horizon_s(self) -> float | None:
        return (None if self.shed_horizon_ms is None
                else self.shed_horizon_ms * 1e-3)


class TickScheduler:
    """Composes each tick's batch by class priority with an aging lane,
    per-session STANDARD fair sharing, deadline accounting, load
    shedding and BULK preemption.  Owns the staged (reserved) frames
    and the admission-side counters; the queues own the
    submit/reject/requeue/shed-count side.  Call pattern (serving
    thread only, with ``queues.cond`` NOT held — the scheduler takes
    it):

        sched.stage(queues, now)    # under the in-flight tick
        ...previous tick syncs; more frames arrive...
        batch = sched.admit(queues, now)   # shed + backfill + preemption
        dropped = sched.pop_shed()         # frames the shed pass removed
    """

    def __init__(self, cfg: SchedulerCfg | None = None, *,
                 registry: MetricsRegistry | None = None,
                 recorder=None):
        # cfg defaults to None, not a shared module-level SchedulerCfg:
        # the frozen dataclass holds mutable dicts, and a shared default
        # instance would leak mutations across servers
        self.cfg = cfg if cfg is not None else SchedulerCfg()
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.recorder = recorder    # FlightRecorder or None: anomaly
        #                             events (miss/shed/preempt) land
        #                             there with full decision context
        self.staged: list[QueuedFrame] = []
        # admission-side counters in the shared registry; the dict-of-
        # ints surface the tests and StreamStats read is the properties
        # below (plain snapshots over these live counters)
        self._admitted = {q.value: self.registry.counter(
            "stream_admitted", qos=q.value) for q in QoSClass}
        self._deadline_misses = {q.value: self.registry.counter(
            "stream_deadline_misses", qos=q.value) for q in QoSClass}
        self._promoted = {q.value: self.registry.counter(
            "stream_promoted", qos=q.value) for q in QoSClass}
        # bounded streaming quantile sketches -> p50/p95 queue wait per
        # class (repro.obs: exact == numpy.percentile below exact_cap,
        # log-binned and O(1)-memory beyond — the old deque rings grew
        # no further but FORGOT, this forgets nothing and stays bounded)
        self.wait_hist = {q.value: self.registry.histogram(
            "stream_queue_wait_ms", qos=q.value) for q in QoSClass}
        # STANDARD fair-share state: per-session deficit counters plus
        # the tenant the ring last served (service resumes after it)
        self._drr_deficit: dict = {}
        self._drr_last = None
        self._drr_mid_turn = False   # last fill hit the batch limit
        #                              mid-turn: that tenant resumes
        #                              first, without a fresh quantum
        # frames the most recent admit's shed pass dropped, until the
        # server collects them (replaced — never grows — each admit)
        self._last_shed: list[QueuedFrame] = []

    # admission counters as the plain {class: int} dicts they always
    # were — snapshots over the registry counters, so exporters and the
    # legacy readers see the same numbers
    @property
    def admitted(self) -> dict:
        return {c: m.value for c, m in self._admitted.items()}

    @property
    def deadline_misses(self) -> dict:
        return {c: m.value for c, m in self._deadline_misses.items()}

    @property
    def promoted(self) -> dict:
        return {c: m.value for c, m in self._promoted.items()}

    # -- phase 1: reserve under the in-flight tick ---------------------------
    def stage(self, queues: QoSQueues, now: float | None = None) -> int:
        """Reserve frames up to ``max_batch``; returns how many are
        staged in total.  ``now`` feeds the aging lane (``None`` skips
        it — promotion then happens at ``admit``, which always has the
        clock); wait/deadline accounting still only happens at
        ``admit``."""
        with queues.cond:
            return self._fill_locked(queues, now)

    def _fill_locked(self, queues, now) -> int:
        if now is not None:
            self._promote_locked(queues, now)
        n0 = len(self.staged)
        for qos in PRIORITY:
            if len(self.staged) >= self.cfg.max_batch:
                break
            if qos is QoSClass.STANDARD:
                self._fill_standard_drr_locked(queues)
            else:
                while len(self.staged) < self.cfg.max_batch:
                    qf = queues.pop_locked(qos)
                    if qf is None:
                        break
                    self.staged.append(qf)
        if now is not None:
            for qf in self.staged[n0:]:
                if qf.trace is not None:
                    qf.trace.add("stage", now)
        return len(self.staged)

    def _promote_locked(self, queues, now) -> None:
        """The aging lane: stage frames waiting past their class
        ``max_wait_ms``, oldest arrival first across classes, up to
        ``promote_slots`` promoted frames in the batch.  Each class
        queue's front is its oldest frame (queue invariant), so peeking
        the three fronts finds the globally oldest aged frame."""
        quota = self.cfg.promote_slots
        n_promoted = sum(1 for f in self.staged if f.promoted)
        while (len(self.staged) < self.cfg.max_batch
               and n_promoted < quota):
            oldest, oldest_qos = None, None
            for qos in PRIORITY:
                mw = self.cfg.max_wait_s(qos)
                if mw is None:
                    continue
                qf = queues.peek_locked(qos)
                if qf is None or (now - qf.enq_s) < mw:
                    continue
                if oldest is None or qf.seq < oldest.seq:
                    oldest, oldest_qos = qf, qos
            if oldest is None:
                return
            qf = queues.pop_locked(oldest_qos)
            qf.promoted = True
            self._promoted[qf.qos.value].inc()
            if qf.trace is not None:
                qf.trace.add("promote", now,
                             waited_ms=(now - qf.enq_s) * 1e3)
            self.staged.append(qf)
            n_promoted += 1

    def _fill_standard_drr_locked(self, queues) -> None:
        """Weighted deficit round-robin across STANDARD tenants: every
        tenant with waiting frames earns ``drr_quantum * weight``
        deficit per round and spends 1 per staged frame, so over any
        backlogged interval tenants are served proportionally to their
        weights — a chatty session cannot monopolize the class.  Within
        a tenant the order stays FIFO == EDF."""
        S = QoSClass.STANDARD
        cfg = self.cfg
        while len(self.staged) < cfg.max_batch:
            ring = queues.sids_locked(S)
            if not ring:
                return
            live = set(ring)
            # classic DRR: a tenant that drained its queue resets
            self._drr_deficit = {s: d for s, d in
                                 self._drr_deficit.items() if s in live}
            if self._drr_last in live:
                i = ring.index(self._drr_last)
                if self._drr_mid_turn:
                    # last fill ran out of batch slots MID-turn: that
                    # tenant resumes first and spends its remaining
                    # deficit before anyone earns a fresh quantum —
                    # otherwise rotation re-serves the whole ring ahead
                    # of it every pass and weights collapse to 1:1
                    ring = ring[i:] + ring[:i]
                else:
                    ring = ring[i + 1:] + ring[:i + 1]
            else:
                self._drr_mid_turn = False
            progressed = False
            for sid in ring:
                if len(self.staged) >= cfg.max_batch:
                    return
                head = queues.peek_sid_locked(S, sid)
                if head is None:        # drained earlier this round
                    continue
                if not (self._drr_mid_turn and sid == self._drr_last):
                    self._drr_deficit[sid] = (
                        self._drr_deficit.get(sid, 0.0)
                        + cfg.drr_quantum * clamp_weight(head.weight))
                self._drr_mid_turn = False
                while self._drr_deficit[sid] >= 1.0:
                    if len(self.staged) >= cfg.max_batch:
                        self._drr_last = sid
                        self._drr_mid_turn = True     # turn not finished
                        return
                    qf = queues.pop_sid_locked(S, sid)
                    if qf is None:
                        self._drr_deficit[sid] = 0.0
                        break
                    self._drr_deficit[sid] -= 1.0
                    self.staged.append(qf)
                    self._drr_last = sid
                    progressed = True
            if not progressed and len(self.staged) >= cfg.max_batch:
                return

    # -- phase 2: finalize at launch -----------------------------------------
    def admit(self, queues: QoSQueues, now: float) -> list[QueuedFrame]:
        """Shed pass + backfill + preemption pass + wait/deadline
        accounting; clears and returns the staged batch (admission
        order: class priority, FIFO within).  ALL counter mutation —
        sheds, admissions, wait samples, misses — happens inside
        ``queues.cond``, so a concurrent ``stats()`` snapshot (which
        reads under the same lock) is actually atomic."""
        with queues.cond:
            self._shed_locked(queues, now)
            self._fill_locked(queues, now)
            if self.cfg.preempt_bulk:
                self._preempt_locked(queues, now)
            batch = sorted(self.staged,
                           key=lambda f: (PRIORITY.index(f.qos), f.seq))
            self.staged = []
            for qf in batch:
                cls = qf.qos.value
                self._admitted[cls].inc()
                wait_ms = (now - qf.enq_s) * 1e3
                self.wait_hist[cls].observe(wait_ms)
                missed = now > qf.deadline_s
                if missed:
                    self._deadline_misses[cls].inc()
                    if self.recorder is not None:
                        self.recorder.record(
                            "deadline_miss", now, sid=qf.sid,
                            t=qf.frame.t, qos=cls,
                            late_ms=(now - qf.deadline_s) * 1e3)
                if qf.trace is not None:
                    qf.trace.add("admit", now, wait_ms=wait_ms,
                                 missed=missed)
            return batch

    def _shed_locked(self, queues, now) -> None:
        """Real load-shedding: drop every waiting frame whose deadline
        expired more than ``shed_horizon_ms`` ago.  Each shed frame is
        counted as the deadline miss it already was (starved-in-queue
        misses were previously invisible until — if ever — admission)
        and its terminal wait is sampled, so overload shows up in the
        same percentiles the healthy path reports."""
        horizon = self.cfg.shed_horizon_s
        if horizon is None:
            self._last_shed = []
            return
        shed: list[QueuedFrame] = []
        for qos in PRIORITY:
            shed.extend(queues.shed_expired_locked(qos, now, horizon))
        for qf in shed:
            cls = qf.qos.value
            self._deadline_misses[cls].inc()
            wait_ms = (now - qf.enq_s) * 1e3
            self.wait_hist[cls].observe(wait_ms)
            if self.recorder is not None:
                self.recorder.record(
                    "shed", now, sid=qf.sid, t=qf.frame.t, qos=cls,
                    waited_ms=wait_ms,
                    expired_ms=(now - qf.deadline_s) * 1e3)
            if qf.trace is not None:
                qf.trace.add("shed", now, waited_ms=wait_ms)
        self._last_shed = shed

    def pop_shed(self) -> list[QueuedFrame]:
        """Frames the most recent ``admit`` shed (consumed: a second
        call returns [] until the next admit).  The server folds these
        into per-session accounting so closes still drain."""
        out, self._last_shed = self._last_shed, []
        return out

    def _preempt_locked(self, queues, now=None) -> None:
        """While a higher-class frame waits and the staged batch holds
        preemptible BULK frames, bump the newest-staged one (LIFO —
        least committed) back to the front of its queue and stage the
        waiting frame in its place.  Promoted frames are immune: the
        aging lane's grant must stick, or sustained INTERACTIVE load
        would re-starve BULK one preemption at a time."""
        for qos in (QoSClass.INTERACTIVE, QoSClass.STANDARD):
            while queues.depth_locked(qos):
                bulk_at = max(
                    (i for i, f in enumerate(self.staged)
                     if f.qos is QoSClass.BULK and not f.promoted),
                    default=None,
                    key=lambda i: self.staged[i].seq)
                if bulk_at is None:
                    return
                bumped = self.staged.pop(bulk_at)
                if now is not None:
                    if bumped.trace is not None:
                        bumped.trace.add("preempt", now, by=qos.value)
                    if self.recorder is not None:
                        self.recorder.record(
                            "preempt", now, sid=bumped.sid,
                            t=bumped.frame.t, qos=bumped.qos.value,
                            by=qos.value,
                            preemptions=bumped.preemptions + 1)
                queues.requeue_front_locked(bumped)
                taken = queues.pop_locked(qos)
                if now is not None and taken.trace is not None:
                    taken.trace.add("stage", now, via="preemption")
                self.staged.append(taken)

    # -- live migration (repro.cluster) --------------------------------------
    def extract_session_locked(self, sid) -> list[QueuedFrame]:
        """Remove and return the session's staged (reserved-but-
        unlaunched) frames — the migration path.  Caller holds
        ``queues.cond`` and moves the frames' submit ledger with them
        (``queues.uncount_locked``); admission counters are untouched
        because these frames were never admitted."""
        out = [qf for qf in self.staged if qf.sid == sid]
        if out:
            self.staged = [qf for qf in self.staged if qf.sid != sid]
        return out

    # -- observability -------------------------------------------------------
    def staged_depths(self) -> dict:
        """Staged (reserved-but-unlaunched) frames per class — counted
        into ``StreamStats.queue_depth`` so conservation holds at every
        snapshot."""
        out = {q.value: 0 for q in QoSClass}
        for qf in self.staged:
            out[qf.qos.value] += 1
        return out

    def wait_percentiles(self) -> dict:
        """{class: {"p50","p95","mean","max"}} over the wait sketches
        (empty classes report zeros).  Exact ``numpy.percentile``
        values while a class has seen <= the sketch's ``exact_cap``
        samples; bounded-error log-bin estimates beyond — ``mean`` and
        ``max`` are exact always."""
        return {cls: h.summary() for cls, h in self.wait_hist.items()}
