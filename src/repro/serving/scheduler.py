"""Deadline-aware QoS tick scheduler: who rides the next tick.

The scheduler turns the three class queues into one tick batch in two
deterministic phases that mirror the runtime's cross-tick pipeline
(``serving/server.py``):

1. ``stage(queues)`` — while the PREVIOUS tick's device chains are
   still in flight, reserve up to ``max_batch`` frames by strict class
   priority (``INTERACTIVE`` → ``STANDARD`` → ``BULK``; FIFO == EDF
   within a class, since every frame of a class carries the same
   deadline budget).
2. ``admit(queues, now)`` — immediately before launch, finalize the
   batch: first backfill free slots from the queues (same priority
   order), then run the **preemption pass** — while an
   ``INTERACTIVE``/``STANDARD`` frame is still waiting and the staged
   batch holds a ``BULK`` frame, the newest-staged BULK frame is bumped
   back to the FRONT of its queue (original deadline intact, bump
   counted) and the waiting frame takes its slot.  Preempted frames
   re-queue; they are never dropped.

Frames that arrive between ``stage`` and ``admit`` — i.e. during the
previous tick's sync — are exactly the ones that can trigger a
preemption: that window is where "tick t+1 staging under tick t's
chains" meets "latency-sensitive tenants jump the line".

Everything here is pure host-side Python and clock-injected: decisions
are a function of (queue contents, ``now``) only, so every policy
property — priority order, deadline monotonicity, preempted-frame
conservation — is pinned by deterministic fake-clock tests
(``tests/test_serving.py``).

Wait/deadline accounting happens once per frame, at admission: the
queue wait is ``now - enq_s`` and a deadline miss is ``now >
deadline_s`` — both against the caller's injected clock.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.api.types import QoSClass
from repro.serving.queues import QoSQueues, QueuedFrame

# Default per-class deadline budgets (ms between submit and tick
# admission).  The INTERACTIVE budget is the paper's ~2 mel-frame
# interactivity envelope.  BULK is strictly best-effort: under
# sustained higher-class load >= max_batch it is starved outright (by
# design — visible as growing queue_depth/max wait, and its deadline
# misses are only counted when a frame is finally admitted; aging /
# promotion is an open ROADMAP item).
DEADLINE_MS = {
    QoSClass.INTERACTIVE: 50.0,
    QoSClass.STANDARD: 250.0,
    QoSClass.BULK: 2000.0,
}

# Admission order == preemption precedence (first is most privileged).
PRIORITY = (QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK)


@dataclass(frozen=True)
class SchedulerCfg:
    """Tick-composition policy knobs (all deterministic)."""

    max_batch: int = 64                  # frames per tick (dispatch width)
    deadline_ms: dict = field(
        default_factory=lambda: dict(DEADLINE_MS))
    preempt_bulk: bool = True            # bump staged BULK for INT/STD

    def deadline_s(self, qos: QoSClass) -> float:
        return self.deadline_ms[qos] * 1e-3


class TickScheduler:
    """Composes each tick's batch by class priority with deadline
    accounting and BULK preemption.  Owns the staged (reserved) frames
    and the admission-side counters; the queues own the
    submit/reject/requeue side.  Call pattern (serving thread only, with
    ``queues.cond`` NOT held — the scheduler takes it):

        sched.stage(queues)         # under the in-flight tick
        ...previous tick syncs; more frames arrive...
        batch = sched.admit(queues, now)   # backfill + preemption pass
    """

    def __init__(self, cfg: SchedulerCfg | None = None):
        # cfg defaults to None, not a shared module-level SchedulerCfg:
        # the frozen dataclass holds a mutable deadline_ms dict, and a
        # shared default instance would leak mutations across servers
        self.cfg = cfg if cfg is not None else SchedulerCfg()
        self.staged: list[QueuedFrame] = []
        self.admitted = {q.value: 0 for q in QoSClass}
        self.deadline_misses = {q.value: 0 for q in QoSClass}
        # bounded wait-sample rings -> p50/p95 queue wait per class
        self.waits_ms = {q.value: deque(maxlen=4096) for q in QoSClass}

    # -- phase 1: reserve under the in-flight tick ---------------------------
    def stage(self, queues: QoSQueues) -> int:
        """Reserve frames (strict priority, FIFO within class) up to
        ``max_batch``; returns how many are staged in total.  Takes no
        clock: every wait/deadline decision is accounted at ``admit``."""
        with queues.cond:
            return self._fill_locked(queues)

    def _fill_locked(self, queues) -> int:
        for qos in PRIORITY:
            while len(self.staged) < self.cfg.max_batch:
                qf = queues.pop_locked(qos)
                if qf is None:
                    break
                self.staged.append(qf)
        return len(self.staged)

    # -- phase 2: finalize at launch -----------------------------------------
    def admit(self, queues: QoSQueues, now: float) -> list[QueuedFrame]:
        """Backfill + preemption pass + wait/deadline accounting; clears
        and returns the staged batch (admission order: class priority)."""
        with queues.cond:
            self._fill_locked(queues)
            if self.cfg.preempt_bulk:
                self._preempt_locked(queues)
            batch = sorted(self.staged,
                           key=lambda f: (PRIORITY.index(f.qos), f.seq))
            self.staged = []
        for qf in batch:
            cls = qf.qos.value
            self.admitted[cls] += 1
            self.waits_ms[cls].append((now - qf.enq_s) * 1e3)
            if now > qf.deadline_s:
                self.deadline_misses[cls] += 1
        return batch

    def _preempt_locked(self, queues) -> None:
        """While a higher-class frame waits and the staged batch holds
        BULK frames, bump the newest-staged BULK frame (LIFO — least
        committed) back to the front of its queue and stage the waiting
        frame in its place."""
        for qos in (QoSClass.INTERACTIVE, QoSClass.STANDARD):
            while queues.depth_locked(qos):
                bulk_at = max(
                    (i for i, f in enumerate(self.staged)
                     if f.qos is QoSClass.BULK),
                    default=None,
                    key=lambda i: self.staged[i].seq)
                if bulk_at is None:
                    return
                queues.requeue_front_locked(self.staged.pop(bulk_at))
                self.staged.append(queues.pop_locked(qos))

    # -- observability -------------------------------------------------------
    def staged_depths(self) -> dict:
        """Staged (reserved-but-unlaunched) frames per class — counted
        into ``StreamStats.queue_depth`` so conservation holds at every
        snapshot."""
        out = {q.value: 0 for q in QoSClass}
        for qf in self.staged:
            out[qf.qos.value] += 1
        return out

    def wait_percentiles(self) -> dict:
        """{class: {"p50","p95","mean","max"}} over the retained wait
        samples (empty classes report zeros)."""
        out = {}
        for cls, ring in self.waits_ms.items():
            if ring:
                a = np.asarray(ring, np.float64)
                out[cls] = {"p50": float(np.percentile(a, 50)),
                            "p95": float(np.percentile(a, 95)),
                            "mean": float(a.mean()),
                            "max": float(a.max())}
            else:
                out[cls] = {"p50": 0.0, "p95": 0.0, "mean": 0.0, "max": 0.0}
        return out
