"""Public jit'd wrappers for the Pallas kernels: shape padding, block-size
selection, and kernel/ref dispatch.  ``interpret=True`` executes the
kernel bodies on CPU for validation; on TPU pass ``interpret=False`` (or
run the whole process with ``REPRO_PALLAS_INTERPRET=0`` — the
compiled-backend CI lane does exactly that, see ``.github/workflows``).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gmm_posterior import gmm_posterior_pallas
from repro.kernels.infonce_vneg import infonce_vneg_pallas
from repro.kernels.int8_quant import (int8_dequantize_pallas,
                                      int8_quantize_pallas,
                                      wire_roundtrip_pallas)
from repro.kernels.laplacian_energy import laplacian_energy_pallas
from repro.kernels.swd_kernel import swd_pallas


# Process-level backend switch for every wrapper below: callers that do
# not pass ``interpret=`` explicitly get this default, so one env var
# flips the whole suite between interpret mode (the CPU default) and the
# compiled Pallas backend (TPU/GPU runners).  Read once at import — a
# process-level switch, not a per-call one — and ``default_interpret``
# reports that same snapshot so probes can never disagree with what the
# wrappers actually resolve to.
_DEFAULT_INTERPRET = os.environ.get(
    "REPRO_PALLAS_INTERPRET", "1").lower() not in ("0", "false", "no")
_COMPILED_OK: bool | None = None


def default_interpret() -> bool:
    return _DEFAULT_INTERPRET


def _resolve(interpret):
    return _DEFAULT_INTERPRET if interpret is None else interpret


def compiled_backend_supported() -> bool:
    """Probe (once) whether this jax backend can *compile* Pallas kernels
    — CPU-only jaxlibs support interpret mode only, so the compiled CI
    lane self-skips there (``tests/test_kernels.py``).

    Only the CPU backend may swallow the probe failure: on an
    accelerator, a failing compile is exactly the regression the
    compiled lane exists to catch, so it propagates."""
    global _COMPILED_OK
    if _COMPILED_OK is None:
        try:
            int8_quantize(jnp.ones((8,), jnp.float32), interpret=False)
            _COMPILED_OK = True
        except Exception:
            if jax.default_backend() != "cpu":
                raise
            _COMPILED_OK = False
    return _COMPILED_OK


def _pad_rows(x, mult, value=0.0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        padding = jnp.full((pad,) + x.shape[1:], value, x.dtype)
        x = jnp.concatenate([x, padding], 0)
    return x, n


@partial(jax.jit, static_argnames=("interpret", "block_b"))
def gmm_posterior(z, mu, var, logpi, *, block_b=128, interpret=None):
    """-> (responsibilities (B, C), entropy (B,))."""
    interpret = _resolve(interpret)
    zp, n = _pad_rows(z, block_b)
    resp, ent = gmm_posterior_pallas(zp, mu, var, logpi, block_b=block_b,
                                     interpret=interpret)
    return resp[:n], ent[:n]


@partial(jax.jit, static_argnames=("tau", "interpret", "block_b", "block_n"))
def infonce_vneg(z, z_pos, z_neg, *, tau=0.1, block_b=64, block_n=128,
                 interpret=None):
    """Per-sample streaming InfoNCE (Eq. 10). Inputs must be l2-normalized."""
    interpret = _resolve(interpret)
    B, d = z.shape
    N = z_neg.shape[1]
    bb = min(block_b, B)
    while B % bb:
        bb -= 1
    bn = min(block_n, N)
    while N % bn:
        bn -= 1
    return infonce_vneg_pallas(z, z_pos, z_neg, tau=tau, block_b=bb,
                               block_n=bn, interpret=interpret)


@partial(jax.jit, static_argnames=("n_dirs", "interpret"))
def swd(key, x, *, n_dirs=50, interpret=None):
    """Sliced-W2² to the uniform sphere prior, fully fused (Eq. 3)."""
    interpret = _resolve(interpret)
    from repro.core.swd import random_directions, sphere_prior_samples
    N, d = x.shape
    kd, kp = jax.random.split(key)
    dirs = random_directions(kd, n_dirs, d)
    prior = sphere_prior_samples(kp, N, d)
    n_pow2 = 1 << max((N - 1).bit_length(), 3)
    xp, _ = _pad_rows(x.astype(jnp.float32), n_pow2)
    pq = jnp.sort(prior @ dirs.T, axis=0)                  # (N, M)
    pq = jnp.concatenate(
        [pq, jnp.zeros((n_pow2 - N, n_dirs), jnp.float32)], 0)
    return swd_pallas(xp, pq, dirs, valid_n=N, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def int8_quantize(x, *, interpret=None):
    return int8_quantize_pallas(x, interpret=_resolve(interpret))


@partial(jax.jit, static_argnames=("interpret", "dtype"))
def int8_dequantize(q, scale, zero, *, dtype=jnp.float32, interpret=None):
    return int8_dequantize_pallas(q, scale, zero, dtype=dtype,
                                  interpret=_resolve(interpret))


@partial(jax.jit, static_argnames=("interpret", "block_b"))
def wire_roundtrip(x, *, block_b=8, interpret=None):
    """Fused per-sample INT8 quantize∘dequantize over the leading batch
    dim — the split-link wire stage of ``SplitEngine.run_batch_async``.
    Bitwise-equal to ``jax.vmap(lambda a: dequantize(quantize(a)))``
    (pinned in tests/test_kernels.py), so the per-frame vs bucketed
    bit-parity contract survives the fusion."""
    return wire_roundtrip_pallas(x, block_b=block_b,
                                 interpret=_resolve(interpret))


@partial(jax.jit, static_argnames=("k", "interpret"))
def laplacian_energy(z, mask=None, *, k=5, interpret=None):
    if z.ndim == 2:
        z = z[None]
    if mask is None:
        mask = jnp.ones(z.shape[:2], jnp.float32)
    elif mask.ndim == 1:
        mask = mask[None]
    return laplacian_energy_pallas(z, mask, k=k, interpret=_resolve(interpret))
