"""Temporal Dirichlet-energy Pallas kernel (paper Eq. 6/14): the server
refiner's L_Lap over a W≈100-frame buffer, fused with gap masking.

The whole (T, d) buffer tile sits in VMEM (the paper's W=100, d=128 is
50 KB); for each temporal offset δ ∈ 1..k the kernel accumulates
Σ mask·‖z[t+δ] − z[t]‖² with a shifted elementwise pass — no gather, no
HBM round trips between offsets.  Grid parallelizes over batch rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, m_ref, tot_ref, cnt_ref, *, k):
    z = z_ref[...].astype(jnp.float32)        # (1, T, d)
    m = m_ref[...].astype(jnp.float32)        # (1, T)
    T = z.shape[1]
    total = jnp.float32(0.0)
    count = jnp.float32(0.0)
    for delta in range(1, min(k, T - 1) + 1):
        diff = z[:, delta:] - z[:, :-delta]
        pair = m[:, delta:] * m[:, :-delta]
        total += jnp.sum(jnp.sum(diff * diff, -1) * pair)
        count += jnp.sum(pair)
    tot_ref[...] = total.reshape(tot_ref.shape)
    cnt_ref[...] = count.reshape(cnt_ref.shape)


def laplacian_energy_pallas(z, mask, *, k=5, interpret=True):
    """z: (B, T, d); mask: (B, T). -> scalar mean-edge energy."""
    B, T, d = z.shape
    tot, cnt = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((B,), jnp.float32),
                   jax.ShapeDtypeStruct((B,), jnp.float32)],
        interpret=interpret,
    )(z, mask)
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)
