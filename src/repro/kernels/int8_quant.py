"""Asymmetric INT8 quantization Pallas kernels — the split-link wire
format (paper §5 "Quantization Implementation", <0.5 ms class).

Two-pass: (1) blockwise min/max reduction, (2) fused affine quantize with
the agreed per-tensor scale/zero.  Both passes stream 1-D tiles through
VMEM; pass 2 writes int8 — a 4x HBM-write saving vs fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minmax_kernel(x_ref, lo_ref, hi_ref):
    x = x_ref[...].astype(jnp.float32)
    lo_ref[...] = jnp.min(x, keepdims=True).reshape(lo_ref.shape)
    hi_ref[...] = jnp.max(x, keepdims=True).reshape(hi_ref.shape)


def _quant_kernel(x_ref, sz_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = sz_ref[0]
    zero = sz_ref[1]
    q = jnp.clip(jnp.round(x / scale + zero), -128, 127)
    q_ref[...] = q.astype(jnp.int8)


def _dequant_kernel(q_ref, sz_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = ((q - sz_ref[1]) * sz_ref[0]).astype(x_ref.dtype)


def int8_quantize_pallas(x, *, block=4096, interpret=True):
    """-> (q int8 flat-shaped-like-x, scale (), zero ())."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.full((pad,), flat[0], flat.dtype)])
    g = flat.shape[0] // block
    lo, hi = pl.pallas_call(
        _minmax_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((g,), jnp.float32),
                   jax.ShapeDtypeStruct((g,), jnp.float32)],
        interpret=interpret,
    )(flat)
    lo = jnp.min(lo)
    hi = jnp.max(hi)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    zero = -128.0 - lo / scale
    sz = jnp.stack([scale, zero])
    q = pl.pallas_call(
        _quant_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * block,), jnp.int8),
        interpret=interpret,
    )(flat, sz)
    q = q[:n].reshape(shape)
    return q, scale, zero


def _wire_roundtrip_kernel(x_ref, out_ref):
    """A (block_b, n) tile of samples per grid step: row-wise min/max
    reduction, affine quantize to the int8 grid and requantize back to
    fp32 — one VMEM pass, no int8 tensor ever written to HBM.  The
    arithmetic is kept op-for-op identical to per-sample
    ``quant.int8.dequantize(quantize(x))`` (row min/max are exactly
    associative, the affine chain is elementwise), which is what makes
    the bitwise pin against the vmapped reference possible."""
    x = x_ref[...].astype(jnp.float32)
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    zero = -128.0 - lo / scale
    q = jnp.clip(jnp.round(x / scale + zero), -128, 127).astype(jnp.int8)
    out_ref[...] = (q.astype(jnp.float32) - zero) * scale


def wire_roundtrip_pallas(x, *, block_b=8, interpret=True):
    """Fused per-sample INT8 wire simulation: ``vmap(dequantize∘quantize)``
    over the leading (batch) dim as ONE kernel.

    The two-executable path (``int8_quantize_pallas`` +
    ``int8_dequantize_pallas``) writes the int8 payload to HBM and reads
    it back; serving only needs the *received* activation, so the fused
    kernel keeps each sample's tile in VMEM through reduce → quantize →
    requantize and writes fp32 once.  ``block_b`` rows ride one grid step
    — (8, 128·m) tiles, the fp32 minimum on TPU.  -> same shape as ``x``,
    float32, bitwise-equal to the vmapped reference
    (tests/test_kernels.py pins it in both interpret and compiled modes).
    """
    B = x.shape[0]
    shape = x.shape
    flat = x.reshape(B, -1).astype(jnp.float32)
    n = flat.shape[1]
    pad_n = (-n) % 128               # lane-width alignment for the TPU path
    if pad_n:
        # pad each row with its OWN first element: per-sample min/max —
        # and therefore every quantization constant — is unchanged
        flat = jnp.concatenate(
            [flat, jnp.broadcast_to(flat[:, :1], (B, pad_n))], axis=1)
    bb = min(block_b, B)
    pad_b = (-B) % bb                # pad rows quantize too, sliced off
    if pad_b:
        flat = jnp.concatenate(
            [flat, jnp.broadcast_to(flat[:1], (pad_b,) + flat.shape[1:])])
    out = pl.pallas_call(
        _wire_roundtrip_kernel,
        grid=(flat.shape[0] // bb,),
        in_specs=[pl.BlockSpec((bb, flat.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, flat.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=interpret,
    )(flat)
    return out[:B, :n].reshape(shape)


def int8_dequantize_pallas(q, scale, zero, *, block=4096, dtype=jnp.float32,
                           interpret=True):
    shape = q.shape
    flat = q.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    g = flat.shape[0] // block
    sz = jnp.stack([scale, zero])
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * block,), dtype),
        interpret=interpret,
    )(flat, sz)
    return x[:n].reshape(shape)
