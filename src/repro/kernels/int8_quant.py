"""Asymmetric INT8 quantization Pallas kernels — the split-link wire
format (paper §5 "Quantization Implementation", <0.5 ms class).

Two-pass: (1) blockwise min/max reduction, (2) fused affine quantize with
the agreed per-tensor scale/zero.  Both passes stream 1-D tiles through
VMEM; pass 2 writes int8 — a 4x HBM-write saving vs fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minmax_kernel(x_ref, lo_ref, hi_ref):
    x = x_ref[...].astype(jnp.float32)
    lo_ref[...] = jnp.min(x, keepdims=True).reshape(lo_ref.shape)
    hi_ref[...] = jnp.max(x, keepdims=True).reshape(hi_ref.shape)


def _quant_kernel(x_ref, sz_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = sz_ref[0]
    zero = sz_ref[1]
    q = jnp.clip(jnp.round(x / scale + zero), -128, 127)
    q_ref[...] = q.astype(jnp.int8)


def _dequant_kernel(q_ref, sz_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = ((q - sz_ref[1]) * sz_ref[0]).astype(x_ref.dtype)


def int8_quantize_pallas(x, *, block=4096, interpret=True):
    """-> (q int8 flat-shaped-like-x, scale (), zero ())."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.full((pad,), flat[0], flat.dtype)])
    g = flat.shape[0] // block
    lo, hi = pl.pallas_call(
        _minmax_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((g,), jnp.float32),
                   jax.ShapeDtypeStruct((g,), jnp.float32)],
        interpret=interpret,
    )(flat)
    lo = jnp.min(lo)
    hi = jnp.max(hi)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    zero = -128.0 - lo / scale
    sz = jnp.stack([scale, zero])
    q = pl.pallas_call(
        _quant_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * block,), jnp.int8),
        interpret=interpret,
    )(flat, sz)
    q = q[:n].reshape(shape)
    return q, scale, zero


def int8_dequantize_pallas(q, scale, zero, *, block=4096, dtype=jnp.float32,
                           interpret=True):
    shape = q.shape
    flat = q.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    g = flat.shape[0] // block
    sz = jnp.stack([scale, zero])
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * block,), dtype),
        interpret=interpret,
    )(flat, sz)
    return x[:n].reshape(shape)
