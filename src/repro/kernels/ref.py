"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG2PI = 1.8378770664093453


def gmm_posterior_ref(z, mu, var, logpi):
    """-> (responsibilities (B, C), entropy (B,))."""
    z = z.astype(jnp.float32)
    mu = mu.astype(jnp.float32)
    var = var.astype(jnp.float32)
    d = z.shape[-1]
    maha = jnp.sum(jnp.square(z[:, None, :] - mu[None]) / var[None], -1)
    logdet = jnp.sum(jnp.log(var), -1)
    lj = logpi[None] - 0.5 * (maha + logdet + d * LOG2PI)
    logp = lj - jax.nn.logsumexp(lj, axis=-1, keepdims=True)
    p = jnp.exp(logp)
    ent = -jnp.sum(p * logp, -1)
    return p, ent


def swd_ref(x, prior, dirs):
    """Sliced-W2² between x and prior point sets (both (N, d)) over dirs."""
    px = jnp.sort(x.astype(jnp.float32) @ dirs.T.astype(jnp.float32), axis=0)
    py = jnp.sort(prior.astype(jnp.float32) @ dirs.T.astype(jnp.float32),
                  axis=0)
    return jnp.mean(jnp.square(px - py))


def infonce_vneg_ref(z, z_pos, z_neg, tau):
    """Streaming InfoNCE (Eq. 10); z/z_pos (B, d), z_neg (B, N, d).
    All inputs assumed l2-normalized. -> per-sample loss (B,)."""
    z = z.astype(jnp.float32)
    pos = jnp.sum(z * z_pos.astype(jnp.float32), -1) / tau
    negs = jnp.einsum("bd,bnd->bn", z, z_neg.astype(jnp.float32)) / tau
    logits = jnp.concatenate([pos[:, None], negs], 1)
    return jax.nn.logsumexp(logits, axis=1) - pos


def int8_quantize_ref(x):
    """-> (q int8, scale, zero) — asymmetric per-tensor (quant/int8.py)."""
    x = x.astype(jnp.float32)
    lo, hi = jnp.min(x), jnp.max(x)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    zero = -128.0 - lo / scale
    q = jnp.clip(jnp.round(x / scale + zero), -128, 127).astype(jnp.int8)
    return q, scale, zero


def laplacian_energy_ref(z, mask, k):
    """Temporal k-window Dirichlet energy (core/laplacian.py semantics),
    returning (total, count) so callers can combine partials."""
    z = z.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    T = z.shape[0]
    total = jnp.float32(0.0)
    count = jnp.float32(0.0)
    for delta in range(1, min(k, T - 1) + 1):
        diff = z[delta:] - z[:-delta]
        pair = m[delta:] * m[:-delta]
        total += jnp.sum(jnp.sum(jnp.square(diff), -1) * pair)
        count += jnp.sum(pair)
    return total, count
