"""Fused GMM posterior + entropy Pallas kernel — the "zero-cost
uncertainty" hot path (paper Eq. 11, §4.2.2).

Computes, for a block of embeddings z (Bb, d) against all C components:
    log N(z; mu_c, diag var_c) + log pi_c  ->  softmax  ->  entropy
in one VMEM-resident pass.  The Mahalanobis term is decomposed into three
MXU matmuls:
    maha = z² @ (1/var)ᵀ − 2 z @ (mu/var)ᵀ + Σ mu²/var
so the (B, C) logit tile never round-trips to HBM, and mu/var (C×d, ≤64 KB
at the paper's C=64, d=128) stay pinned in VMEM across the whole batch.

Grid: (B // Bb,) — batch-parallel; C and d are kept whole per block (both
MXU-aligned at the paper's sizes; pad otherwise via ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG2PI = 1.8378770664093453


def _kernel(z_ref, mu_ref, var_ref, logpi_ref, resp_ref, ent_ref, *, d):
    z = z_ref[...].astype(jnp.float32)            # (Bb, d)
    mu = mu_ref[...].astype(jnp.float32)          # (C, d)
    var = var_ref[...].astype(jnp.float32)        # (C, d)
    logpi = logpi_ref[...].astype(jnp.float32)    # (C,)

    inv = 1.0 / var                               # (C, d)
    # maha(b,c) = z²·inv − 2 z·(mu*inv) + Σ mu²·inv     (two MXU matmuls)
    t1 = jnp.dot(z * z, inv.T, preferred_element_type=jnp.float32)
    t2 = jnp.dot(z, (mu * inv).T, preferred_element_type=jnp.float32)
    t3 = jnp.sum(mu * mu * inv, axis=-1)          # (C,)
    maha = t1 - 2.0 * t2 + t3[None, :]
    logdet = jnp.sum(jnp.log(var), axis=-1)       # (C,)
    lj = logpi[None, :] - 0.5 * (maha + logdet[None, :] + d * LOG2PI)

    m = jnp.max(lj, axis=-1, keepdims=True)
    e = jnp.exp(lj - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    logp = lj - m - jnp.log(s)
    p = e / s
    resp_ref[...] = p.astype(resp_ref.dtype)
    ent_ref[...] = (-jnp.sum(p * logp, axis=-1)).astype(ent_ref.dtype)


def gmm_posterior_pallas(z, mu, var, logpi, *, block_b=128, interpret=True):
    B, d = z.shape
    C = mu.shape[0]
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((C, d), lambda i: (0, 0)),
            pl.BlockSpec((C, d), lambda i: (0, 0)),
            pl.BlockSpec((C,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        interpret=interpret,
    )(z, mu, var, logpi)
