"""Flash attention (Pallas, TPU target) with a recompute backward.

This is the designated fix for the memory-bound attention cells in
EXPERIMENTS.md §Perf iteration 1: the pure-jnp chunked-softmax path must
stack per-chunk probabilities (scan-carry saves) for the backward, so
score tiles hit HBM; a fused kernel keeps them in VMEM and the
custom-vjp backward *recomputes* them from the saved (out, m+log l) row
statistics — O(S) residuals instead of O(S²).

Forward grid: (B·H, Q_tiles) with an inner fori over KV tiles (causal
tiles skipped).  Backward: two passes — dq over (B·H, Q_tiles), dk/dv
over (B·H, KV_tiles).  MHA layout (B, H, S, hd); GQA callers expand KV
heads first (cheap — see models/attention.py).  Causal masking only
(softcap/windows stay on the jnp path).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, block_q,
                block_k, seq_k, causal):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale           # (bq, d)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    n_kv = seq_k // block_k

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                      # (bq, bk)
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l, acc

    upper = n_kv if not causal else \
        jnp.minimum(n_kv, (qi + 1) * block_q // block_k + 1)
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(jnp.maximum(l, 1e-30))


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               scale, block_q, block_k, seq_k, causal):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    dq = jnp.zeros_like(q)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    n_kv = seq_k // block_k

    def body(j, dq):
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                   # recomputed probs
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dq + ds @ k

    upper = n_kv if not causal else \
        jnp.minimum(n_kv, (qi + 1) * block_q // block_k + 1)
    dq = jax.lax.fori_loop(0, upper, body, dq)
    dq_ref[...] = (dq * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref,
                dv_ref, *, scale, block_q, block_k, seq_q, causal):
    kj = pl.program_id(1)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    dk = jnp.zeros_like(k)
    dv = jnp.zeros_like(v)
    k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
    n_q = seq_q // block_q

    def body(i, carry):
        dk, dv = carry
        q = pl.load(q_ref, (pl.dslice(i * block_q, block_q),
                            slice(None))).astype(jnp.float32) * scale
        do = pl.load(do_ref, (pl.dslice(i * block_q, block_q),
                              slice(None))).astype(jnp.float32)
        lse = pl.load(lse_ref, (pl.dslice(i * block_q, block_q),))
        delta = pl.load(delta_ref, (pl.dslice(i * block_q, block_q),))
        s = q @ k.T
        if causal:
            q_pos = i * block_q + jax.lax.iota(jnp.int32, block_q)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        dk = dk + (ds.T @ q)
        return dk, dv

    lower = 0 if not causal else kj * block_k // block_q
    dk, dv = jax.lax.fori_loop(lower, n_q, body, (dk, dv))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _flat(x):
    B, H, S, d = x.shape
    return x.reshape(B * H, S, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                    interpret=True):
    """q, k, v: (B, H, S, hd) — returns (B, H, S, hd)."""
    o, _ = _fwd(q, k, v, causal, block_q, block_k, interpret)
    return o


def _fwd(q, k, v, causal, block_q, block_k, interpret):
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    qf, kf, vf = _flat(q), _flat(k), _flat(v)
    grid = (B * H, Sq // bq)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, block_q=bq, block_k=bk,
                          seq_k=Sk, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Sk, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, Sk, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, bq), lambda h, i: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return o.reshape(B, H, Sq, d), lse


def _fwd_rule(q, k, v, causal, block_q, block_k, interpret):
    o, lse = _fwd(q, k, v, causal, block_q, block_k, interpret)
    return o, (q, k, v, o, lse)


def _bwd_rule(causal, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    qf, kf, vf = _flat(q), _flat(k), _flat(v)
    dof, of = _flat(do), _flat(o)
    delta = jnp.sum(dof.astype(jnp.float32) * of.astype(jnp.float32), -1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=bq, block_k=bk,
                          seq_k=Sk, causal=causal),
        grid=(B * H, Sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Sk, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, Sk, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, bq), lambda h, i: (h, i)),
            pl.BlockSpec((None, bq), lambda h, i: (h, i)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=bq, block_k=bk,
                          seq_q=Sq, causal=causal),
        grid=(B * H, Sk // bk),
        in_specs=[
            pl.BlockSpec((None, Sq, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((None, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((None, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((None, Sq, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((None, Sq), lambda h, j: (h, 0)),
            pl.BlockSpec((None, Sq), lambda h, j: (h, 0)),
        ],
        out_specs=[pl.BlockSpec((None, bk, d), lambda h, j: (h, j, 0)),
                   pl.BlockSpec((None, bk, d), lambda h, j: (h, j, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * H, Sk, d), k.dtype),
                   jax.ShapeDtypeStruct((B * H, Sk, d), v.dtype)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    rs = lambda x: x.reshape(B, H, -1, d)
    return rs(dq), rs(dk), rs(dv)


flash_attention.defvjp(_fwd_rule, _bwd_rule)


def flash_attention_ref(q, k, v, causal=True):
    """jnp oracle (B, H, S, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        Sq, Sk = s.shape[-2:]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
