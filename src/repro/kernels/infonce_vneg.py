"""Flash-style streaming-softmax InfoNCE over virtual negatives
(paper Eq. 10) — the edge contrastive hot loop.

Never materializes the (B, N_syn) logit matrix in HBM: the grid iterates
(batch tile × negative tile) with the negative axis innermost; a running
(m, l) online-logsumexp pair lives in VMEM scratch across the inner
iterations (the same trick as flash attention's softmax).  The per-tile
similarity z·z_synᵀ is a batched MXU matvec.

Grid: (B/Bb, N/Nb), dimension order guarantees out/scratch blocks for a
given batch tile stay resident while negatives stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_BIG = -1e30


def _kernel(z_ref, zp_ref, zn_ref, loss_ref, m_ref, l_ref, *, tau, n_tiles):
    j = pl.program_id(1)
    z = z_ref[...].astype(jnp.float32)            # (Bb, d)
    zn = zn_ref[...].astype(jnp.float32)          # (Bb, Nb, d)
    s = jnp.einsum("bd,bnd->bn", z, zn) / tau     # (Bb, Nb)

    @pl.when(j == 0)
    def _init():
        zp = zp_ref[...].astype(jnp.float32)
        pos = jnp.sum(z * zp, axis=-1) / tau      # (Bb,)
        m_ref[...] = pos                          # running max seeded w/ pos
        l_ref[...] = jnp.ones_like(pos)           # exp(pos - m) = 1
        loss_ref[...] = pos                       # stash pos in the output

    m = m_ref[...]
    l = l_ref[...]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(s - m_new[:, None]), -1)
    m_ref[...] = m_new
    l_ref[...] = l

    @pl.when(j == n_tiles - 1)
    def _fin():
        pos = loss_ref[...]
        # logsumexp = m + log l ;  loss = lse - pos
        loss_ref[...] = m_ref[...] + jnp.log(l_ref[...]) - pos


def infonce_vneg_pallas(z, z_pos, z_neg, *, tau=0.1, block_b=128,
                        block_n=256, interpret=True):
    """z, z_pos: (B, d) l2-normalized; z_neg: (B, N, d). -> (B,) loss."""
    B, d = z.shape
    N = z_neg.shape[1]
    assert B % block_b == 0 and N % block_n == 0, (B, N)
    grid = (B // block_b, N // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, tau=tau, n_tiles=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_n, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),   # loss
            jax.ShapeDtypeStruct((B,), jnp.float32),   # m (discarded)
            jax.ShapeDtypeStruct((B,), jnp.float32),   # l (discarded)
        ],
        interpret=interpret,
    )(z, z_pos, z_neg)[0]
