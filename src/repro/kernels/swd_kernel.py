"""Sliced-Wasserstein Pallas kernel (paper Eq. 3, the §5 "1.2 ms" hot
spot): fused  projection matmul -> in-VMEM bitonic sort -> quantile-L2.

Per grid step, a tile of M_b projection directions is handled end-to-end:
  proj = x @ dirsᵀ            (N × Mb, MXU)
  sort columns                 (bitonic network, log²N VPU stages, VMEM)
  partial = Σ (sort(proj) − prior_q)²
The (N, M) projection matrix never exists in HBM, and the sort — the
O(M·N log N) bottleneck the paper pays 1.2 ms for — runs entirely out of
VMEM.  N must be a power of two (ops.py pads with +inf sentinels that the
caller's averaging divides out via the `count` output).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _bitonic_sort_cols(a):
    """Sort each column of a (N, M) array ascending via a bitonic network.

    The partner exchange a[i ^ j] is a *block swap*: XOR with the
    power-of-two stride j flips the bit of weight j, i.e. swaps adjacent
    row-blocks of size j — expressed as reshape + flip rather than a
    gather (row-gathers in the unrolled network make XLA compile time
    explode combinatorially: minutes at N=32, hours beyond).  The
    permutation-carrying twin lives in core/swd.py::_bitonic_sort_with_perm
    — keep exchange-step changes in sync."""
    N, M = a.shape
    assert (N & (N - 1)) == 0, "power of two"
    idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    k = 2
    while k <= N:
        j = k // 2
        while j >= 1:
            a_part = jnp.flip(
                a.reshape(N // (2 * j), 2, j, M), axis=1).reshape(N, M)
            dir_up = (idx & k) == 0
            keep_min = ((idx & j) == 0) == dir_up   # idx < (idx ^ j)
            lo = jnp.minimum(a, a_part)
            hi = jnp.maximum(a, a_part)
            a = jnp.where(keep_min, lo, hi)
            j //= 2
        k *= 2
    return a


def _kernel(x_ref, dirs_ref, pq_ref, out_ref, *, valid_n):
    x = x_ref[...].astype(jnp.float32)            # (N, d)
    dirs = dirs_ref[...].astype(jnp.float32)      # (Mb, d)
    pq = pq_ref[...].astype(jnp.float32)          # (N, Mb) sorted prior
    proj = jnp.dot(x, dirs.T, preferred_element_type=jnp.float32)  # (N, Mb)
    # +inf sentinels on padded rows sort to the bottom
    n = proj.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, proj.shape, 0)
    proj = jnp.where(row < valid_n, proj, BIG)
    srt = _bitonic_sort_cols(proj)
    diff = jnp.where(row < valid_n, srt - pq, 0.0)
    out_ref[...] = jnp.sum(diff * diff, keepdims=True).reshape(out_ref.shape)


def swd_pallas(x, prior_sorted, dirs, *, valid_n=None, block_m=None,
               interpret=True):
    """x: (N, d) with N a power of 2 (rows >= valid_n are padding);
    prior_sorted: (N, M) per-direction sorted prior quantiles (padded rows
    ignored); dirs: (M, d).  -> mean squared quantile difference."""
    N, d = x.shape
    M = dirs.shape[0]
    valid_n = valid_n or N
    block_m = block_m or M
    assert M % block_m == 0
    g = M // block_m
    partial = pl.pallas_call(
        functools.partial(_kernel, valid_n=valid_n),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((N, d), lambda i: (0, 0)),
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((N, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g,), jnp.float32),
        interpret=interpret,
    )(x, dirs, prior_sorted)
    return jnp.sum(partial) / (valid_n * M)
