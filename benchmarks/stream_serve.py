"""Streaming serving benchmark: the always-on pipelined ``StreamServer``
vs the hand-rolled sequential ``submit``+``tick`` loop, plus a QoS
overload lane.

**Lane 1 — mixed-k throughput.**  N concurrent sessions, the deep thin
encoder of ``gateway_serve``'s mixed-k lane (L=8 -> 9 k-buckets per
tick), identical pre-built frames for every path:

- ``seq_sync``  — sequential loop over ``overlap=False`` (the PR-3
  per-bucket-sync dispatch: the fully *synchronous* serving model, one
  host staging + one blocking device round-trip per bucket);
- ``seq_async`` — sequential loop over the overlapped single-sync tick
  (PR 4's data plane, still one thread driving submit→tick→results);
- ``server``    — the threaded ``StreamServer``: clients submit from
  their own thread, the serving thread pipelines tick t+1's staging
  under tick t's in-flight chains.

Hard asserts: server embeddings **bit-identical** per (sid, t) to the
sequential gateway serving the same frames, and
``device_syncs_per_tick == 1`` throughout.  Speedups are *reported* (and
written to ``BENCH_stream.json``): the ≥1.3x target is against the
synchronous loop and, like every overlap number in this repo, is
regime-bound — on a 2-core CPU runner the "device" shares cores with
the host thread, so both overlap layers win only what the spare cores
can absorb (docs/PERF.md's regime note; on an accelerator backend every
blocking round-trip the baselines pay is a real stall).

**Lane 2 — synthetic overload.**  Offered load 2x tick capacity across
the three QoS classes with bounded queues (producer paced by
backpressure).  Hard asserts: conservation (accepted == served +
backlog + shed; ``preempted == requeued`` > 0 and only BULK),
INTERACTIVE p95 queue wait < BULK p50, INTERACTIVE misses no deadlines.
Reports per-class p50/p95 queue waits, deadline-miss rates and shed
counts.

**Lane 3 — sustained overload, deterministic.**  ~2x capacity for the
WHOLE run on a stepped fake clock: mixed tenants (weighted STANDARD,
a rate-limited chatty tenant, BULK beyond the aging quota).  Hard
asserts: no BULK starvation with the terminal wait bounded by
``deadline + shed_horizon + 2 ticks``, weighted DRR honors 2:1, real
sheds are visible in ``shed_expired``, and two independent runs are
bit-identical — a fairness regression fails loudly, never flakes.

    PYTHONPATH=src python -m benchmarks.stream_serve [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import row
from benchmarks.gateway_serve import DEEP_KW, MixedKPolicy

N = 32
WARMUP_ROUNDS = 2


def _build(n, rounds_total):
    from repro.api import FrameRequest
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    us = rng.permutation(np.linspace(0.02, 0.98, n))
    frames = [[FrameRequest(
        t=t, mel=rng.normal(size=(cfg.frames, cfg.n_mels)).astype(
            np.float32), u=float(us[i]))
        for i in range(n)] for t in range(rounds_total)]
    return cfg, params, frames


def _gateway(cfg, params, n, *, overlap=True):
    from repro.api import StreamSplitGateway
    return StreamSplitGateway(cfg, params, policy=MixedKPolicy(cfg.n_blocks),
                              capacity=n, window=16, qos_reserve=0,
                              overlap=overlap)


def bench_stream(n=N, *, rounds=24, repeats=3):
    """-> lane-1 result dict.  Interleaved best-of-repeats (machine
    drift hits every path equally); bit-parity asserted on the warmup
    rounds BEFORE anything is timed."""
    from repro.serving import QueueFullError, SchedulerCfg, StreamServer
    rounds_total = WARMUP_ROUNDS + rounds * repeats
    cfg, params, frames = _build(n, rounds_total)

    lanes = {
        "seq_sync": dict(gw=_gateway(cfg, params, n, overlap=False)),
        "seq_async": dict(gw=_gateway(cfg, params, n)),
    }
    for ln in lanes.values():
        ln["sids"] = [ln["gw"].open_session().sid for _ in range(n)]
        ln["best"] = float("inf")
        ln["results"] = {}
    # open-loop ingest: the queue bound exceeds one repeat's offered
    # load, so the producer never stalls inside a timed region (the
    # bounded-queue/backpressure regime is lane 2's subject)
    server_gw = _gateway(cfg, params, n)
    srv = StreamServer(server_gw, cfg=SchedulerCfg(max_batch=n),
                       queue_maxlen=(rounds + WARMUP_ROUNDS) * n)
    srv_sids = [srv.open_session().sid for _ in range(n)]
    srv_best = float("inf")

    def seq_round(ln, t):
        for i, sid in enumerate(ln["sids"]):
            ln["gw"].submit(sid, frames[t][i])
        for r in ln["gw"].tick():
            ln["results"][(r.sid, r.t)] = r

    def srv_pump(t):
        for i, sid in enumerate(srv_sids):
            while True:
                try:
                    srv.submit(sid, frames[t][i])
                    break
                except QueueFullError:     # bounded queue: backpressure
                    time.sleep(1e-4)

    srv_results = {}

    def srv_drain_into():
        for r in srv.drain_results():
            srv_results[(r.sid, r.t)] = r

    with srv:
        # warmup: compile every per-k executable + pow2 bucket shape on
        # every path, and pin bit-parity BEFORE the timed region
        for t in range(WARMUP_ROUNDS):
            for ln in lanes.values():
                seq_round(ln, t)
            srv_pump(t)
        while srv.served_total < WARMUP_ROUNDS * n:
            time.sleep(1e-3)
        srv_drain_into()
        for t in range(WARMUP_ROUNDS):
            for i in range(n):
                key = (srv_sids[i], t)
                za = srv_results[key].z
                for ln in lanes.values():
                    zs = ln["results"][(ln["sids"][i], t)].z
                    assert (za == zs).all(), \
                        f"server diverged from sequential at {key}"
        # timed: interleave the three paths per repeat
        t_base = WARMUP_ROUNDS
        for rep in range(repeats):
            for name, ln in lanes.items():
                t0 = time.perf_counter()
                for t in range(t_base, t_base + rounds):
                    seq_round(ln, t)
                ln["best"] = min(ln["best"], time.perf_counter() - t0)
            done = srv.served_total
            t0 = time.perf_counter()
            for t in range(t_base, t_base + rounds):
                srv_pump(t)
            while srv.served_total < done + rounds * n:
                time.sleep(1e-3)
            srv_best = min(srv_best, time.perf_counter() - t0)
            t_base += rounds
        srv_drain_into()
    st = srv.stats()

    # full-run bit-parity: every frame the server ever served, against
    # the sequential gateway that served the same frame
    assert len(srv_results) == rounds_total * n
    for (sid, t), r in srv_results.items():
        i = srv_sids.index(sid)
        ref = lanes["seq_sync"]["results"][(lanes["seq_sync"]["sids"][i], t)]
        assert (r.z == ref.z).all() and r.k == ref.k, \
            f"server diverged from sequential at {(sid, t)}"
    # the single-sync contract survived pipelining
    assert st.gateway.device_syncs_per_tick == 1
    assert st.gateway.d2h_copies_per_tick == 1
    assert st.pipelined_ticks > 0, "server never overlapped a tick"

    fps = {name: n * rounds / ln["best"] for name, ln in lanes.items()}
    fps["server"] = n * rounds / srv_best
    return {
        "n": n,
        "frames_per_s": fps,
        "speedup_vs_sync": fps["server"] / fps["seq_sync"],
        "speedup_vs_async": fps["server"] / fps["seq_async"],
        "pipelined_tick_fraction": st.pipelined_ticks / max(st.ticks, 1),
        "device_syncs_per_tick": st.gateway.device_syncs_per_tick,
        "bit_identical": True,
    }


def bench_overload(*, rounds=160, capacity=16, max_batch=8):
    """-> lane-2 result dict: 2x offered load, bounded queues, QoS
    isolation measured on the real clock.

    Traffic shape: a big BULK backlog lands first, then the
    latency-sensitive classes arrive in bursts — every INTERACTIVE /
    STANDARD frame that lands while the next (all-BULK) tick is staged
    under the in-flight chains preempts a staged BULK frame.  One
    k-bucket (fixed-k policy) keeps the lane's compile surface tiny;
    the QoS machinery is class-level, not k-level."""
    from repro.api import FrameRequest, QoSClass, StreamSplitGateway
    from repro.api.policies import FixedKPolicy
    from repro.serving import QueueFullError, SchedulerCfg, StreamServer
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    I, S, B = QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    gw = StreamSplitGateway(cfg, params,
                            policy=FixedKPolicy(cfg.n_blocks, 4),
                            capacity=capacity, window=16, qos_reserve=0)
    deadline_ms = {I: 1000.0, S: 1000.0, B: 150.0}
    srv = StreamServer(gw, cfg=SchedulerCfg(max_batch=max_batch,
                                            deadline_ms=deadline_ms),
                       queue_maxlen=8 * capacity,
                       queue_maxlens={B: 1 << 16})
    sids = ([(srv.open_session(qos=I).sid, I) for _ in range(2)]
            + [(srv.open_session(qos=S).sid, S) for _ in range(2)]
            + [(srv.open_session(qos=B).sid, B)
               for _ in range(capacity - 4)])
    bulk_sids = [sid for sid, q in sids if q is B]
    fast_sids = [sid for sid, q in sids if q is not B]
    rng = np.random.default_rng(1)
    mels = [rng.normal(size=(cfg.frames, cfg.n_mels)).astype(np.float32)
            for _ in range(64)]
    accepted = 0
    tick_of = {}                           # rolling frame index per sid

    def bulk_burst(k):
        nonlocal accepted
        sent = 0
        for j in range(k):
            sid = bulk_sids[j % len(bulk_sids)]
            t = tick_of[sid] = tick_of.get(sid, -1) + 1
            try:
                srv.submit(sid, FrameRequest(t=t, mel=mels[t % 64]))
                accepted += 1
                sent += 1
            except QueueFullError:         # shed BULK: counted, reported
                pass
        return sent

    with srv:
        # warmup + service-rate probe (compile happens here, unpaced)
        bulk_burst(64)
        while srv.served_total < 64:
            time.sleep(1e-3)
        t0 = time.perf_counter()
        bulk_burst(256)
        while srv.served_total < 64 + 256:
            time.sleep(1e-3)
        rate = 256 / (time.perf_counter() - t0)   # frames/s, post-compile
        # phase 1: a BULK flood deep enough that draining it takes >> the
        # BULK deadline budget, whatever this machine's service rate is
        backlog = max(12 * rounds, int(4 * rate * deadline_ms[B] * 1e-3))
        t_serve0 = time.perf_counter()
        bulk_burst(backlog)
        # phase 2: latency-class bursts, self-paced one tick apart —
        # each burst lands while an all-BULK tick is staged under the
        # in-flight chains, exactly the preemption window
        for t in range(rounds):
            target = srv.served_total + max_batch
            while srv.served_total < target:
                time.sleep(1e-4)
            for sid in fast_sids:
                while True:
                    try:
                        srv.submit(sid, FrameRequest(
                            t=t, mel=mels[t % 64]))
                        accepted += 1
                        break
                    except QueueFullError:
                        time.sleep(1e-4)
        # phase 3: drain most of the backlog so late-admitted BULK
        # frames carry queue waits far beyond their deadline budget
        # (poll the bare queue depth — stats() rebuilds percentile
        # snapshots and would contend with the thread being measured)
        while srv.queues.depths()["bulk"] > backlog // 3:
            time.sleep(5e-3)
        srv.stop(drain=False)              # keep the rest measurable
    serve_s = time.perf_counter() - t_serve0
    st = srv.stats()

    # conservation: every accepted frame is served, still queued, or
    # (with a shed horizon configured — not in this lane) visibly shed
    assert sum(st.frames_submitted.values()) == accepted
    for c in st.frames_submitted:
        assert st.frames_submitted[c] == (st.frames_served[c]
                                          + st.queue_depth[c]
                                          + st.in_flight[c]
                                          + st.shed_expired[c]), c
    assert st.preempted == st.requeued
    assert st.preempted["bulk"] > 0, "2x overload must preempt BULK"
    assert st.preempted["interactive"] == st.preempted["standard"] == 0
    w = st.queue_wait_ms
    assert w["interactive"]["p95"] < w["bulk"]["p50"], \
        (w["interactive"], w["bulk"])
    # self-consistent, CI-robust form of "INTERACTIVE misses nothing":
    # a miss may only exist if some measured wait actually crossed the
    # budget (a runner stall, not a scheduling bug) — the zero-miss
    # absolute is pinned deterministically in tests/test_serving.py
    assert (st.deadline_misses["interactive"] == 0
            or w["interactive"]["max"] >= deadline_ms[I]), \
        (st.deadline_misses, w["interactive"])
    assert st.deadline_misses["bulk"] > 0, \
        "a backlog deeper than the BULK budget must miss deadlines"
    served = {c: max(v, 1) for c, v in st.frames_served.items()}
    return {
        "offered_per_round": len(sids),
        "max_batch": max_batch,
        "rounds": rounds,
        "accepted": accepted,
        "served": st.frames_served,
        "backlog": st.queue_depth,
        "rejected_full": st.rejected_full,
        "shed_expired": st.shed_expired,
        "preempted": st.preempted,
        "deadline_ms": {q.value: v for q, v in deadline_ms.items()},
        "deadline_miss_rate": {c: st.deadline_misses[c] / served[c]
                               for c in served},
        "queue_wait_ms": w,
        "frames_per_s": sum(st.frames_served.values()) / max(serve_s, 1e-9),
    }


def bench_sustained(*, rounds=240, max_batch=8):
    """-> lane-3 result dict: SUSTAINED overload (~2x capacity for the
    whole run, not a burst), mixed tenants, every scheduling decision on
    a stepped fake clock — the lane is bit-reproducible, so a fairness
    regression fails loudly instead of flaking.

    Tenants: one INTERACTIVE (3 frames/tick, tight deadline), three
    STANDARD — two equal-weight plus one double-weight — and a "chatty"
    STANDARD tenant offering 3x its token-bucket budget, and one BULK
    tenant offering more than the aging lane can promote (so real sheds
    happen deterministically).

    Hard asserts: no starvation (BULK keeps being served via aged
    promotion while STANDARD backlog never clears), BULK terminal wait
    bounded by ``deadline + shed_horizon + 2 ticks``, INTERACTIVE
    misses zero deadlines, DRR honors the 2:1 weight, the chatty tenant
    is capped at its token-bucket rate without hurting its peers, the
    extended conservation invariant holds at every sampled snapshot,
    and TWO independent runs produce identical schedules, sheds and
    counters."""
    from repro.api import FrameRequest, QoSClass, StreamSplitGateway
    from repro.api.policies import FixedKPolicy
    from repro.serving import (QueueFullError, RateLimitError,
                               SchedulerCfg, StreamServer)
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    I, S, B = QoSClass.INTERACTIVE, QoSClass.STANDARD, QoSClass.BULK
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    DT = 0.05                              # one tick per 50 ms of fake time
    deadline_ms = {I: 200.0, S: 2000.0, B: 1000.0}
    shed_horizon_ms = 400.0
    max_wait_ms = {B: 600.0}

    class _FakeClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    def run_once():
        clock = _FakeClock()
        gw = StreamSplitGateway(cfg, params,
                                policy=FixedKPolicy(cfg.n_blocks, 4),
                                capacity=8, window=16, qos_reserve=0,
                                clock=clock)
        # queues sized so SERVING (not queue-full) is the bottleneck for
        # S/B: the shed horizon bounds the backlog instead — a full
        # shared class queue would ration acceptance by submit order
        # and mask the scheduler's fairness (lane 2 owns that regime)
        srv = StreamServer(gw, cfg=SchedulerCfg(
            max_batch=max_batch, deadline_ms=deadline_ms,
            max_wait_ms=max_wait_ms, promote_quota=0.25,
            shed_horizon_ms=shed_horizon_ms), queue_maxlen=64,
            queue_maxlens={S: 4096, B: 512})
        inter = srv.open_session(qos=I).sid
        # 40 tokens/s at DT=0.05 -> 2 accepted/tick; the tenant offers 6
        chatty = srv.open_session(qos=S, rate_limit=(40.0, 4)).sid
        std_w1 = srv.open_session(qos=S).sid
        std_w2 = srv.open_session(qos=S, weight=2.0).sid
        bulk = srv.open_session(qos=B).sid
        rng = np.random.default_rng(7)
        mels = [rng.normal(size=(cfg.frames, cfg.n_mels)).astype(np.float32)
                for _ in range(32)]
        served_by = {sid: 0 for sid in (inter, chatty, std_w1, std_w2,
                                        bulk)}
        accepted = 0
        tick_of = {}

        def offer(sid, k):
            nonlocal accepted
            for _ in range(k):
                t = tick_of[sid] = tick_of.get(sid, -1) + 1
                try:
                    srv.submit(sid, FrameRequest(t=t, mel=mels[t % 32]))
                    accepted += 1
                except (QueueFullError, RateLimitError):
                    pass                   # typed refusal: counted, visible

        def pump():
            srv.step()
            for res in srv.drain_results():
                served_by[res.sid] += 1
            clock.t += DT

        def check_conservation():
            st = srv.stats()
            for c in st.frames_submitted:
                assert st.frames_submitted[c] == (
                    st.frames_served[c] + st.queue_depth[c]
                    + st.in_flight[c] + st.shed_expired[c]), (c, st)
            assert st.preempted == st.requeued
            return st

        # stepped, not threaded: the serving thread only ever runs
        # step(), so this IS the serving loop — minus nondeterminism
        for r_ in range(rounds):
            offer(inter, 3)
            offer(chatty, 6)
            offer(std_w1, 2)
            offer(std_w2, 2)
            offer(bulk, 3)                 # > the 2/tick promote quota
            pump()
            if r_ % 8 == 0:
                check_conservation()
        st_mid = check_conservation()
        assert st_mid.queue_depth["standard"] > 0, \
            "sustained lane must keep STANDARD saturated"
        served_mid = dict(served_by)       # fair-share ratio is measured
        #                                    over the SUSTAINED phase —
        #                                    the drain below serves every
        #                                    backlog and dilutes it
        while sum(srv.stats().queue_depth.values()) \
                + sum(srv.stats().in_flight.values()):
            pump()                         # drain: clock keeps ticking
        st = check_conservation()
        return {"st": st, "served_by": served_by, "served_mid": served_mid,
                "accepted": accepted,
                "sids": dict(inter=inter, chatty=chatty, std_w1=std_w1,
                             std_w2=std_w2, bulk=bulk),
                "schedule": srv.schedule()}

    a, b = run_once(), run_once()
    # bit-reproducibility: same admitted schedule, same sheds, same
    # promotions, same refusals, same wait percentiles — twice
    assert a["schedule"] == b["schedule"], "sustained lane nondeterministic"
    for field in ("frames_submitted", "frames_served", "shed_expired",
                  "promoted", "rejected_full", "rejected_rate_limited",
                  "deadline_misses", "queue_wait_ms"):
        assert getattr(a["st"], field) == getattr(b["st"], field), field
    assert a["served_by"] == b["served_by"]

    st, ids = a["st"], a["sids"]
    w = st.queue_wait_ms
    # no starvation: BULK is served continuously through the aging lane
    # even though plain priority fill never reaches it (STANDARD stayed
    # saturated all run), and EVERY terminal wait — served OR shed — is
    # bounded by deadline + horizon + 2 tick windows, per class
    assert st.promoted["bulk"] > rounds // 2
    assert a["served_by"][ids["bulk"]] > rounds
    bulk_bound_ms = deadline_ms[B] + shed_horizon_ms + 2 * DT * 1e3
    assert w["bulk"]["max"] <= bulk_bound_ms, (w["bulk"], bulk_bound_ms)
    assert w["standard"]["max"] <= (deadline_ms[S] + shed_horizon_ms
                                    + 2 * DT * 1e3), w["standard"]
    # real load-shedding: offered BULK exceeds the promote quota (and
    # offered STANDARD exceeds its slots), so the excess expires past
    # the horizon and is dropped VISIBLY — never silently
    assert st.shed_expired["bulk"] > 0
    assert st.shed_expired["interactive"] == 0
    # INTERACTIVE rides priority fill: zero deadline misses, exact
    assert st.deadline_misses["interactive"] == 0
    assert w["interactive"]["max"] <= deadline_ms[I]
    # DRR over the sustained phase: the double-weight tenant gets ~2x
    # its equal-offered peer, and the chatty tenant is rate-capped to
    # parity with its peers despite offering 3x its budget
    mid = a["served_mid"]
    r21 = mid[ids["std_w2"]] / max(mid[ids["std_w1"]], 1)
    assert 1.6 <= r21 <= 2.4, f"weighted DRR share off 2:1: {r21:.2f}"
    assert st.rejected_rate_limited["standard"] > rounds
    assert mid[ids["chatty"]] <= 1.2 * mid[ids["std_w1"]]
    return {
        "rounds": rounds,
        "max_batch": max_batch,
        "tick_ms": DT * 1e3,
        "offered_per_tick": 16,
        "accepted": a["accepted"],
        "served": st.frames_served,
        "served_by_tenant": {name: a["served_by"][sid]
                             for name, sid in ids.items()},
        "served_by_tenant_sustained": {name: mid[sid]
                                       for name, sid in ids.items()},
        "standard_weight_ratio": r21,
        "promoted": st.promoted,
        "shed_expired": st.shed_expired,
        "rejected_full": st.rejected_full,
        "rejected_rate_limited": st.rejected_rate_limited,
        "deadline_misses": st.deadline_misses,
        "queue_wait_ms": w,
        "bulk_wait_bound_ms": bulk_bound_ms,
        "deadline_ms": {q.value: v for q, v in deadline_ms.items()},
        "reproducible": True,
    }


def run_all(*, quick=False, smoke=False):
    result = {"stream": {}, "overload": {}}
    rounds = 6 if smoke else (12 if quick else 24)
    m = bench_stream(N, rounds=rounds, repeats=2 if smoke else 3)
    result["stream"][N] = m
    fps = m["frames_per_s"]
    row(f"stream.seq_sync.N{N}", 1e6 / fps["seq_sync"],
        "sequential submit+tick, per-bucket-sync plane")
    row(f"stream.seq_async.N{N}", 1e6 / fps["seq_async"],
        "sequential submit+tick, single-sync plane")
    row(f"stream.server.N{N}", 1e6 / fps["server"],
        f"{m['speedup_vs_sync']:.2f}x vs sync loop, "
        f"{m['speedup_vs_async']:.2f}x vs single-sync loop, "
        f"bit-identical, {m['pipelined_tick_fraction']:.0%} ticks "
        "pipelined, 1 sync/tick")
    if m["speedup_vs_sync"] < 1.3:
        import sys
        print(f"# WARNING: stream server {m['speedup_vs_sync']:.2f}x vs "
              "the synchronous loop (< the 1.3x target) — overlap wins "
              "are regime-bound on shared-core CPU runners (docs/PERF.md)",
              file=sys.stderr)
    o = bench_overload(rounds=40 if smoke else 160)
    result["overload"] = o
    row("stream.overload.interactive_p95_wait",
        o["queue_wait_ms"]["interactive"]["p95"] * 1e3,
        f"ms*1e3; BULK p50 {o['queue_wait_ms']['bulk']['p50']:.1f}ms, "
        f"{o['preempted']['bulk']} preempted (conserved), "
        f"bulk miss rate {o['deadline_miss_rate']['bulk']:.2f}")
    u = bench_sustained(rounds=80 if smoke else 240)
    result["sustained"] = u
    row("stream.sustained.bulk_max_wait",
        u["queue_wait_ms"]["bulk"]["max"] * 1e3,
        f"ms*1e3 (bound {u['bulk_wait_bound_ms']:.0f}ms); "
        f"{u['promoted']['bulk']} promoted, "
        f"{u['shed_expired']['bulk']} shed visibly, "
        f"DRR 2:1 ratio {u['standard_weight_ratio']:.2f}, "
        "bit-reproducible")
    print("BENCH " + json.dumps({"bench": "stream_serve", **result}))
    return result


def write_bench_json(result, path="BENCH_stream.json"):
    """Machine-readable stream-serving trajectory (CI artifact — see
    docs/STREAMING.md for the schema)."""
    doc = {"bench": "stream_serve", "schema": 1,
           "backend": jax.default_backend(), **result}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: fewest rounds that still "
                         "exercise every assert")
    args = ap.parse_args()
    out = run_all(quick=args.quick, smoke=args.smoke)
    print("wrote", write_bench_json(out))
