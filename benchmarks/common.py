"""Shared benchmark infra: timing, CSV rows, cached PPO policies, and the
system-metric episode runner used by the Fig 6/7 and Table 2/4/6 benches.
"""
from __future__ import annotations

import itertools
import os
import time

import jax
import numpy as np

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
ROWS = []


def row(name, us_per_call, derived=""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def pcts(ms):
    """mean/p50/p95 of a latency sample list — the shape every serving
    benchmark reports alongside its throughput number."""
    return {"mean": float(np.mean(ms)),
            "p50": float(np.percentile(ms, 50)),
            "p95": float(np.percentile(ms, 95))}


def time_us(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
# Cached PPO policies (paper: trained offline on traces across profiles)
# ---------------------------------------------------------------------------

def policy_path(platform):
    return os.path.join(ART, f"ppo_{platform}.npz")


def get_policy(platform="pi4", *, iters=40, force=False, verbose=False):
    from repro.core.env import EdgeCloudEnv, EnvCfg
    from repro.core.ppo import PPOCfg, train_ppo
    os.makedirs(ART, exist_ok=True)
    path = policy_path(platform)
    if os.path.exists(path) and not force:
        data = np.load(path)
        return {k: jax.numpy.asarray(v) for k, v in data.items()}
    profiles = ["stable", "variable", "congested", "wifi", "5g", "dropout"]
    counter = itertools.count()

    def factory():
        i = next(counter)
        return EdgeCloudEnv(EnvCfg(platform=platform,
                                   net=profiles[i % len(profiles)],
                                   horizon=200, seed=i))

    n_actions = EdgeCloudEnv(EnvCfg(platform=platform)).L + 1
    params, hist = train_ppo(factory, n_actions,
                             PPOCfg(iters=iters, steps_per_iter=2048,
                                    seed=0),
                             verbose=verbose)
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})
    return params


def episode_summary(kind, *, platform="pi4", net="stable", horizon=600,
                    seed=7, rl_params=None, static_k=3, extra_kb=0.0,
                    env_overrides=None):
    """Run one policy through the calibrated env; returns summary dict.

    extra_kb models per-batch sync overhead of FSL/FedCL baselines."""
    from repro.core.controller import Controller, run_episode
    from repro.core.env import EdgeCloudEnv, EnvCfg
    env = EdgeCloudEnv(EnvCfg(platform=platform, net=net, horizon=horizon,
                              **(env_overrides or {})))
    ctrl = Controller(kind, env.L, rl_params=rl_params, static_k=static_k)
    s = run_episode(env, ctrl, seed=seed)
    if extra_kb:
        s["kb_per_batch"] += extra_kb
        # radio energy for the extra sync bytes
        s["energy_mj"] += extra_kb * 1024 / 8 * 5.46e-6 * 1e3
    return s


METHODS = ("Edge-Only", "Server-Only", "FSL", "FedCL", "Rule-Based",
           "StreamSplit")

# controller kind, per-batch sync overhead KB, env overrides
_METHOD_MAP = {
    "Edge-Only": ("edge", 0.0, None),
    "Server-Only": ("server", 0.0, None),
    # fixed split + periodic split-weight sync
    "FSL": ("static", 130.0, None),
    # local training with *synchronized memory banks*: the bank restores
    # global negatives (no dimensional collapse -> q_min=1) but hard frames
    # still lack server refinement, and the bank sync costs bandwidth.
    "FedCL": ("edge", 200.0, {"q_min": 1.0, "o_ref": 1e-9}),
    "Rule-Based": ("rule", 0.0, None),
    "StreamSplit": ("rl", 0.0, None),
}


def method_summary(method, *, platform="pi4", net="stable", horizon=600,
                   seed=7):
    """The paper's six methods mapped onto controller kinds + overheads."""
    rl = get_policy(platform) if method == "StreamSplit" else None
    kind, extra, ovr = _METHOD_MAP[method]
    return episode_summary(kind, platform=platform, net=net,
                           horizon=horizon, seed=seed, rl_params=rl,
                           extra_kb=extra, env_overrides=ovr)


def method_summary_mixed(method, *, platform="pi4", horizon=400, seed=7,
                         nets=("stable", "variable", "congested")):
    """Average over network profiles — the deployment-realistic accuracy
    comparison (differentiates static from adaptive policies)."""
    outs = [method_summary(method, platform=platform, net=n,
                           horizon=horizon, seed=seed + i)
            for i, n in enumerate(nets)]
    return {k: float(np.mean([o[k] for o in outs])) for k in outs[0]}
