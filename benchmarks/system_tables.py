"""Paper system tables: Fig 6 (bandwidth), Fig 7 (latency), Table 2
(energy/battery), Table 4 (adaptation), Table 6 (cross-platform),
Table 7 (policy transfer)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (METHODS, episode_summary, get_policy,
                               method_summary, method_summary_mixed, row)
from repro.core.env import (EdgeCloudEnv, EnvCfg, battery_hours,
                            utility_to_accuracy)
from repro.core.controller import Controller


def bench_bandwidth():
    """Fig 6: KB per processing batch (8 clips)."""
    base = None
    for m in METHODS:
        s = method_summary(m, net="stable")
        if m == "Server-Only":
            base = s["kb_per_batch"]
        row(f"fig6_bandwidth_kb_per_batch[{m}]", s["kb_per_batch"],
            f"paper:{dict(zip(METHODS, (1.0, 256, 187.2, 201.4, 124.3, 58.7)))[m]}")
    s = method_summary("StreamSplit", net="stable")
    red = 100 * (1 - s["kb_per_batch"] / base)
    row("fig6_bandwidth_reduction_pct[StreamSplit]", red, "paper:77.1")


def bench_latency():
    """Fig 7: end-to-end latency/batch, stable + congested."""
    for net, paper_ss, paper_srv in (("stable", 127, 464),
                                     ("congested", 287, 1847)):
        srv = method_summary("Server-Only", net=net)
        ss = method_summary("StreamSplit", net=net)
        row(f"fig7_latency_ms_batch[Server-Only,{net}]", srv["lat_ms"] * 8,
            f"paper:{paper_srv}")
        row(f"fig7_latency_ms_batch[StreamSplit,{net}]", ss["lat_ms"] * 8,
            f"paper:{paper_ss}")
        red = 100 * (1 - ss["lat_ms"] / srv["lat_ms"])
        row(f"fig7_latency_reduction_pct[{net}]", red,
            "paper:72.6" if net == "stable" else "paper:84.5")
        row(f"fig7_breakdown_ms[StreamSplit,{net}]", ss["lat_ms"] * 8,
            f"edge:{ss['edge_ms']*8:.0f};net:{ss['net_ms']*8:.0f};"
            f"server:{ss['server_ms']*8:.0f}")


def bench_energy():
    """Table 2: energy/frame + battery life on Pi 4B (10,000 mAh)."""
    paper = {"Edge-Only": (67.4, 14.8), "Server-Only": (187.2, 5.3),
             "FSL": (147.0, 6.8), "FedCL": (164.7, 6.1),
             "Rule-Based": (141.3, 7.1), "StreamSplit": (89.3, 11.2)}
    for m in METHODS:
        s = method_summary(m, net="stable")
        row(f"table2_energy_mj[{m}]", s["energy_mj"], f"paper:{paper[m][0]}")
        row(f"table2_battery_h[{m}]", battery_hours(s["energy_mj"]),
            f"paper:{paper[m][1]}")


def bench_accuracy():
    """Fig 8 (system view): utility->accuracy over mixed profiles."""
    paper = {"Edge-Only": 58.6, "Server-Only": 73.6, "FSL": 66.4,
             "FedCL": 68.7, "Rule-Based": 68.2, "StreamSplit": 71.8}
    accs = {}
    for m in METHODS:
        s = method_summary_mixed(m)
        accs[m] = utility_to_accuracy(s["utility"])
        row(f"fig8_accuracy_pct[{m}]", accs[m], f"paper:{paper[m]}")
    # the paper's 2.2% gap is under stable conditions (Fig 8); under the
    # mixed volatile profiles StreamSplit can BEAT Server-Only (drops)
    srv = utility_to_accuracy(
        method_summary("Server-Only", net="stable")["utility"])
    ss = utility_to_accuracy(
        method_summary("StreamSplit", net="stable")["utility"])
    row("fig8_gap_to_server_pct[stable]", srv - ss, "paper:<=2.2")


def _adaptation_time(kind, rl_params=None, *, seed=3):
    """Time (ms of stream) for latency to recover within 1.5x of its new
    steady state after a bandwidth collapse (stable -> congested)."""
    env = EdgeCloudEnv(EnvCfg(net="stable", horizon=10 ** 9))
    ctrl = Controller(kind, env.L, rl_params=rl_params)
    obs = env.reset(seed=seed)
    for _ in range(100):
        obs, _, _, _ = env.step(ctrl.decide(obs))
    # bandwidth collapse
    env.net = type(env.net)("shock", (1.0, 2.0), (150, 200), 0.03, 0.1)
    env.bw = 1.5
    # steady-state latency under shock for this policy (oracle run)
    lat = []
    t_rec = None
    for t in range(400):
        obs, _, _, info = env.step(ctrl.decide(obs))
        lat.append(info["lat_ms"])
        if t > 30 and t_rec is None:
            recent = np.mean(lat[-5:])
            tail = np.mean(lat[-30:])
            if recent < 1.2 * np.median(lat[-10:]) and \
               recent <= 1.5 * min(np.mean(lat[i:i + 5])
                                   for i in range(len(lat) - 5)):
                t_rec = t
    if t_rec is None:
        t_rec = 400
    return t_rec * 100.0  # decision interval = 100 ms


def bench_adaptation():
    """Table 4: static / rule / RL — accuracy, latency, energy, adaptation."""
    rl = get_policy("pi4")
    paper = {"static": (68.7, 203, 142.6, None),
             "rule": (69.4, 156, 118.7, 4200),
             "rl": (71.8, 127, 89.3, 1200)}
    for kind in ("static", "rule", "rl"):
        s = method_summary_mixed(
            {"static": "FSL", "rule": "Rule-Based",
             "rl": "StreamSplit"}[kind])
        p = paper[kind]
        row(f"table4_accuracy_pct[{kind}]",
            utility_to_accuracy(s["utility"]), f"paper:{p[0]}")
        row(f"table4_latency_ms[{kind}]", s["lat_ms"] * 8, f"paper:{p[1]}")
        row(f"table4_energy_mj[{kind}]", s["energy_mj"], f"paper:{p[2]}")
        if kind != "static":
            t = _adaptation_time(kind, rl_params=rl)
            row(f"table4_adaptation_ms[{kind}]", t, f"paper:{p[3]}")


def bench_cross_platform():
    """Table 6: Pi 4B vs Apple M2 with platform-native policies."""
    paper = {"pi4": (71.8, 127, 89.3, 58.7), "m2": (73.2, 67, 78.4, 42.3)}
    for plat in ("pi4", "m2"):
        rl = get_policy(plat)
        s = episode_summary("rl", platform=plat, net="stable",
                            rl_params=rl)
        p = paper[plat]
        row(f"table6_accuracy_pct[{plat}]",
            utility_to_accuracy(s["utility"]), f"paper:{p[0]}")
        row(f"table6_latency_ms[{plat}]", s["lat_ms"] * 8, f"paper:{p[1]}")
        row(f"table6_energy_mj[{plat}]", s["energy_mj"], f"paper:{p[2]}")
        row(f"table6_bandwidth_kb[{plat}]", s["kb_per_batch"],
            f"paper:{p[3]}")


def bench_policy_transfer():
    """Table 7: direct cross-platform policy transfer."""
    for src, dst, paper_acc in (("pi4", "pi4", 71.8), ("m2", "pi4", 69.4),
                                ("m2", "m2", 73.2), ("pi4", "m2", 72.0)):
        rl = get_policy(src)
        s = episode_summary("rl", platform=dst, net="stable", rl_params=rl)
        tag = "native" if src == dst else "transfer"
        row(f"table7_accuracy_pct[{src}->{dst},{tag}]",
            utility_to_accuracy(s["utility"]), f"paper:{paper_acc}")


def run_all():
    bench_bandwidth()
    bench_latency()
    bench_energy()
    bench_accuracy()
    bench_adaptation()
    bench_cross_platform()
    bench_policy_transfer()
