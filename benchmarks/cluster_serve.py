"""Multi-gateway federation benchmark: a ``GatewayCluster`` of N member
``StreamServer``s under steady mixed-k load, with a live ``drain()``
(rolling-restart migration) in the middle of the run, plus a CHAOS lane
(seeded member kill mid-stream, replication off vs on — the loss bound
and the bit-identical journal-replay recovery are hard asserts).

**Lane — drain under load, N ∈ {2, 4} members.**  ``sessions_per_member``
sessions per member (consistent-hash placement), every session holding a
CONSTANT uncertainty so its k-bucket is stable tick-to-tick.  Because
the fleet executables are jitted per gateway *instance*, a receiver that
has never served a migrated composition pays XLA compile on first
contact — so the lane warms with a full dry drain → ``add_member``
rejoin cycle (which itself exercises the rebalance path both ways), then
times three phases:

- ``before``        — steady state, all members serving;
- ``during_drain``  — the same offered load with a ``drain(victim)``
  dropped mid-round, so the victim's sessions quiesce, export and
  import onto ring-chosen survivors (books + token bucket + queued
  frames with original deadlines) while traffic keeps flowing;
- ``after``         — steady state on the survivors.

Reported (and written to ``BENCH_cluster.json``): frames/s per phase,
warm migration pause p50/p95/max ms (wall-clock per session move:
quiesce → export → import), the cold first-contact pause for contrast,
and migrated frame/byte volume.

Hard asserts — a failure fails the process loudly (CI smoke runs this):

- the cluster-wide per-class conservation identity ``submitted ==
  served + queue_depth + in_flight + shed_expired + lost_in_flight``
  holds at every sampled snapshot, and after the final pump every
  accepted frame was served (zero shed, zero lost — a drain drops
  nothing);
- exactly the victim's sessions migrated, and queued frames travelled
  with them (``migrated_frames > 0``);
- **bit-parity**: every migrated session's full served stream (z, k)
  is bit-identical to an unmigrated replay of the same frames on a
  fresh single gateway — migration is invisible to the embedding.

    PYTHONPATH=src python -m benchmarks.cluster_serve [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import row
from benchmarks.gateway_serve import DEEP_KW, MixedKPolicy

SESSIONS_PER_MEMBER = 4
WARMUP_ROUNDS = 2


def _mel(gsid, t, cfg):
    rng = np.random.default_rng(1000 * (gsid + 1) + t)
    return rng.normal(size=(cfg.frames, cfg.n_mels)).astype(np.float32)


def _req(gsid, t, cfg, us):
    from repro.api import FrameRequest
    return FrameRequest(t=t, mel=_mel(gsid, t, cfg), u=us[gsid])


def _member(cfg, params, n):
    from repro.api import StreamSplitGateway
    from repro.serving import SchedulerCfg, StreamServer
    gw = StreamSplitGateway(cfg, params, policy=MixedKPolicy(cfg.n_blocks),
                            capacity=n, window=16, qos_reserve=0,
                            overlap=True)
    # constructed UNSTARTED: the cluster owns stepping
    return StreamServer(gw, cfg=SchedulerCfg(max_batch=n),
                        queue_maxlen=16 * n)


def _pcts(ms):
    if not ms:
        return {"p50": 0.0, "p95": 0.0, "max": 0.0}
    a = np.asarray(ms, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)), "max": float(a.max())}


def bench_cluster_drain(members=2, *, rounds=8,
                        spm=SESSIONS_PER_MEMBER):
    """-> one lane result dict for an N-member cluster."""
    from repro.api import StreamSplitGateway
    from repro.cluster import GatewayCluster
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    n = members * spm
    # constant per-session uncertainty spread over every k-bucket: the
    # bucket composition is stable tick-to-tick, so compiles land in
    # the warmup cycle and the phase numbers measure serving, not XLA
    us = [float(u) for u in
          np.random.default_rng(3).permutation(np.linspace(0.02, 0.98, n))]

    results = []
    servers = {f"g{i}": _member(cfg, params, n) for i in range(members)}
    cl = GatewayCluster(dict(servers), seed=0, on_result=results.append)
    infos = [cl.open_session() for _ in range(n)]
    t_next = 0

    def round_(*, drain=None):
        nonlocal t_next
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t_next, cfg, us))
        if drain is not None:     # mid-round: queued frames must travel
            cl.drain(drain)
        cl.step()
        t_next += 1

    def conserved():
        st = cl.stats()
        assert st.conserved, (st.submitted, st.served, st.queue_depth,
                              st.in_flight, st.shed_expired,
                              st.lost_in_flight)
        return st

    def timed(fn):
        s0 = sum(cl.stats().served.values())
        t0 = time.perf_counter()
        fn()
        cl.pump()
        dt = time.perf_counter() - t0
        conserved()
        return (sum(cl.stats().served.values()) - s0) / dt

    victim = sorted({cl.session_member(i.sid) for i in infos})[0]
    homed = [i.sid for i in infos if cl.session_member(i.sid) == victim]

    # warm cycle: per-member compositions, then a full drain so every
    # survivor compiles the migrated compositions (import + encode),
    # then the rejoin (rebalance moves ownership straight back)
    for _ in range(WARMUP_ROUNDS):
        round_()
    round_(drain=victim)
    for _ in range(WARMUP_ROUNDS):
        round_()
    assert cl.add_member(victim, servers[victim]) == len(homed)
    round_()
    cl.pump()
    st0 = conserved()
    assert st0.migrations == 2 * len(homed) > 0
    cold_pause = _pcts(cl.migration_pauses_ms)

    def steady():
        for _ in range(rounds):
            round_()

    fps_before = timed(steady)

    def drain_phase():
        for _ in range(rounds // 2):
            round_()
        round_(drain=victim)              # live: queued frames travel
        for _ in range(rounds - rounds // 2 - 1):
            round_()

    fps_during = timed(drain_phase)
    fps_after = timed(steady)

    st = conserved()
    assert st.drains - st0.drains == 1
    assert st.migrations - st0.migrations == len(homed)
    assert st.migrated_frames - st0.migrated_frames >= len(homed)
    assert victim not in st.members
    # drained to empty: every accepted frame served, nothing shed/lost
    assert st.served == st.submitted, (st.served, st.submitted)
    assert sum(st.shed_expired.values()) == 0
    assert sum(st.lost_in_flight.values()) == 0
    total = t_next * n
    assert len(results) == total and sum(st.served.values()) == total
    warm_pause = _pcts(cl.migration_pauses_ms[st0.migrations:])

    # bit-parity oracle: replay each MIGRATED session's frames on a
    # fresh never-clustered gateway — z and k must match bitwise
    by_sid = {}
    for r in results:
        by_sid.setdefault(r.sid, {})[r.t] = r
    oracle = StreamSplitGateway(cfg, params,
                                policy=MixedKPolicy(cfg.n_blocks),
                                capacity=len(homed), window=16,
                                qos_reserve=0, overlap=True)
    for gsid in homed:
        assert sorted(by_sid[gsid]) == list(range(t_next))
        osid = oracle.open_session().sid
        for t in range(t_next):
            oracle.submit(osid, _req(gsid, t, cfg, us))
            (ref,) = oracle.tick()
            got = by_sid[gsid][t]
            assert (got.z == ref.z).all() and got.k == ref.k, \
                f"migrated session {gsid} diverged at t={t}"

    for i in infos:
        cl.close_session(i.sid)
    st = conserved()
    assert st.sessions_open == 0
    return {
        "members": members,
        "sessions": n,
        "rounds_per_phase": rounds,
        "frames_per_s": {"before": fps_before,
                         "during_drain": fps_during,
                         "after": fps_after},
        "migration_pause_ms": warm_pause,
        "migration_pause_cold_ms": cold_pause,
        "migrations": st.migrations - st0.migrations,
        "migrated_frames": st.migrated_frames - st0.migrated_frames,
        "migrated_bytes": st.migrated_bytes - st0.migrated_bytes,
        "bit_identical_migrated": True,
        "shed_expired": sum(st.shed_expired.values()),
        "lost_in_flight": sum(st.lost_in_flight.values()),
    }


def _chaos_once(*, replicate, members, rounds, spm, cfg, params, us,
                seed=0):
    """One seeded kill-mid-stream run; same schedule, same kill step,
    replication on or off.  Returns (cluster, infos, results, kill_step,
    victim)."""
    from repro.cluster import FailureInjector, GatewayCluster, HashRing
    n = members * spm
    names = [f"g{i}" for i in range(members)]
    # the victim is the ring owner of gsid 0 — computable before the
    # cluster exists (the ring is a pure function of membership + seed),
    # so the injector can be installed at construction
    victim = HashRing(names, seed=seed).owner(0)
    kill_step = WARMUP_ROUNDS + max(1, rounds // 2)
    results = []
    cl = GatewayCluster({nm: _member(cfg, params, n) for nm in names},
                        seed=seed, snapshot_every=2, replicate=replicate,
                        on_result=results.append,
                        injectors={victim: FailureInjector(
                            fail_at=(kill_step,))})
    infos = [cl.open_session() for _ in range(n)]
    assert cl.session_member(infos[0].sid) == victim
    # every round_ below is exactly one cluster step — no intermediate
    # pump, so the injector's step id maps 1:1 onto the round index
    t_next = 0

    def round_():
        nonlocal t_next
        for i in infos:
            cl.submit(i.sid, _req(i.sid, t_next, cfg, us))
        cl.step()
        t_next += 1
        st = cl.stats()
        assert st.conserved, (st.submitted, st.served, st.queue_depth,
                              st.in_flight, st.shed_expired,
                              st.lost_in_flight)

    for _ in range(WARMUP_ROUNDS + rounds):
        round_()
    cl.pump()
    st = cl.stats()
    assert st.conserved and st.failures == 1
    assert victim not in st.members
    assert st.sessions_open == n and cl.lost_sessions == []
    return cl, infos, results, t_next, victim


def bench_cluster_chaos(members=2, *, rounds=8,
                        spm=SESSIONS_PER_MEMBER):
    """Seeded member kill mid-stream, replication OFF vs ON — the
    self-healing lane.  Hard asserts: the ON run loses STRICTLY fewer
    frames than the OFF run on the same schedule (with a per-step
    journal flush: zero), and every recovered stream's (z, k) is
    bit-identical to an unfailed replay on a fresh single gateway."""
    from repro.api import StreamSplitGateway
    from repro.models.audio_encoder import AudioEncCfg, init_audio_encoder
    cfg = AudioEncCfg(**DEEP_KW)
    params = init_audio_encoder(cfg, jax.random.PRNGKey(0))
    n = members * spm
    us = [float(u) for u in
          np.random.default_rng(3).permutation(np.linspace(0.02, 0.98, n))]

    cl_off, _, _, _, _ = _chaos_once(replicate=False, members=members,
                                     rounds=rounds, spm=spm, cfg=cfg,
                                     params=params, us=us)
    lost_off = sum(cl_off.stats().lost_in_flight.values())
    assert lost_off > 0     # checkpoint-only recovery drops the backlog

    t0 = time.perf_counter()
    cl_on, infos, results, t_next, victim = _chaos_once(
        replicate=True, members=members, rounds=rounds, spm=spm,
        cfg=cfg, params=params, us=us)
    dt = time.perf_counter() - t0
    st = cl_on.stats()
    lost_on = sum(st.lost_in_flight.values())
    assert lost_on < lost_off            # the headline loss bound
    assert lost_on == 0                  # per-step flush: zero loss
    assert st.failovers > 0 and st.replayed_frames > 0
    assert st.served == st.submitted
    assert sum(st.shed_expired.values()) == 0

    # replay-parity oracle over EVERY session (recovered and not):
    # checkpoint + journal replay must be invisible to the embedding
    by_sid = {}
    for r in results:
        assert r.t not in by_sid.setdefault(r.sid, {})   # no dupes
        by_sid[r.sid][r.t] = r
    oracle = StreamSplitGateway(cfg, params,
                                policy=MixedKPolicy(cfg.n_blocks),
                                capacity=n, window=16,
                                qos_reserve=0, overlap=True)
    for gsid in sorted(by_sid):
        assert sorted(by_sid[gsid]) == list(range(t_next))
        osid = oracle.open_session().sid
        for t in range(t_next):
            oracle.submit(osid, _req(gsid, t, cfg, us))
            (ref,) = oracle.tick()
            got = by_sid[gsid][t]
            assert (got.z == ref.z).all() and got.k == ref.k, \
                f"recovered session {gsid} diverged at t={t}"

    for i in infos:
        cl_on.close_session(i.sid)
    return {
        "members": members,
        "sessions": n,
        "rounds": rounds,
        "victim": victim,
        "frames_per_s": (t_next * n) / dt,
        "lost_replication_off": lost_off,
        "lost_replication_on": lost_on,
        "failovers": st.failovers,
        "replayed_frames": st.replayed_frames,
        "journal_bytes": st.journal_bytes,
        "retries": st.retries,
        "bit_identical_replay": True,
    }


def run_all(*, quick=False, smoke=False):
    result = {"cluster": {}}
    rounds = 4 if smoke else (6 if quick else 10)
    for m in (2, 4):
        r = bench_cluster_drain(m, rounds=rounds)
        result["cluster"][m] = r
        p = r["migration_pause_ms"]
        row(f"cluster.migration_pause.N{m}", p["p50"] * 1e3,
            f"ms*1e3 p50 warm; p95 {p['p95']:.2f}ms max {p['max']:.2f}ms "
            f"(cold max {r['migration_pause_cold_ms']['max']:.0f}ms), "
            f"{r['migrations']} sessions moved, "
            f"{r['migrated_frames']} queued frames, "
            f"{r['migrated_bytes']} B")
        fps = r["frames_per_s"]
        row(f"cluster.drain_fps.N{m}", 1e6 / max(fps["during_drain"], 1e-9),
            f"{fps['during_drain']:.0f} frames/s during drain "
            f"(before {fps['before']:.0f}, after {fps['after']:.0f}), "
            "0 shed, 0 lost, bit-identical migrated replay")
    c = bench_cluster_chaos(2, rounds=rounds)
    result["chaos"] = {2: c}
    row("cluster.chaos_lost_frames", float(c["lost_replication_on"]),
        f"lost with replication ON (OFF run: "
        f"{c['lost_replication_off']}), {c['failovers']} failovers, "
        f"{c['replayed_frames']} journal frames replayed "
        f"({c['journal_bytes']} B shipped), bit-identical recovery")
    print("BENCH " + json.dumps({"bench": "cluster_serve", **result}))
    return result


def write_bench_json(result, path="BENCH_cluster.json"):
    """Machine-readable federation trajectory (CI artifact — see
    docs/FEDERATION.md for the schema)."""
    doc = {"bench": "cluster_serve", "schema": 1,
           "backend": jax.default_backend(), **result}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: fewest rounds that still "
                         "exercise every assert")
    args = ap.parse_args()
    out = run_all(quick=args.quick, smoke=args.smoke)
    print("wrote", write_bench_json(out))
